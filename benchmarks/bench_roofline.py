"""Roofline scoreboard for the LPA kernels and the out-of-core driver.

Two sections, one JSON artifact (``BENCH_roofline.json``):

**Kernels** — for each degree bucket, the per-sweep HBM byte/FLOP model of
the fused single-dispatch sweep (``kernels/fused_sweep.py``) vs. the
separate-dispatch baseline (wake pass + ``label_argmax``; split-wake pass
+ ``min_label``), with measured wall time and achieved vs. *measured*
peak bytes/s and FLOP/s on this host.  The byte model counts what each
dispatch must read from HBM per (row, neighbor-slot) cell:

  separate move sweep:  wake(chg 1B + mask 1B) + argmax(lab 4B + w 4B
                        + mask 1B)                     = 11 B/cell
  fused move sweep:     lab 4B + w 4B + mask 1B + chg 1B = 10 B/cell
  separate split sweep: split-wake(comm 4B + chg 1B + mask 1B)
                        + min_label(lab 4B + comm 4B + mask 1B) = 15 B/cell
  fused split sweep:    lab 4B + comm 4B + mask 1B + chg 1B    = 10 B/cell

The fused kernel reads the (TILE_B, D) tiles once per sweep; the separate
path re-reads the mask (and the split path the community column) in its
second dispatch.  The bench **asserts** fused < separate for both sweeps.
FLOPs: the equality-masked matmul is a (1, D) x (D, D) dot per row —
2·D FLOP per cell (move sweeps only; the split min is compare-bound).

**OOC** — the ``bench_ooc_partition.py`` rmat fixture at 1/8 budget,
detected with the PR-5 serial driver (separate dispatches, no prefetch,
no halo cache) vs. the overlapped driver (fused partition sweeps +
window prefetch + halo-label cache).  Asserts label parity, ledger peak
<= budget for both, and that the prefetcher actually staged windows.
The >= 1.15x wall-time bar needs a second core (the prefetch worker can
only hide load+prepare time if something else can run meanwhile); on a
single-CPU host the ratio is recorded and the bar is reported as
``overlap_capable: false`` instead of asserted.

    PYTHONPATH=src python benchmarks/bench_roofline.py [BENCH_roofline.json]
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import jax.numpy as jnp
import numpy as np

from common import emit

from repro.kernels import ops

# ------------------------------------------------------------ byte model ---
LAB, WGT, COMM, MASK, CHG = 4, 4, 4, 1, 1
MOVE_SEPARATE_BPC = (CHG + MASK) + (LAB + WGT + MASK)       # wake + argmax
MOVE_FUSED_BPC = LAB + WGT + MASK + CHG
SPLIT_SEPARATE_BPC = (COMM + CHG + MASK) + (LAB + COMM + MASK)
SPLIT_FUSED_BPC = LAB + COMM + MASK + CHG


def move_flops_per_cell(d: int) -> int:
    """The equality-masked matmul: (1, D) x (D, D) per row = 2·D per cell."""
    return 2 * d


# ------------------------------------------------------- measured peaks ----
def measure_peak_bandwidth() -> float:
    """STREAM-triad bytes/s on this host (numpy, ~48 MB working set)."""
    n = 2_000_000
    a = np.zeros(n)
    b = np.random.default_rng(0).random(n)
    c = np.random.default_rng(1).random(n)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        np.add(b, c, out=a)
        a *= 1.000001
        best = min(best, time.perf_counter() - t0)
    # triad + scale: 3 reads + 2 writes of 8 B
    return n * 8 * 5 / best


def measure_peak_flops() -> float:
    """f32 matmul FLOP/s through the same XLA backend the kernels use."""
    k = 512
    x = jnp.asarray(np.random.default_rng(2).random((k, k)), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 2 * k**3 / best


# ------------------------------------------------------------ kernel legs --
def _tiles(n_pad: int, d: int, seed: int):
    """Synthetic padded-neighbor tiles with realistic label collisions."""
    rng = np.random.default_rng(seed)
    nbr_lab = jnp.asarray(rng.integers(0, n_pad, (n_pad, d)), jnp.int32)
    nbr_w = jnp.asarray(rng.random((n_pad, d)), jnp.float32)
    nbr_mask = jnp.asarray(rng.random((n_pad, d)) < 0.8)
    chg = jnp.asarray(rng.random((n_pad, d)) < 0.3)
    cur = jnp.asarray(rng.integers(0, n_pad, n_pad), jnp.int32)
    comm = jnp.asarray(rng.integers(0, max(n_pad // 8, 1), n_pad), jnp.int32)
    nbr_comm = jnp.asarray(
        rng.integers(0, max(n_pad // 8, 1), (n_pad, d)), jnp.int32)
    ones = jnp.ones(n_pad, dtype=bool)
    return nbr_lab, nbr_w, nbr_mask, chg, cur, comm, nbr_comm, ones


def _timed(fn, repeats: int = 3) -> float:
    fn()  # warmup / compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


# the unfused tile path's wake dispatches (jnp, XLA-compiled) — what the
# fused kernel folds into its single pallas_call
@jax.jit
def _wake_dispatch(chg_nbr, nbr_mask):
    return jnp.any(chg_nbr & nbr_mask, axis=1)


@jax.jit
def _split_wake_dispatch(chg_nbr, nbr_mask, nbr_comm, comm):
    same = nbr_mask & (nbr_comm == comm[:, None])
    return jnp.any(chg_nbr & same, axis=1)


def kernel_rows(peak_bps: float, peak_flops: float) -> list[dict]:
    # (n_pad, d, mode): ref rows give the real achieved-vs-peak numbers on
    # this backend; the interpret rows run the actual Pallas kernel bodies
    # (slow — interpreter overhead — kept small, scoreboard completeness)
    cases = [(2048, 128, "ref"), (1024, 256, "ref"), (512, 512, "ref"),
             (256, 128, "interpret")]
    rows = []
    for n_pad, d, mode in cases:
        nbr_lab, nbr_w, nbr_mask, chg, cur, comm, nbr_comm, ones = \
            _tiles(n_pad, d, seed=d)
        cells = n_pad * d
        seed = jnp.int32(3)

        t_sep_move = _timed(lambda: (
            _wake_dispatch(chg, nbr_mask),
            ops.label_argmax(nbr_lab, nbr_w, nbr_mask, cur, seed,
                             mode=mode))[-1])
        t_fus_move = _timed(lambda: ops.fused_move(
            nbr_lab, nbr_w, nbr_mask, chg, cur, ones, ones, ones, ones,
            seed, mode=mode))
        t_sep_split = _timed(lambda: (
            _split_wake_dispatch(chg, nbr_mask, nbr_comm, comm),
            ops.min_label(nbr_lab, nbr_comm, nbr_mask, cur, comm,
                          mode=mode))[-1])
        t_fus_split = _timed(lambda: ops.fused_split(
            nbr_lab, nbr_comm, nbr_mask, chg, cur, comm, prune=True,
            mode=mode))

        for sweep, t_sep, t_fus, bpc_sep, bpc_fus, fpc in (
                ("move", t_sep_move, t_fus_move,
                 MOVE_SEPARATE_BPC, MOVE_FUSED_BPC, move_flops_per_cell(d)),
                ("split", t_sep_split, t_fus_split,
                 SPLIT_SEPARATE_BPC, SPLIT_FUSED_BPC, 0)):
            assert bpc_fus < bpc_sep, (
                f"fused {sweep} sweep must move strictly fewer HBM bytes "
                f"({bpc_fus} vs {bpc_sep} B/cell)")
            for variant, t, bpc in (("separate", t_sep, bpc_sep),
                                    ("fused", t_fus, bpc_fus)):
                bps = cells * bpc / t
                fps = cells * fpc / t
                rows.append({
                    "bench": f"{sweep}_{variant}_d{d}_{mode}",
                    "kind": "kernel", "sweep": sweep, "variant": variant,
                    "d": d, "rows": n_pad, "mode": mode, "seconds": t,
                    "model_bytes_per_cell": bpc,
                    "model_bytes": cells * bpc,
                    "model_flops": cells * fpc,
                    "achieved_bytes_per_s": round(bps, 1),
                    "achieved_flops_per_s": round(fps, 1),
                    "frac_of_peak_bw": round(bps / peak_bps, 4),
                    "frac_of_peak_flops": round(fps / peak_flops, 4)
                    if fpc else 0.0,
                })
            rows.append({
                "bench": f"{sweep}_fusion_gain_d{d}_{mode}",
                "kind": "kernel_gain", "sweep": sweep, "d": d, "mode": mode,
                "seconds": t_sep - t_fus,
                "bytes_saved_per_cell": bpc_sep - bpc_fus,
                "time_ratio_separate_over_fused": round(t_sep / t_fus, 3),
            })
    return rows


# --------------------------------------------------------------- ooc leg ---
def ooc_rows() -> list[dict]:
    from bench_ooc_partition import BUDGET_DIVISOR, ensure_store_entry

    from repro.engine import CompileCache, EngineConfig
    from repro.io.store import CsrStore
    from repro.partition.ooc import fit_out_of_core, in_core_edge_bytes
    from repro.partition.slices import StoreEntrySource

    store = CsrStore(os.environ.get("REPRO_GRAPH_CACHE"))
    source = StoreEntrySource(ensure_store_entry(store))
    budget = in_core_edge_bytes(source) // BUDGET_DIVISOR
    cache = CompileCache()
    serial_cfg = EngineConfig(backend="segment", split="lp",
                              fuse_sweeps="off")
    over_cfg = EngineConfig(backend="segment", split="lp", fuse_sweeps="on")

    def best_of(cfg, **kw):
        fit_out_of_core(source, cfg, memory_budget=budget, cache=cache,
                        **kw)  # warmup: compile + page cache
        best, run = float("inf"), None
        for _ in range(2):
            t0 = time.perf_counter()
            run = fit_out_of_core(source, cfg, memory_budget=budget,
                                  cache=cache, **kw)
            best = min(best, time.perf_counter() - t0)
        return best, run

    t_serial, serial = best_of(serial_cfg, prefetch=False, halo_cache=False)
    t_over, over = best_of(over_cfg, prefetch=True, halo_cache=True)

    assert np.array_equal(serial.labels, over.labels), \
        "overlapped ooc sweep diverged from the serial driver"
    for name, run in (("serial", serial), ("overlapped", over)):
        assert run.peak_resident_bytes <= budget, (
            f"{name} peak {run.peak_resident_bytes} exceeded budget {budget}")
    assert over.fused, "overlapped leg did not dispatch the fused sweeps"
    assert over.prefetch_hits > 0, "prefetcher never staged a window"

    cores = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    speedup = t_serial / t_over
    overlap_capable = cores > 1
    if overlap_capable:
        assert speedup >= 1.15, (
            f"overlapped ooc sweep only {speedup:.3f}x serial "
            f"(>= 1.15x required with {cores} cores)")
    m = source.num_edges
    rows = []
    for name, t, run in (("serial", t_serial, serial),
                         ("overlapped", t_over, over)):
        rows.append({
            "bench": f"ooc_{name}", "kind": "ooc", "variant": name,
            "seconds": t, "edges": m, "edges_per_s": round(m / t, 1),
            "budget": budget, "peak_resident_bytes": run.peak_resident_bytes,
            "partitions": run.num_partitions, "fused": run.fused,
            "partition_loads": run.partition_loads,
            "prefetches": run.prefetches,
            "prefetch_hits": run.prefetch_hits,
            "halo_cache_hits": run.halo_cache_hits,
            "halo_cache_bytes_saved": run.halo_cache_bytes_saved,
            "exchange_bytes": run.exchange_bytes,
        })
    rows.append({
        "bench": "ooc_overlap", "kind": "ooc_gain",
        "seconds": t_serial - t_over,
        "speedup_serial_over_overlapped": round(speedup, 3),
        "cores": cores, "overlap_capable": overlap_capable,
        "bar_1_15x": "asserted" if overlap_capable else
        "single-core host: prefetch thread cannot overlap, ratio recorded",
    })
    return rows


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_roofline.json"
    peak_bps = measure_peak_bandwidth()
    peak_flops = measure_peak_flops()
    rows = [{
        "bench": "peaks", "kind": "peaks", "seconds": 0.0,
        "peak_bytes_per_s": round(peak_bps, 1),
        "peak_flops_per_s": round(peak_flops, 1),
        "backend": jax.default_backend(),
    }]
    rows += kernel_rows(peak_bps, peak_flops)
    rows += ooc_rows()
    emit(rows, "roofline")
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=2)
    print(f"[bench-roofline] wrote {out_path} "
          f"(peak {peak_bps / 1e9:.1f} GB/s, {peak_flops / 1e9:.1f} GFLOP/s)")


if __name__ == "__main__":
    main()
