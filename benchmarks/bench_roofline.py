"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Sources (per DESIGN.md §7; hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

  compute term    = FLOPs_per_device / peak_flops
  memory term     = HBM_bytes_per_device / hbm_bw
  collective term = wire_bytes_per_device / link_bw

The compiled SPMD module is per-device, so ``cost_analysis()`` numbers are
per-device already.  XLA counts while-loop bodies ONCE, so rolled-scan
lowerings under-report FLOPs/bytes by ~n_layers; cells with an unrolled
lowering (``*_unrolled.json``) use the compiled number (source=hlo), the
rest use the analytic model below (source=analytic), cross-validated
against the unrolled cells.  Collective bytes always come from the HLO
parse (with the while-trip multiplier applied at dry-run time).

MODEL_FLOPS convention: 6*N_active*T for training (8*N*T with full remat),
2*N_active*T for prefill, 2*N_active*B for decode, plus explicit S^2
attention terms — the ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy
waste.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_config, supported_shapes
from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s / link
DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


# ---------------------------------------------------------------- FLOPs ----
def attention_flops_fwd(cfg, b, s_q, s_kv):
    """QK^T + PV for every attention layer (full rectangle, as compiled)."""
    l_attn = sum(1 for mix, _ in cfg.layer_kinds() if mix == "attn")
    per_layer = 4 * b * s_q * s_kv * cfg.n_heads * cfg.head_dim
    if cfg.kind == "encdec":
        # decoder self + cross; encoder self
        enc = 4 * b * s_kv * s_kv * cfg.n_heads * cfg.head_dim \
            * cfg.enc_layers
        cross = 4 * b * s_q * cfg.cross_memory_len * cfg.n_heads \
            * cfg.head_dim * cfg.n_layers
        return per_layer * l_attn + enc + cross
    return per_layer * l_attn


def model_flops(cfg, shape: str) -> dict:
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    n_act = cfg.active_param_count()
    if sp.step == "train":
        t = b * s
        matmul = 6 * n_act * t
        if cfg.remat == "full":
            matmul = 8 * n_act * t          # + recompute forward
        attn = attention_flops_fwd(cfg, b, s, s) * 4   # fwd+bwd+remat
        return {"model_flops": 6 * n_act * t,          # canonical 6ND
                "expected_hlo_flops": matmul + attn}
    if sp.step == "prefill":
        t = b * s
        return {"model_flops": 2 * n_act * t,
                "expected_hlo_flops": 2 * n_act * t
                + attention_flops_fwd(cfg, b, s, s)}
    # decode: one token, cache of s; enc-dec reads the (precomputed)
    # cross memory, the encoder itself does NOT run
    if cfg.kind == "encdec":
        l_attn = cfg.n_layers
        self_a = 4 * b * 1 * s * cfg.n_heads * cfg.head_dim * l_attn
        cross = 4 * b * 1 * cfg.cross_memory_len * cfg.n_heads \
            * cfg.head_dim * l_attn
        return {"model_flops": 2 * n_act * b,
                "expected_hlo_flops": 2 * n_act * b + self_a + cross}
    return {"model_flops": 2 * n_act * b,
            "expected_hlo_flops": 2 * n_act * b
            + attention_flops_fwd(cfg, b, 1, s)}


def analytic_hbm_bytes(cfg, shape: str, chips: int,
                       state_bytes_per_dev: int) -> float:
    """Per-device HBM traffic per step (roofline memory numerator).

    train:   read params+opt, write params+opt (~2x state) + activation
             spill (2 bytes x tokens x d x layers / chips, saved + reread)
    prefill: read params + write KV cache
    decode:  read params + read cache once (the classic decode roofline)
    """
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    if sp.step == "train":
        act = 2 * b * s * cfg.d_model * cfg.n_layers * 2 * 2 / chips
        return 2.0 * state_bytes_per_dev + act
    if sp.step == "prefill":
        return float(state_bytes_per_dev) \
            + 2 * b * s * cfg.d_model * cfg.n_layers * 2 / chips
    return float(state_bytes_per_dev)   # decode: params + cache read once


# ------------------------------------------------------------ the table ----
def load_cell(arch: str, shape: str, mesh: str) -> dict | None:
    unrolled = DRYRUN_DIR / f"{arch}_{shape}_{mesh}_unrolled.json"
    rolled = DRYRUN_DIR / f"{arch}_{shape}_{mesh}.json"
    rec = None
    if rolled.exists():
        rec = json.loads(rolled.read_text())
    if unrolled.exists():
        u = json.loads(unrolled.read_text())
        if rec is None:
            rec = u
        else:
            rec["cost_analysis"] = u["cost_analysis"]
            rec["unrolled"] = True
    return rec


def roofline_row(arch: str, shape: str, mesh: str = "pod") -> dict | None:
    rec = load_cell(arch, shape, mesh)
    if rec is None:
        return None
    cfg = get_config(arch)
    chips = rec["chips"]
    mf = model_flops(cfg, shape)
    state_b = rec["meta"].get("analytic_state_bytes_per_device", 0)

    if rec.get("unrolled"):
        flops_dev = rec["cost_analysis"].get("flops", 0.0)
        flops_src = "hlo_unrolled"
    else:
        flops_dev = mf["expected_hlo_flops"] / chips
        flops_src = "analytic"
    mem_dev = analytic_hbm_bytes(cfg, shape, chips, state_b)
    wire_dev = rec["collectives"]["wire_bytes"].get("total", 0.0)
    # CPU-backend float normalization upcasts bf16 tensors to f32, so the
    # parsed HLO shows activation/gradient collectives at 2x their TPU
    # width.  LM-cell traffic is bf16-dominated on TPU -> halve; the graph
    # engine exchanges s32 labels (true 4B) -> no correction.
    wire_dev *= 0.5

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = mem_dev / HBM_BW
    t_coll = wire_dev / LINK_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_compute, t_memory, t_coll)
    useful = mf["model_flops"] / chips / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf["model_flops"],
        "hlo_flops_per_dev": flops_dev, "flops_source": flops_src,
        "useful_ratio": mf["model_flops"] / max(flops_dev * chips, 1.0),
        "roofline_fraction": useful / max(bound, 1e-30),
        "state_bytes_per_dev": state_b,
        "compile_seconds": rec.get("compile_seconds"),
    }


def run(quiet: bool = False, mesh: str = "pod") -> list[dict]:
    rows = []
    for arch, cfg in ARCHS.items():
        for shape in supported_shapes(cfg):
            r = roofline_row(arch, shape, mesh)
            if r:
                rows.append(r)
    # the paper's own workload
    g = DRYRUN_DIR / f"graph-lpa_graph_{mesh}.json"
    if g.exists():
        rec = json.loads(g.read_text())
        wire = rec["collectives"]["wire_bytes"].get("total", 0.0)
        flops = rec["cost_analysis"].get("flops", 0.0)
        ba = rec["cost_analysis"].get("bytes accessed", 0.0)
        rows.append({
            "arch": "graph-lpa", "shape": "graph", "mesh": mesh,
            "chips": rec["chips"],
            "t_compute_s": flops / PEAK_FLOPS,
            "t_memory_s": ba / HBM_BW,
            "t_collective_s": wire / LINK_BW,
            "dominant": "collective" if wire / LINK_BW >
            max(flops / PEAK_FLOPS, ba / HBM_BW) else "memory",
            "flops_source": "hlo",
        })
    if not quiet:
        for r in rows:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
                  f"tc={r['t_compute_s']:.3e};tm={r['t_memory_s']:.3e};"
                  f"tx={r['t_collective_s']:.3e};dom={r['dominant']};"
                  f"frac={r.get('roofline_fraction', 0):.3f};"
                  f"src={r['flops_source']}")
    return rows


if __name__ == "__main__":
    run()
