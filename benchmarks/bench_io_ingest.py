"""Ingestion throughput: parse / preprocess / CSR-cache-hit rates.

Generates a deterministic mid-size edge list (seeded RMAT-style power
law, so the file bytes — and therefore the CSR store key — are stable
across runs), writes it in both supported formats, and measures:

  * ``parse_mtx`` / ``parse_snap``   chunked text -> raw EdgeList (edges/s)
  * ``preprocess``                   §4.1 cleaning passes (edges/s)
  * ``ingest_cold``                  full load_graph with ``force=True``
                                     (parse + preprocess + build + save)
  * ``ingest_hit``                   load_graph on a warm store (content
                                     hash + mmap read, no parsing)

Acceptance bar (asserted, JSON artifact in CI): the cache-hit load is
>= 10x faster than the text parse alone — the store must make repeat
loads effectively free relative to parsing.

    PYTHONPATH=src python benchmarks/bench_io_ingest.py [out.json]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import emit

from repro.io import (
    PreprocessOptions,
    load_graph,
    parse_mtx,
    parse_snap,
    preprocess,
    write_mtx,
    write_snap,
)

SCALE_VERTICES = 1 << 15
UNDIRECTED_EDGES = 250_000
REPEATS = 3
HIT_SPEEDUP_FLOOR = 10.0


def make_edges() -> tuple[np.ndarray, int]:
    """Deterministic power-law-ish edge list (stable file bytes)."""
    rng = np.random.default_rng(42)
    n = SCALE_VERTICES
    # heavy-tailed endpoints: squash uniform^2 toward low ids
    u = (rng.random(UNDIRECTED_EDGES) ** 2 * n).astype(np.int64)
    v = (rng.random(UNDIRECTED_EDGES) ** 2 * n).astype(np.int64)
    return np.stack([u, v], axis=1), n


def median_time(fn, repeats: int = REPEATS) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "io_ingest.json"
    edges, n = make_edges()
    workdir = Path(tempfile.mkdtemp(prefix="bench-io-"))
    cache_dir = os.environ.get("REPRO_GRAPH_CACHE",
                               str(workdir / "csr-cache"))
    mtx = workdir / "bench_ingest.mtx"
    snap = workdir / "bench_ingest.snap.txt"
    write_mtx(mtx, edges, n=n, symmetric=True)
    write_snap(snap, edges)
    raw_entries = len(edges)

    rows = []

    def add(bench: str, seconds: float, edge_count: int, **extra):
        rows.append({"bench": bench, "seconds": seconds,
                     "edges_per_s": round(edge_count / max(seconds, 1e-9)),
                     **extra})

    parse_mtx_s = median_time(lambda: parse_mtx(mtx))
    add("parse_mtx", parse_mtx_s, raw_entries,
        file_mb=round(mtx.stat().st_size / 1e6, 1))
    parse_snap_s = median_time(lambda: parse_snap(snap))
    add("parse_snap", parse_snap_s, raw_entries,
        file_mb=round(snap.stat().st_size / 1e6, 1))

    raw = parse_mtx(mtx)
    pre_s = median_time(lambda: preprocess(raw, PreprocessOptions()))
    add("preprocess", pre_s, raw.num_edges)

    cold_s = median_time(lambda: load_graph(
        mtx, cache_dir=cache_dir, force=True))
    add("ingest_cold", cold_s, raw_entries)

    hit_reports = []

    def hit():
        _, rep = load_graph(mtx, cache_dir=cache_dir, return_report=True)
        hit_reports.append(rep)

    hit_s = median_time(hit)
    assert all(r.cache_hit for r in hit_reports), \
        "warm loads missed the CSR store"
    speedup = parse_mtx_s / max(hit_s, 1e-9)
    add("ingest_hit", hit_s, raw_entries,
        speedup_vs_parse=round(speedup, 1))

    emit(rows, "io_ingest")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"[bench-io-ingest] wrote {out_path}")

    assert speedup >= HIT_SPEEDUP_FLOOR, (
        f"CSR cache hit ({hit_s * 1e3:.1f}ms) is only {speedup:.1f}x the "
        f"parse ({parse_mtx_s * 1e3:.1f}ms); floor is "
        f"{HIT_SPEEDUP_FLOOR:.0f}x")
    print(f"[bench-io-ingest] cache hit {speedup:.0f}x faster than parse "
          f"({raw_entries} raw entries): OK")


if __name__ == "__main__":
    main()
