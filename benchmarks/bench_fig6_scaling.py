"""Paper Figure 6: strong scaling of GSL-LPA (propagation + split phases).

The paper scales threads 1..64 on a dual-Xeon.  This container has ONE
physical core, so wall-clock "scaling" over virtual devices measures
partitioning overhead, not speedup.  What this benchmark therefore reports
per device count is (a) the per-device work (rows x d_max) — perfectly
balanced by construction, (b) the collective bytes per sweep
(n x 4B label all-gather) — the structural scaling terms that the §Roofline
analysis converts into time on real hardware — plus the (overhead-dominated)
CPU wall time for completeness.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

REPO = Path(__file__).resolve().parents[1]

_CHILD = r"""
import json, time
import jax, numpy as np
from repro.core.distributed import distributed_gsl_lpa, shard_graph
from repro.graphgen import rmat

ndev = {ndev}
from repro.parallel.compat import make_mesh
mesh = make_mesh((ndev,), ("data",))
g = rmat(11, 12, seed=7)
t0 = time.time()
labels, it, sit = distributed_gsl_lpa(g, mesh)
dt = time.time() - t0
sg = shard_graph(g, mesh)
print("RESULT" + json.dumps({{
    "seconds": dt, "lpa_iters": it, "split_iters": sit,
    "rows_per_device": sg.n_pad // ndev, "d_max": sg.d_max,
    "allgather_bytes_per_sweep": int(sg.n_pad * 4),
    "n": g.n, "edges": g.num_edges}}))
"""


def run(quiet: bool = False, device_counts=(1, 2, 4, 8)) -> list[dict]:
    rows = []
    base = None
    for ndev in device_counts:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
                   PYTHONPATH=str(REPO / "src"))
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD.format(ndev=ndev)],
            env=env, capture_output=True, text=True, timeout=560)
        if proc.returncode != 0:
            rows.append({"bench": f"ndev{ndev}", "seconds": -1.0,
                         "error": proc.stderr.strip()[-200:]})
            continue
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT")][0]
        r = json.loads(line[len("RESULT"):])
        if base is None:
            base = r["seconds"]
        rows.append({
            "bench": f"ndev{ndev}", "seconds": r["seconds"],
            "rel_time": round(r["seconds"] / base, 3),
            "rows_per_device": r["rows_per_device"],
            "work_scaling": round(
                rows[0]["rows_per_device"] / r["rows_per_device"], 2)
            if rows else 1.0,
            "allgather_bytes_per_sweep": r["allgather_bytes_per_sweep"],
            "iters": r["lpa_iters"] + r["split_iters"],
        })
    if not quiet:
        emit(rows, "fig6_scaling")
    return rows


if __name__ == "__main__":
    run()
