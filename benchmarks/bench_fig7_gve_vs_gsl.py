"""Paper Figure 7 / §A.2: GVE-LPA vs GSL-LPA — the cost of the guarantee.

Paper: GSL ~2.25x GVE runtime (125% longer), +0.4% modularity,
GVE averages 6.6% disconnected communities vs 0 for GSL.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import disconnected_fraction, gsl_lpa, gve_lpa, modularity
from benchmarks.common import emit, suite


def run(quiet: bool = False) -> list[dict]:
    rows = []
    ratios, dq, dfrac = [], [], []
    for gname, (g, desc) in suite().items():
        gve_lpa(g)                           # warmup (jit compile)
        gsl_lpa(g, split="lp")
        gve = gve_lpa(g)
        gsl = gsl_lpa(g, split="lp")
        q_gve = float(modularity(g, jnp.asarray(gve.labels)))
        q_gsl = float(modularity(g, jnp.asarray(gsl.labels)))
        f_gve = float(disconnected_fraction(g, jnp.asarray(gve.labels)))
        f_gsl = float(disconnected_fraction(g, jnp.asarray(gsl.labels)))
        ratio = gsl.total_seconds / max(gve.total_seconds, 1e-9)
        ratios.append(ratio)
        dq.append(q_gsl - q_gve)
        dfrac.append(f_gve)
        rows.append({
            "bench": gname, "seconds": gsl.total_seconds,
            "runtime_ratio_gsl_over_gve": round(ratio, 2),
            "Q_gve": round(q_gve, 4), "Q_gsl": round(q_gsl, 4),
            "disc_gve": round(f_gve, 5), "disc_gsl": round(f_gsl, 5),
        })
    rows.append({
        "bench": "mean", "seconds": 0.0,
        "runtime_ratio_gsl_over_gve": round(
            sum(ratios) / len(ratios), 2),
        "mean_dQ": round(sum(dq) / len(dq), 4),
        "mean_disc_gve": round(sum(dfrac) / len(dfrac), 4),
        "mean_disc_gsl": 0.0,
    })
    if not quiet:
        emit(rows, "fig7_gve_vs_gsl")
    return rows


if __name__ == "__main__":
    run()
