"""Render BENCH_roofline.json as the §Roofline markdown tables.

  PYTHONPATH=src python benchmarks/report_roofline_md.py [BENCH_roofline.json]

Two tables: the per-kernel scoreboard (fused vs. separate dispatch, per
degree bucket — model HBM bytes, measured wall, achieved vs. measured
peak bytes/s and FLOP/s) and the out-of-core sweep comparison
(overlapped vs. serial driver).  Run ``bench_roofline.py`` first.
"""
from __future__ import annotations

import json
import sys


def _si(x: float) -> str:
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if x >= div:
            return f"{x / div:.2f} {unit}"
    return f"{x:.0f} "


def main(path: str = "BENCH_roofline.json") -> None:
    rows = json.load(open(path))
    peaks = next(r for r in rows if r["kind"] == "peaks")
    print(f"Measured peaks ({peaks['backend']}): "
          f"{_si(peaks['peak_bytes_per_s'])}B/s, "
          f"{_si(peaks['peak_flops_per_s'])}FLOP/s\n")

    print("| sweep | variant | d | mode | wall (ms) | model B/cell "
          "| achieved B/s | % peak BW | achieved FLOP/s | % peak FLOPs |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["kind"] != "kernel":
            continue
        print(f"| {r['sweep']} | {r['variant']} | {r['d']} | {r['mode']} "
              f"| {r['seconds'] * 1e3:.2f} | {r['model_bytes_per_cell']} "
              f"| {_si(r['achieved_bytes_per_s'])}B/s "
              f"| {100 * r['frac_of_peak_bw']:.2f}% "
              f"| {_si(r['achieved_flops_per_s'])}FLOP/s "
              f"| {100 * r['frac_of_peak_flops']:.2f}% |")

    print("\n| ooc driver | wall (s) | edges/s | partitions | fused "
          "| prefetch hits | cache hits | peak bytes / budget |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["kind"] != "ooc":
            continue
        print(f"| {r['variant']} | {r['seconds']:.2f} "
              f"| {_si(r['edges_per_s'])} | {r['partitions']} "
              f"| {'yes' if r['fused'] else 'no'} | {r['prefetch_hits']} "
              f"| {r['halo_cache_hits']} "
              f"| {r['peak_resident_bytes']} / {r['budget']} |")
    gain = next((r for r in rows if r["kind"] == "ooc_gain"), None)
    if gain:
        print(f"\nOverlap: {gain['speedup_serial_over_overlapped']}x "
              f"serial/overlapped on {gain['cores']} core(s) — "
              f"{gain['bar_1_15x']}")


if __name__ == "__main__":
    main(*(sys.argv[1:] or ["BENCH_roofline.json"]))
