"""Render the §Roofline markdown table for EXPERIMENTS.md from dry-run JSONs.

  PYTHONPATH=src:. python benchmarks/report_roofline_md.py [mesh]
"""
from __future__ import annotations

import sys

from benchmarks.bench_roofline import run


def fmt(x, digits=3):
    if x == 0:
        return "0"
    return f"{x:.{digits}e}"


def main(mesh: str = "pod") -> None:
    rows = run(quiet=True, mesh=mesh)
    print(f"| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
          f" | dominant | roofline frac | useful ratio | flops src |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute_s'])} "
              f"| {fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} "
              f"| **{r['dominant']}** "
              f"| {r.get('roofline_fraction', 0):.3f} "
              f"| {r.get('useful_ratio', 0):.2f} "
              f"| {r['flops_source']} |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or ["pod"]))
