"""Paper Figure 4: GSL-LPA vs FLPA / igraph LPA / NetworKit PLP —
runtime, speedup, modularity, disconnected fraction."""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import disconnected_fraction, gsl_lpa, modularity
from repro.core.baselines import flpa_host, igraph_lpa_host, networkit_plp
from benchmarks.common import emit, suite

BASELINES = {
    "flpa": flpa_host,
    "igraph_lpa": igraph_lpa_host,
    "networkit_plp": networkit_plp,
}


def run(quiet: bool = False) -> list[dict]:
    rows = []
    for gname, (g, desc) in suite().items():
        gsl_lpa(g, split="lp")               # warmup (jit compile)
        t0 = time.perf_counter()
        res = gsl_lpa(g, split="lp")
        t_gsl = time.perf_counter() - t0
        rows.append({
            "bench": f"{gname}/gsl-lpa", "seconds": t_gsl,
            "Q": round(float(modularity(g, jnp.asarray(res.labels))), 4),
            "disc_frac": round(float(disconnected_fraction(
                g, jnp.asarray(res.labels))), 5),
            "medges_per_s": round(g.num_edges / max(t_gsl, 1e-9) / 1e6, 2),
        })
        for bname, fn in BASELINES.items():
            t0 = time.perf_counter()
            lab = fn(g)
            t = time.perf_counter() - t0
            rows.append({
                "bench": f"{gname}/{bname}", "seconds": t,
                "Q": round(float(modularity(g, jnp.asarray(lab))), 4),
                "disc_frac": round(float(disconnected_fraction(
                    g, jnp.asarray(lab))), 5),
                "speedup_vs_gsl": round(t / max(t_gsl, 1e-9), 2),
            })
    if not quiet:
        emit(rows, "fig4_baselines")
    return rows


if __name__ == "__main__":
    run()
