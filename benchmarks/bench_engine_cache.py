"""Engine compile-cache smoke benchmark: cold compile vs warm bucket hit.

For each backend, fits a stream of same-size-class random graphs through
one Engine and reports (a) the cold first-fit latency (trace + XLA
compile + run), (b) the mean warm latency across subsequent same-bucket
fits of *different* graphs, and (c) the measured trace counts — the
caching win the Unified Engine API exists to deliver.

    PYTHONPATH=src python benchmarks/bench_engine_cache.py
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import emit

from repro.engine import TRACE_LOG, CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi

N, DEG, STREAM = 600, 6.0, 6
BACKENDS = ("segment", "tile", "sharded")


def bench_backend(backend: str) -> dict:
    eng = Engine(EngineConfig(backend=backend), cache=CompileCache())
    graphs = [erdos_renyi(N, DEG, seed=100 + i) for i in range(STREAM)]

    before = TRACE_LOG.total(backend)
    t0 = time.perf_counter()
    first = eng.fit(graphs[0])
    cold = time.perf_counter() - t0

    warm_times = []
    for g in graphs[1:]:
        t0 = time.perf_counter()
        res = eng.fit(g)
        warm_times.append(time.perf_counter() - t0)
        assert res.cache_hit, "same-bucket fit missed the compile cache"
    traces = TRACE_LOG.total(backend) - before

    return {"bench": f"{backend}_warm", "seconds": float(np.mean(warm_times)),
            "cold_s": round(cold, 4), "speedup": round(
                cold / max(float(np.mean(warm_times)), 1e-9), 1),
            "traces": traces, "bucket": str(first.bucket),
            "stream": STREAM}


def main() -> None:
    rows = [bench_backend(b) for b in BACKENDS]
    emit(rows, "engine_cache")


if __name__ == "__main__":
    main()
