"""Append one-line per-PR summaries of BENCH_*.json artifacts to BENCH_TREND.md.

Closes the ROADMAP perf-visibility gap: every benchmark artifact a CI run
produces gets exactly one row in a *committed* trend file, so perf drift
is visible in review diffs instead of buried in expiring artifact zips.

  PYTHONPATH=src python benchmarks/trend.py BENCH_ooc.json BENCH_trace_audit.json \
      [--trend BENCH_TREND.md] [--sha <commit>] [--date YYYY-MM-DD]

Rows are deduped by ``(sha, artifact)``: re-running on the same commit
replaces that artifact's row in place (idempotent in CI retries); a new
commit appends.  Unknown artifact shapes get a generic scalar summary,
so new ``BENCH_*.json`` producers join the trend with no code change.
"""
from __future__ import annotations

import argparse
import datetime
import json
import subprocess
import sys
from pathlib import Path

HEADER = [
    "# Benchmark trend",
    "",
    "One row per (commit, artifact), appended by `benchmarks/trend.py`",
    "(the CI `bench-trend` job). Numbers are single-run CI measurements —",
    "directional, not rigorous; see `benchmarks/` for methodology.",
    "",
    "| date | sha | artifact | summary |",
    "|------|-----|----------|---------|",
]


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


def _scalars(d: dict, limit: int = 6) -> str:
    keep = [(k, v) for k, v in d.items()
            if isinstance(v, (int, float, bool))
            or (isinstance(v, str) and len(v) <= 24)]
    return " ".join(f"{k}={_fmt(v)}" for k, v in keep[:limit])


def summarize(name: str, payload) -> str:
    """One-line summary for a known artifact, generic scalars otherwise."""
    if name == "BENCH_trace_audit" and isinstance(payload, dict):
        fits = sum(payload.get("coverage", {}).values())
        return (f"{'PASS' if payload.get('ok') else 'FAIL'}: "
                f"{payload.get('total_traces')} traces / "
                f"{len(payload.get('contexts', []))} contexts, "
                f"{payload.get('excess_contexts')} excess over {fits} fits "
                f"({payload.get('workload_seconds', '?')}s)")
    if name == "BENCH_ooc" and isinstance(payload, list):
        by_mode = {r.get("mode"): r for r in payload if isinstance(r, dict)}
        ooc, ic = by_mode.get("ooc"), by_mode.get("in_core")
        if ooc:
            parts = [f"{ooc.get('partitions')} partitions",
                     f"peak {ooc.get('peak_resident_bytes')}B <= "
                     f"budget {ooc.get('budget')}B"]
            if ic and ic.get("seconds") and ooc.get("seconds"):
                parts.append(f"{ooc['seconds'] / ic['seconds']:.2f}x in-core time")
            return ", ".join(parts)
    if name == "BENCH_roofline" and isinstance(payload, list):
        kinds = {r.get("kind"): r for r in payload if isinstance(r, dict)}
        gains = [r for r in payload if isinstance(r, dict)
                 and r.get("kind") == "kernel_gain"]
        parts = []
        if gains:
            saved = {f"{r.get('sweep')}-{r.get('bytes_saved_per_cell')}B"
                     for r in gains}
            parts.append(f"fused saves {'/'.join(sorted(saved))} per cell")
        ooc_gain = kinds.get("ooc_gain")
        if ooc_gain:
            parts.append(
                f"ooc overlap {ooc_gain.get('speedup_serial_over_overlapped')}x"
                f" on {ooc_gain.get('cores')} core(s)")
        if parts:
            return ", ".join(parts)
    if name == "BENCH_serve_tenants" and isinstance(payload, list):
        by = {r.get("bench"): r for r in payload if isinstance(r, dict)}
        slo = by.get("slo_load")
        if slo:
            parts = [f"{slo.get('tenants')} tenants "
                     f"{_fmt(slo.get('edges_per_s', 0))} edges/s, "
                     f"p99 {_fmt(slo.get('p99_ms', 0))}ms, "
                     f"rej {_fmt(slo.get('rejection_rate', 0))}, "
                     f"{slo.get('stranded')} stranded"]
            spill = by.get("spill_pressure")
            if spill:
                parts.append(f"{spill.get('spills')} spills <= "
                             f"{spill.get('warm_budget')}B")
            rest = by.get("restore_warm")
            if rest:
                parts.append(f"restore {rest.get('warm_iters')}/"
                             f"{rest.get('cold_iters')} warm/cold iters")
            ep = by.get("metrics_endpoint")
            if ep:
                parts.append(f"scrape {ep.get('metric_families')} families "
                             f"{ep.get('latency_exemplars')} exemplars "
                             f"disc={_fmt(ep.get('worst_disconnected_fraction'))}")
            return ", ".join(parts)
    if name == "BENCH_quality" and isinstance(payload, list):
        by = {r.get("mode"): r for r in payload if isinstance(r, dict)}
        basic, full = by.get("basic"), by.get("full")
        if basic:
            parts = [f"basic {basic.get('overhead_vs_off_pct'):+.2f}% "
                     f"vs off (limit "
                     f"{_fmt(basic.get('overhead_limit_pct', 0))}%)"]
            if full:
                parts.append(f"full {full.get('overhead_vs_off_pct'):+.2f}% "
                             f"Q={_fmt(full.get('modularity', 0))} "
                             f"disc={_fmt(full.get('disconnected_fraction'))}")
            parts.append(f"{_fmt(basic.get('edges_per_s', 0))} edges/s")
            return ", ".join(parts)
    if name == "BENCH_obs_overhead" and isinstance(payload, list):
        by = {r.get("mode"): r for r in payload if isinstance(r, dict)}
        conv, full = by.get("convergence"), by.get("full")
        if conv:
            parts = [f"convergence {conv.get('overhead_vs_off_pct'):+.2f}% "
                     f"vs off (limit "
                     f"{_fmt(conv.get('overhead_limit_pct', 0))}%)"]
            if full:
                parts.append(f"full {full.get('overhead_vs_off_pct'):+.2f}%")
            parts.append(f"{_fmt(conv.get('edges_per_s', 0))} edges/s")
            return ", ".join(parts)
    if isinstance(payload, dict):
        return _scalars(payload) or "(no scalar fields)"
    if isinstance(payload, list):
        head = _scalars(payload[0]) if payload and isinstance(payload[0], dict) else ""
        return f"{len(payload)} rows" + (f": {head}" if head else "")
    return str(payload)[:80]


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=Path(__file__).resolve().parent).stdout.strip()
    except Exception:
        return "unknown"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="+", type=Path,
                    help="BENCH_*.json files to summarize")
    ap.add_argument("--trend", type=Path,
                    default=Path(__file__).resolve().parent.parent
                    / "BENCH_TREND.md")
    ap.add_argument("--sha", default=None,
                    help="commit id for the rows (default: git HEAD)")
    ap.add_argument("--date", default=None, help="row date (default: today)")
    args = ap.parse_args(argv)

    sha = (args.sha or _git_sha())[:12]
    date = args.date or datetime.date.today().isoformat()

    lines = (args.trend.read_text().rstrip("\n").split("\n")
             if args.trend.exists() else list(HEADER))
    if not any(l.startswith("| date ") for l in lines):
        lines = list(HEADER) + [l for l in lines if l.startswith("| ")]

    appended = replaced = 0
    for path in args.artifacts:
        if not path.exists():
            print(f"[trend] skip missing {path}", file=sys.stderr)
            continue
        name = path.stem
        try:
            payload = json.loads(path.read_text())
        except json.JSONDecodeError as exc:
            print(f"[trend] skip unparseable {path}: {exc}", file=sys.stderr)
            continue
        row = f"| {date} | {sha} | {name} | {summarize(name, payload)} |"
        key = f"| {sha} | {name} |"
        hit = [i for i, l in enumerate(lines) if key in l]
        if hit:
            lines[hit[0]] = row
            replaced += 1
        else:
            lines.append(row)
            appended += 1
        print(f"[trend] {row}")

    args.trend.write_text("\n".join(lines) + "\n")
    print(f"[trend] {args.trend}: +{appended} rows, {replaced} replaced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
