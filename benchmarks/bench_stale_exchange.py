"""§Perf evidence: stale-label exchange (exchange_every=k) quality trade-off.

Runs the distributed engine on 8 virtual devices (subprocess) over a
planted-partition graph and reports modularity + disconnected fraction for
k = 1 (paper-faithful), 2, 4.  Volume scales 1/k by construction (§Perf
cell 1); this benchmark quantifies the quality side of the trade.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

REPO = Path(__file__).resolve().parents[1]

_CHILD = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import modularity, disconnected_fraction
from repro.core.distributed import distributed_gsl_lpa
from repro.graphgen import planted_partition

from repro.parallel.compat import make_mesh
mesh = make_mesh((8,), ("data",))
g, truth = planted_partition(20, 100, p_in=0.2, p_out=0.001, seed=9)
out = {}
for k in (1, 2, 4):
    labels, it, sit = distributed_gsl_lpa(g, mesh, exchange_every=k)
    lab = jnp.asarray(labels)
    out[str(k)] = {
        "Q": float(modularity(g, lab)),
        "disc": float(disconnected_fraction(g, lab)),
        "iters": it,
        "allgathers_per_iter": 2.0 / k,
    }
print("RESULT" + json.dumps(out))
"""


def run(quiet: bool = False) -> list[dict]:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=560)
    rows = []
    if proc.returncode != 0:
        rows.append({"bench": "error", "seconds": -1.0,
                     "error": proc.stderr.strip()[-200:]})
    else:
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT")][0]
        res = json.loads(line[len("RESULT"):])
        for k, r in res.items():
            rows.append({
                "bench": f"exchange_every_{k}", "seconds": 0.0,
                "Q": round(r["Q"], 4), "disc_frac": round(r["disc"], 5),
                "iters": r["iters"],
                "label_allgathers_per_iter": r["allgathers_per_iter"],
            })
    if not quiet:
        emit(rows, "stale_exchange")
    return rows


if __name__ == "__main__":
    run()
