"""Observability overhead gate: profile="convergence" vs profile="off".

The convergence profiler records per-sub-sweep (active, changed, sweep)
rows into a preallocated device buffer inside the jitted while-loop and
fetches them once after convergence — by construction it must not add
host syncs to the hot loop (R001 stays clean).  This benchmark turns
that design claim into a number and a CI assert:

  * the same store-cached ~1M-directed-edge RMAT graph as the ooc bench
    (shared CSR-store CI cache key) is fit in-core with ``profile="off"``
    and ``profile="convergence"`` on separately compiled plans;
  * timings interleave the two modes round-robin and take the per-mode
    minimum, so drift on a noisy shared runner cancels instead of
    landing on whichever mode ran last;
  * asserted: labels bit-identical across modes, the profile actually
    materialises (2 sub-sweeps per iteration), and min-time overhead
    <= OVERHEAD_LIMIT (5%).

A ``profile="full"`` row rides along unasserted for trend visibility
(it adds the split-phase buffer, still device-side).

    PYTHONPATH=src python benchmarks/bench_obs_overhead.py [BENCH_obs_overhead.json]
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from bench_ooc_partition import STORE_KEY, ensure_store_entry
from common import emit

from repro.engine import CompileCache, Engine, EngineConfig
from repro.io.store import CsrStore

BACKEND = "segment"
SPLIT = "lp"
REPEATS = 5
OVERHEAD_LIMIT = 0.05   # the acceptance bar: <= 5% for "convergence"


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_obs_overhead.json"
    store = CsrStore(os.environ.get("REPRO_GRAPH_CACHE"))
    ensure_store_entry(store)
    graph, _meta = store.load(STORE_KEY)

    base = EngineConfig(backend=BACKEND, split=SPLIT)
    modes = ("off", "convergence", "full")
    engines = {m: Engine(dataclasses.replace(base, profile=m),
                         cache=CompileCache())
               for m in modes}

    # warm-up: trace + compile each mode's plan (profile joins algo_key,
    # so each mode is its own executable)
    results = {m: engines[m].fit(graph) for m in modes}
    n = graph.n
    print(f"[bench-obs] n={n} directed_edges={graph.num_edges} "
          f"backend={BACKEND} split={SPLIT} repeats={REPEATS}")

    # interleaved timing: one round = one fit per mode
    times: dict[str, list[float]] = {m: [] for m in modes}
    for _ in range(REPEATS):
        for m in modes:
            t0 = time.perf_counter()
            results[m] = engines[m].fit(graph)
            times[m].append(time.perf_counter() - t0)
    best = {m: min(times[m]) for m in modes}

    # parity + profile-materialisation gates
    ref = results["off"]
    for m in ("convergence", "full"):
        r = results[m]
        assert np.array_equal(r.labels, ref.labels), \
            f"profile={m} changed labels"
        assert r.lpa_iterations == ref.lpa_iterations, \
            f"profile={m} changed iteration count"
        assert r.profile is not None and \
            r.profile.propagation.num_sub_sweeps == 2 * r.lpa_iterations, m
    assert ref.profile is None, 'profile="off" must attach nothing'

    overhead = best["convergence"] / best["off"] - 1.0
    overhead_full = best["full"] / best["off"] - 1.0
    print(f"[bench-obs] off={best['off']:.4f}s "
          f"convergence={best['convergence']:.4f}s "
          f"({overhead:+.2%}) full={best['full']:.4f}s "
          f"({overhead_full:+.2%})")
    assert overhead <= OVERHEAD_LIMIT, (
        f'profile="convergence" overhead {overhead:.2%} exceeds '
        f"{OVERHEAD_LIMIT:.0%} (off={best['off']:.4f}s, "
        f"convergence={best['convergence']:.4f}s)")

    m_edges = graph.num_edges
    rows = [
        {"bench": f"fit_profile_{m}", "mode": m, "seconds": best[m],
         "backend": BACKEND, "split": SPLIT, "n": n, "edges": m_edges,
         "edges_per_s": round(m_edges / best[m], 1),
         "lpa_iterations": results[m].lpa_iterations,
         "overhead_vs_off_pct": round(
             (best[m] / best["off"] - 1.0) * 100, 2),
         "overhead_limit_pct": OVERHEAD_LIMIT * 100}
        for m in modes
    ]
    emit(rows, "obs_overhead")
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=2)
    print(f"[bench-obs] wrote {out_path}")


if __name__ == "__main__":
    main()
