"""Paper Figure 5: phase split — label-propagation vs splitting runtime.

Paper: 47% propagation / 53% splitting on average (SL-BFS on CPU).
Ours uses SL-LP on the TPU path; the split phase is proportionally cheaper
because min-label sweeps reuse the same vectorised machinery.
"""
from __future__ import annotations

from benchmarks.common import emit, fit_graph, suite


def run(quiet: bool = False) -> list[dict]:
    rows = []
    tot_lpa = tot_split = 0.0
    for gname, (g, desc) in suite().items():
        fit_graph(g)                  # warmup (engine compiles the bucket)
        res = fit_graph(g)            # warm: pure phase timings
        tot = max(res.lpa_seconds + res.split_seconds, 1e-9)
        tot_lpa += res.lpa_seconds
        tot_split += res.split_seconds
        rows.append({
            "bench": gname, "seconds": tot,
            "lpa_frac": round(res.lpa_seconds / tot, 3),
            "split_frac": round(res.split_seconds / tot, 3),
            "lpa_iters": res.lpa_iterations,
            "split_iters": res.split_iterations,
        })
    s = max(tot_lpa + tot_split, 1e-9)
    rows.append({"bench": "mean", "seconds": s,
                 "lpa_frac": round(tot_lpa / s, 3),
                 "split_frac": round(tot_split / s, 3)})
    if not quiet:
        emit(rows, "fig5_phase_split")
    return rows


if __name__ == "__main__":
    run()
