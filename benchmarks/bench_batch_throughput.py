"""Batched-dispatch throughput: per-dispatch vs fit_many edges/s.

A stream of small graphs (the traffic regime where per-launch overhead
dominates — Sahu, arXiv:2301.09125) is pushed through one Engine two
ways: one ``fit`` dispatch per graph (the PR-1 serving path) and
``fit_many`` in batches of 4 and 16.  Reports aggregate edges/s per
mode; the acceptance bar is batched edges/s strictly above the
per-dispatch baseline at batch size >= 4.

    PYTHONPATH=src python benchmarks/bench_batch_throughput.py [out.json]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import emit

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi

# Small-graph mixes: the dispatch-bound regime.  Besides launch overhead,
# solo dispatches pay the bucket *floors* (min 256 vertices / 2048 edge
# slots per graph); packing shares one floor across the whole batch.  The
# tile mix is smaller still so a 16-graph pack stays inside one 256-row
# tile bucket — its CPU-oracle kernel is O(rows * d^2), so row-floor
# amortisation (not launch count) is where batching pays off that path.
MIXES = {"segment": ((48, 64, 96), 4.0), "tile": ((12, 16, 24), 3.0)}
STREAM = 16
BATCH_SIZES = (1, 4, 16)
REPEATS = 3


def make_mix(backend: str):
    sizes, deg = MIXES[backend]
    return [erdos_renyi(sizes[i % len(sizes)], deg, seed=300 + i)
            for i in range(STREAM)]


def run_stream(eng, graphs, batch_size: int) -> float:
    """Median wall seconds to serve the stream in `batch_size` chunks."""
    def once():
        t0 = time.perf_counter()
        if batch_size == 1:
            for g in graphs:
                eng.fit(g)
        else:
            for i in range(0, len(graphs), batch_size):
                eng.fit_many(graphs[i:i + batch_size])
        return time.perf_counter() - t0

    once()  # warmup: trace + compile every bucket this mode touches
    times = sorted(once() for _ in range(REPEATS))
    return times[len(times) // 2]


def bench_backend(backend: str) -> list[dict]:
    eng = Engine(EngineConfig(backend=backend), cache=CompileCache())
    graphs = make_mix(backend)
    total_edges = sum(g.num_edges for g in graphs)
    sizes, _deg = MIXES[backend]

    rows = []
    baseline_eps = None
    for bs in BATCH_SIZES:
        secs = run_stream(eng, graphs, bs)
        eps = total_edges / secs
        if bs == 1:
            baseline_eps = eps
        rows.append({"bench": f"{backend}_b{bs}", "seconds": secs,
                     "backend": backend, "batch_size": bs,
                     "edges_per_s": round(eps, 1),
                     "speedup_vs_b1": round(eps / baseline_eps, 2),
                     "stream": STREAM, "sizes": "/".join(map(str, sizes))})
    return rows


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "batch_throughput.json"
    rows = []
    for backend in MIXES:
        rows.extend(bench_backend(backend))
    emit(rows, "batch_throughput")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"[bench-batch-throughput] wrote {out_path}")

    # acceptance: batching must beat per-dispatch at batch size >= 4
    for backend in MIXES:
        base = next(r for r in rows if r["backend"] == backend
                    and r["batch_size"] == 1)
        for r in rows:
            if r["backend"] == backend and r["batch_size"] >= 4:
                assert r["edges_per_s"] > base["edges_per_s"], (
                    f"{backend} batch={r['batch_size']} "
                    f"({r['edges_per_s']:.0f} edges/s) did not beat "
                    f"per-dispatch ({base['edges_per_s']:.0f} edges/s)")
    print("[bench-batch-throughput] batched > per-dispatch at bs>=4: OK")


if __name__ == "__main__":
    main()
