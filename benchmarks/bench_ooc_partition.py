"""Out-of-core partitioned detection vs in-core: parity, budget, edges/s.

A store-cached ~1M-directed-edge RMAT graph is detected twice:

  * ``in_core``  — the ordinary ``Engine.fit`` with every edge array on
    device (the baseline the paper's single-node numbers correspond to);
  * ``ooc``      — ``fit_out_of_core`` over the store entry's windowed
    mmap reads with an artificially small budget (in-core edge bytes /
    ``BUDGET_DIVISOR``), forcing a genuine partition sweep with halo
    exchange.

Asserted (the acceptance contract, also recorded in the JSON artifact):

  * labels bit-identical to the in-core fit;
  * peak resident edge bytes <= budget (the ledger's high-water mark).

The graph is written straight into the CSR store once (synthetic key —
no text parse) and reused by later runs; CI caches the store directory,
so the benchmark's steady state measures detection, not generation.

    PYTHONPATH=src python benchmarks/bench_ooc_partition.py [BENCH_ooc.json]
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import emit

from repro.engine import CompileCache, Engine, EngineConfig
from repro.io.store import CsrStore
from repro.partition.ooc import fit_out_of_core, in_core_edge_bytes
from repro.partition.slices import StoreEntrySource

SCALE = 16          # 2^16 vertices
EDGE_FACTOR = 8     # ~1M directed edges after symmetrize + dedup
SEED = 5
BACKEND = "segment"
BUDGET_DIVISOR = 8  # budget = in-core edge bytes / this
STORE_KEY = f"bench-ooc-rmat{SCALE}x{EDGE_FACTOR}-s{SEED}-v1"


def ensure_store_entry(store: CsrStore):
    """Open (or build once) the benchmark graph's store entry."""
    handle = store.open(STORE_KEY)
    if handle is None:
        from repro.graphgen import rmat
        print(f"[bench-ooc] building rmat({SCALE}, {EDGE_FACTOR}) "
              f"store entry {STORE_KEY} ...")
        graph = rmat(SCALE, EDGE_FACTOR, seed=SEED)
        store.save(STORE_KEY, graph, {
            "source": f"synthetic rmat({SCALE}, {EDGE_FACTOR}, seed={SEED})",
            "format": "synthetic", "options": "", "stats": {}})
        handle = store.open(STORE_KEY)
        assert handle is not None, "store save did not produce an entry"
    return handle


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_ooc.json"
    store = CsrStore(os.environ.get("REPRO_GRAPH_CACHE"))
    handle = ensure_store_entry(store)
    source = StoreEntrySource(handle)
    in_core_bytes = in_core_edge_bytes(source)
    budget = in_core_bytes // BUDGET_DIVISOR
    cfg = EngineConfig(backend=BACKEND, split="lp")
    print(f"[bench-ooc] n={source.n} directed_edges={source.num_edges} "
          f"in_core_edge_bytes={in_core_bytes} budget={budget}")

    # --- in-core baseline (full arrays resident) ---
    graph, _meta = store.load(STORE_KEY)
    eng = Engine(cfg, cache=CompileCache())
    eng.fit(graph)                       # warm-up: trace + compile
    t0 = time.perf_counter()
    ref = eng.fit(graph)
    t_in_core = time.perf_counter() - t0

    # --- out-of-core under the tight budget ---
    cache = CompileCache()
    run = fit_out_of_core(source, cfg, memory_budget=budget, cache=cache)
    t0 = time.perf_counter()
    run = fit_out_of_core(source, cfg, memory_budget=budget, cache=cache)
    t_ooc = time.perf_counter() - t0

    m = source.num_edges
    rows = [
        {"bench": "in_core_fit", "mode": "in_core", "seconds": t_in_core,
         "backend": BACKEND, "n": source.n, "edges": m,
         "edges_per_s": round(m / t_in_core, 1),
         "resident_edge_bytes": in_core_bytes},
        {"bench": "ooc_fit", "mode": "ooc", "seconds": t_ooc,
         "backend": run.backend, "n": source.n, "edges": m,
         "edges_per_s": round(m / t_ooc, 1),
         "budget": budget,
         "peak_resident_bytes": run.peak_resident_bytes,
         "budget_utilization": round(run.peak_resident_bytes / budget, 3),
         "partitions": run.num_partitions,
         "partition_loads": run.partition_loads,
         "halo_vertices": run.halo_vertices,
         "exchange_bytes": run.exchange_bytes,
         "lpa_iterations": run.lpa_iterations,
         "split_iterations": run.split_iterations,
         "fused": run.fused,
         "prefetches": run.prefetches,
         "prefetch_hits": run.prefetch_hits,
         "halo_cache_hits": run.halo_cache_hits,
         "halo_cache_bytes_saved": run.halo_cache_bytes_saved,
         "slowdown_vs_in_core": round(t_ooc / t_in_core, 2)},
    ]
    emit(rows, "ooc_partition")
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=2)
    print(f"[bench-ooc] wrote {out_path}")

    # --- acceptance: parity + budget ---
    ooc_labels = np.unique(run.labels, return_inverse=True)[1]
    assert np.array_equal(ref.labels, ooc_labels.astype(np.int32)), \
        "out-of-core labels diverge from the in-core fit"
    print(f"[bench-ooc] labels bit-identical to in-core "
          f"({ref.num_communities} communities): OK")
    assert run.peak_resident_bytes <= budget, (
        f"peak resident edge bytes {run.peak_resident_bytes} exceeded the "
        f"{budget}-byte budget")
    print(f"[bench-ooc] peak resident {run.peak_resident_bytes} <= budget "
          f"{budget} across {run.num_partitions} partitions: OK "
          f"({run.partition_loads} partition loads, "
          f"{run.exchange_bytes / 1e6:.1f}MB halo-label exchange)")


if __name__ == "__main__":
    main()
