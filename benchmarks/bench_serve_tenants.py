"""Multi-tenant serving SLO harness: K tenants over one shared engine.

Drives K simulated tenants (each an evolving graph: cold register, then
mixed warm-delta / cold-refresh rounds) from concurrent client threads
through one :class:`repro.serve.TenantService` — one Engine, one
micro-batcher, bounded admission with per-tenant round-robin fairness —
and reports the SLO surface: sustained aggregate edges/s, p50/p99
request latency, queue depth, rejection rate, warm-memory peak.

The shared engine runs with ``quality="full"``, so every served fit
feeds the per-tenant health timelines — the headline run also asserts
the paper's invariant end to end: disconnected-community fraction 0.0
on every tenant's latest sample.

Four phases, each asserted (JSON artifact joins the bench-trend file):

  * ``slo_load``  — the headline K-tenant run.  Hard liveness bar: zero
    stranded requests (every admitted request resolves), zero failures,
    zero client give-ups; warm-cache bytes never exceed the configured
    budget (the shared ledger's peak is the proof); every tenant's
    quality timeline reads disconnected fraction 0.0.
  * ``spill_pressure`` — same traffic, warm budget sized below the
    tenant set: least-recently-served tenants' warm labels must spill
    (cold-but-correct next update) instead of busting the budget.
  * ``restore_warm`` — snapshot the tenant set, "restart" onto a fresh
    engine, restore, apply one more delta per tenant: restored-warm
    iteration counts must come in strictly under cold re-detection.
  * ``metrics_endpoint`` — scrape a live :class:`repro.obs.MetricsServer`
    during a tenant load and run the strict text-format parser over the
    response: the health disconnected-fraction gauge must read 0.0 and
    the latency histograms must carry exemplar span ids.

    PYTHONPATH=src python benchmarks/bench_serve_tenants.py [out.json]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import emit

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import evolving_sequence
from repro.serve import ServiceConfig, TenantService
from repro.serve.loadgen import LoadConfig, build_traces, run_load

TENANTS = 32
ROUNDS = 3
SIZE = 120
AVG_DEGREE = 5.0
DELTA_EDGES = 4
CLIENT_THREADS = 8
QUEUE_CAPACITY = 16       # < tenants: register bursts exercise rejection
WARM_BUDGET = "64KB"      # generous: the slo_load run must never spill
BACKEND = "segment"


def _service(engine, **over) -> TenantService:
    kw = dict(queue_capacity=QUEUE_CAPACITY, warm_budget=WARM_BUDGET,
              max_batch=8, batch_timeout_ms=2.0, retry_after_s=0.002)
    kw.update(over)
    return TenantService(engine, ServiceConfig(**kw))


def bench_slo_load(engine) -> list[dict]:
    cfg = LoadConfig(tenants=TENANTS, rounds=ROUNDS, size=SIZE,
                     avg_degree=AVG_DEGREE, delta_edges=DELTA_EDGES,
                     refresh_every=3, parity_tenants=4,
                     client_threads=CLIENT_THREADS, seed=0)
    # warm-up sweep compiles the batch plans this traffic shape touches,
    # so the timed run measures serving, not tracing
    warm_cfg = dataclasses.replace(cfg, tenants=8, seed=1000)
    svc = _service(engine)
    run_load(svc, build_traces(warm_cfg), warm_cfg)
    svc.close()

    svc = _service(engine)
    try:
        _records, s = run_load(svc, build_traces(cfg), cfg)
        health = svc.stats()["health"]
    finally:
        svc.close()

    assert s["stranded"] == 0, (
        f"{s['stranded']} admitted requests never resolved")
    assert s["failed"] == 0 and s["errors"] == 0, (
        f"{s['failed']} failed / {s['errors']} errored requests")
    assert s["give_ups"] == 0, (
        f"{s['give_ups']} clients gave up under backpressure")
    assert s["warm_bytes_peak"] <= s["warm_budget"], (
        f"warm ledger peaked at {s['warm_bytes_peak']}B over the "
        f"{s['warm_budget']}B budget")
    assert s["spills"] == 0, "headline run is sized to never spill"
    # the paper's invariant, live across all K tenants' served fits
    assert len(health["tenants"]) == TENANTS, health.keys()
    worst_disc = max(t["last"]["disconnected_fraction"]
                     for t in health["tenants"].values())
    assert worst_disc == 0.0, (
        f"disconnected-community fraction {worst_disc} != 0.0 across "
        f"the {TENANTS}-tenant harness")
    assert "disconnected" not in health["alert_counts"], health
    print(f"[bench-serve-tenants] {s['tenants']} tenants x "
          f"{1 + s['rounds']} requests: {s['edges_per_s']:.0f} edges/s, "
          f"p50 {s['p50_ms']:.1f}ms p99 {s['p99_ms']:.1f}ms, "
          f"rejection rate {s['rejection_rate']:.1%}, 0 stranded, "
          f"disconnected 0.0 on {len(health['tenants'])} timelines: OK")
    return [{
        "bench": "slo_load", "seconds": s["wall_s"],
        "tenants": s["tenants"], "requests": s["requests"],
        "client_threads": CLIENT_THREADS, "backend": BACKEND,
        "edges_per_s": round(s["edges_per_s"], 1),
        "p50_ms": round(s["p50_ms"], 3), "p99_ms": round(s["p99_ms"], 3),
        "rejection_rate": round(s["rejection_rate"], 4),
        "retries": s["retries"],
        "queue_depth_peak": s["queue_depth_peak"],
        "queue_depth_mean": round(s["queue_depth_mean"], 2),
        "mean_batch": round(s["mean_batch"], 2),
        "stranded": s["stranded"], "failed": s["failed"],
        "warm_bytes_peak": s["warm_bytes_peak"],
        "warm_budget": s["warm_budget"],
        "health_tenants": len(health["tenants"]),
        "worst_disconnected_fraction": worst_disc,
    }]


def bench_spill_pressure(engine) -> list[dict]:
    cfg = LoadConfig(tenants=12, rounds=2, size=SIZE,
                     avg_degree=AVG_DEGREE, delta_edges=DELTA_EDGES,
                     refresh_every=0, parity_tenants=0,
                     client_threads=4, seed=50)
    # int32 labels are ~SIZE*4 B per tenant; budget ~half the tenant set
    budget = 6 * SIZE * 4
    svc = _service(engine, warm_budget=budget)
    try:
        _records, s = run_load(svc, build_traces(cfg), cfg)
    finally:
        svc.close()
    assert s["stranded"] == 0 and s["failed"] == 0
    assert s["spills"] > 0, (
        f"budget {budget}B over {cfg.tenants} tenants produced no spills")
    assert s["warm_bytes_peak"] <= budget, (
        f"spilling still peaked {s['warm_bytes_peak']}B over {budget}B")
    print(f"[bench-serve-tenants] spill pressure: {s['spills']} spills "
          f"kept peak {s['warm_bytes_peak']}B <= {budget}B budget: OK")
    return [{
        "bench": "spill_pressure", "seconds": s["wall_s"],
        "tenants": cfg.tenants, "spills": s["spills"],
        "warm_bytes_peak": s["warm_bytes_peak"], "warm_budget": budget,
        "stranded": s["stranded"],
    }]


def bench_restore_warm(engine) -> list[dict]:
    """Snapshot -> restart -> restore: tenants resume warm, and the
    first post-restore update is strictly cheaper than re-detecting."""
    from repro.checkpoint import CheckpointManager

    tenants = 8
    traces = {f"t{i:02d}": evolving_sequence(SIZE, AVG_DEGREE, 3,
                                             DELTA_EDGES, seed=900 + i)
              for i in range(tenants)}
    svc = _service(engine)
    with svc:
        for t, (base, _) in traces.items():
            svc.register(t, base).result()
        for r in range(2):
            tickets = [svc.update(t, ds[r]) for t, (_, ds) in traces.items()]
            for tk in tickets:
                tk.result()
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(Path(tmp) / "ckpt")
            svc.snapshot(mgr)
            graphs = {t: svc.graph(t) for t in traces}

            # restart: fresh engine AND fresh compile cache — nothing
            # warm survives except what the checkpoint carries
            engine2 = Engine(EngineConfig(backend=BACKEND),
                             cache=CompileCache())
            svc2 = _service(engine2)
            t0 = time.perf_counter()
            report = svc2.restore(mgr, graphs)
            restore_s = time.perf_counter() - t0
    assert len(report["restored"]) == tenants, report

    warm_iters = cold_iters = 0
    cold_eng = Engine(EngineConfig(backend=BACKEND), cache=CompileCache())
    with svc2:
        for t, (_, ds) in traces.items():
            res = svc2.update(t, ds[2]).result()
            assert res.warm_started, t
            warm_iters += res.lpa_iterations
            cold_iters += cold_eng.fit(svc2.graph(t)).lpa_iterations
    assert warm_iters < cold_iters, (
        f"restored-warm updates took {warm_iters} LPA iterations vs "
        f"{cold_iters} for cold re-detection — restore bought nothing")
    print(f"[bench-serve-tenants] restore: {len(report['restored'])} "
          f"tenants warm in {restore_s * 1e3:.1f}ms; next updates "
          f"{warm_iters} vs {cold_iters} cold LPA iterations: OK")
    return [{
        "bench": "restore_warm", "seconds": restore_s,
        "tenants": tenants, "restored": len(report["restored"]),
        "warm_iters": warm_iters, "cold_iters": cold_iters,
        "iter_ratio": round(warm_iters / max(cold_iters, 1), 3),
    }]


def bench_metrics_endpoint(engine) -> list[dict]:
    """Scrape a live exporter mid-load and gate on the strict parser:
    the exposition must parse, the health disconnected-fraction gauge
    must read 0.0, and latency histograms must carry exemplar span ids
    linking slow buckets back to their trace spans."""
    import urllib.request

    from repro.obs import MetricsServer, parse_prometheus_text

    cfg = LoadConfig(tenants=8, rounds=2, size=SIZE,
                     avg_degree=AVG_DEGREE, delta_edges=DELTA_EDGES,
                     refresh_every=0, parity_tenants=0,
                     client_threads=4, seed=77)
    svc = _service(engine)
    t0 = time.perf_counter()
    with MetricsServer(port=0) as srv:      # exports the global registry
        try:
            _records, s = run_load(svc, build_traces(cfg), cfg)
            with urllib.request.urlopen(srv.url + "/metrics",
                                        timeout=30) as resp:
                assert resp.headers.get("Content-Type",
                                        "").startswith("text/plain")
                text = resp.read().decode()
        finally:
            svc.close()
    scrape_s = time.perf_counter() - t0
    assert s["stranded"] == 0 and s["failed"] == 0

    parsed = parse_prometheus_text(text)    # raises on any grammar drift
    disc = [samples for name, samples in parsed.items()
            if name.endswith("health_disconnected_fraction")]
    assert disc, "health disconnected-fraction gauge missing from scrape"
    assert all(smp["value"] == 0.0 for samples in disc for smp in samples)
    exemplars = [smp["exemplar"]
                 for name, samples in parsed.items()
                 if name.endswith("latency_ms_bucket")
                 for smp in samples if smp["exemplar"] is not None]
    assert exemplars, "no exemplars on any latency histogram bucket"
    assert all("span_id" in ex["labels"] and int(ex["labels"]["span_id"]) > 0
               for ex in exemplars)
    print(f"[bench-serve-tenants] metrics endpoint: {len(parsed)} metric "
          f"families parsed, disconnected 0.0, {len(exemplars)} latency "
          f"exemplars with span ids: OK")
    return [{
        "bench": "metrics_endpoint", "seconds": scrape_s,
        "tenants": cfg.tenants, "metric_families": len(parsed),
        "latency_exemplars": len(exemplars),
        "worst_disconnected_fraction": 0.0,
    }]


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "serve_tenants.json"
    # full quality telemetry on the shared engine: the harness doubles as
    # the live end-to-end check of the paper's no-disconnected invariant
    engine = Engine(EngineConfig(backend=BACKEND, quality="full"),
                    cache=CompileCache())
    rows = bench_slo_load(engine)
    rows += bench_spill_pressure(engine)
    rows += bench_restore_warm(engine)
    rows += bench_metrics_endpoint(engine)
    emit(rows, "serve_tenants")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"[bench-serve-tenants] wrote {out_path}")


if __name__ == "__main__":
    main()
