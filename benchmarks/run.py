"""Benchmark harness entrypoint — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--skip-scaling`` avoids
the subprocess-based strong-scaling benchmark (used under pytest).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-scaling", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark subset")
    args = ap.parse_args()

    from benchmarks import (
        bench_fig3_split_techniques,
        bench_fig4_baselines,
        bench_fig5_phase_split,
        bench_fig6_scaling,
        bench_fig7_gve_vs_gsl,
        bench_roofline,
        bench_stale_exchange,
        bench_table1_datasets,
    )

    benches = {
        "table1": bench_table1_datasets.run,
        "fig3": bench_fig3_split_techniques.run,
        "fig4": bench_fig4_baselines.run,
        "fig5": bench_fig5_phase_split.run,
        "fig7": bench_fig7_gve_vs_gsl.run,
        "roofline": bench_roofline.run,
    }
    if not args.skip_scaling:
        benches["fig6"] = bench_fig6_scaling.run
        benches["stale"] = bench_stale_exchange.run
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    t0 = time.time()
    print("bench,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},0.0,ERROR={e!r}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s; failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
