"""Paper Figure 3: Split-Last technique comparison (LP vs LPP vs BFS vs
default) — relative runtime, modularity, fraction of disconnected
communities."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import disconnected_fraction, gsl_lpa, modularity
from benchmarks.common import emit, suite


def run(quiet: bool = False) -> list[dict]:
    rows = []
    for gname, (g, desc) in suite().items():
        base = None
        for split in ("none", "lp", "lpp", "bfs_host"):
            gsl_lpa(g, split=split)          # warmup (jit compile)
            res = gsl_lpa(g, split=split)
            t = res.total_seconds
            if split == "none":
                base = t
            rows.append({
                "bench": f"{gname}/{split}",
                "seconds": t,
                "rel_runtime": round(t / max(base, 1e-9), 3),
                "split_seconds": round(res.split_seconds, 4),
                "Q": round(float(modularity(g, jnp.asarray(res.labels))), 4),
                "disc_frac": round(float(disconnected_fraction(
                    g, jnp.asarray(res.labels))), 5),
            })
    if not quiet:
        emit(rows, "fig3_split_techniques")
    return rows


if __name__ == "__main__":
    run()
