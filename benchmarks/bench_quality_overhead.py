"""Quality-telemetry overhead gate: quality="basic" vs quality="off".

``EngineConfig.quality`` is post-fit instrumentation: it reads the
converged labels once per fit and never touches the sweep loop —
``quality`` is deliberately absent from ``algo_key``, so all modes share
one compiled executable.  "basic" is host-only (bincount sizes + churn);
only "full" pays per-fit device passes (modularity ~ one extra sweep,
plus the connectivity check).  This benchmark turns those design claims
into numbers and a CI assert:

  * the same store-cached ~1M-directed-edge RMAT graph as the ooc bench
    (shared CSR-store CI cache key) is fit in-core with ``quality="off"``
    and ``quality="basic"``;
  * timings interleave the modes round-robin and take the per-mode
    minimum, so drift on a noisy shared runner cancels instead of
    landing on whichever mode ran last;
  * asserted: labels + iteration counts bit-identical across modes,
    the basic report actually materialises (community count matches),
    and min-time overhead <= OVERHEAD_LIMIT (5%).

A ``quality="full"`` row rides along unasserted-for-time (it adds the
modularity + connectivity passes) but hard-asserts the paper's headline
invariant: disconnected-community fraction exactly 0.0 at 1M-edge scale.

    PYTHONPATH=src python benchmarks/bench_quality_overhead.py [BENCH_quality.json]
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from bench_ooc_partition import STORE_KEY, ensure_store_entry
from common import emit

from repro.engine import CompileCache, Engine, EngineConfig
from repro.io.store import CsrStore

BACKEND = "segment"
SPLIT = "lp"
REPEATS = 5
OVERHEAD_LIMIT = 0.05   # the acceptance bar: <= 5% for "basic"


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_quality.json"
    store = CsrStore(os.environ.get("REPRO_GRAPH_CACHE"))
    ensure_store_entry(store)
    graph, _meta = store.load(STORE_KEY)

    base = EngineConfig(backend=BACKEND, split=SPLIT)
    modes = ("off", "basic", "full")
    # one shared compile cache: quality is not in algo_key, so every
    # mode must hit the same executable (part of what the gate measures)
    cache = CompileCache()
    engines = {m: Engine(dataclasses.replace(base, quality=m), cache=cache)
               for m in modes}

    # warm-up: trace + compile once; later fits are steady-state
    results = {m: engines[m].fit(graph) for m in modes}
    n = graph.n
    print(f"[bench-quality] n={n} directed_edges={graph.num_edges} "
          f"backend={BACKEND} split={SPLIT} repeats={REPEATS}")

    # interleaved timing: one round = one fit per mode
    times: dict[str, list[float]] = {m: [] for m in modes}
    for _ in range(REPEATS):
        for m in modes:
            t0 = time.perf_counter()
            results[m] = engines[m].fit(graph)
            times[m].append(time.perf_counter() - t0)
    best = {m: min(times[m]) for m in modes}

    # parity + report-materialisation gates
    ref = results["off"]
    for m in ("basic", "full"):
        r = results[m]
        assert np.array_equal(r.labels, ref.labels), \
            f"quality={m} changed labels"
        assert r.lpa_iterations == ref.lpa_iterations, \
            f"quality={m} changed iteration count"
        assert r.quality is not None and \
            r.quality.num_communities == r.num_communities, m
    assert ref.quality is None, 'quality="off" must attach nothing'
    assert results["basic"].quality.modularity is None, \
        'quality="basic" must stay host-only (no modularity pass)'
    assert results["full"].quality.modularity is not None
    # the headline invariant, asserted at scale through the full report
    disc = results["full"].quality.disconnected_fraction
    assert disc == 0.0, (
        f"disconnected-community fraction {disc} != 0.0 on the 1M-edge "
        f"graph — the paper's invariant broke")

    overhead = best["basic"] / best["off"] - 1.0
    overhead_full = best["full"] / best["off"] - 1.0
    print(f"[bench-quality] off={best['off']:.4f}s "
          f"basic={best['basic']:.4f}s ({overhead:+.2%}) "
          f"full={best['full']:.4f}s ({overhead_full:+.2%}) "
          f"Q={results['full'].quality.modularity:.4f} disconnected={disc}")
    assert overhead <= OVERHEAD_LIMIT, (
        f'quality="basic" overhead {overhead:.2%} exceeds '
        f"{OVERHEAD_LIMIT:.0%} (off={best['off']:.4f}s, "
        f"basic={best['basic']:.4f}s)")

    m_edges = graph.num_edges
    rows = [
        {"bench": f"fit_quality_{m}", "mode": m, "seconds": best[m],
         "backend": BACKEND, "split": SPLIT, "n": n, "edges": m_edges,
         "edges_per_s": round(m_edges / best[m], 1),
         "lpa_iterations": results[m].lpa_iterations,
         "communities": results[m].num_communities,
         "modularity": (round(results[m].quality.modularity, 6)
                        if results[m].quality else None),
         "disconnected_fraction": (
             results[m].quality.disconnected_fraction
             if results[m].quality else None),
         "overhead_vs_off_pct": round(
             (best[m] / best["off"] - 1.0) * 100, 2),
         "overhead_limit_pct": OVERHEAD_LIMIT * 100}
        for m in modes
    ]
    emit(rows, "quality")
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=2)
    print(f"[bench-quality] wrote {out_path}")


if __name__ == "__main__":
    main()
