"""Trace-audit bench: run the canonical engine workload under TraceAudit.

Executes :func:`repro.analysis.audit_workload` — solo cold fits,
same-bucket reuse, warm refits, batched dispatch, sharded exchange, and
out-of-core partitioned sweeps — and records the per-(stage, backend,
bucket) trace counts.  The acceptance contract (also the CI gate):

  * every (stage, backend, bucket) pair traces **at most once** across
    the whole workload — zero excess retraces;
  * the workload genuinely covered every dispatch family (solo, batch,
    warm, partition), so a silently skipped leg can't fake a pass.

Exits nonzero on any excess retrace so the CI job fails loudly.

    PYTHONPATH=src python benchmarks/bench_trace_audit.py [BENCH_trace_audit.json]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import emit

from repro.analysis import audit_workload


def main() -> int:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_trace_audit.json"
    t0 = time.perf_counter()
    audit = audit_workload()
    seconds = time.perf_counter() - t0
    report = audit.report()
    coverage = dict(getattr(audit, "coverage", {}))

    report["workload_seconds"] = round(seconds, 3)
    report["coverage"] = coverage
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")

    emit([{"bench": "workload", "seconds": seconds,
           "total_traces": report["total_traces"],
           "contexts": len(report["contexts"]),
           "excess_contexts": report["excess_contexts"],
           "ok": report["ok"]}], "trace-audit")
    for row in report["contexts"]:
        marker = "RETRACE" if row["excess"] else "ok"
        print(f"[trace-audit] {row['stage']} @ {row['bucket']}: "
              f"{row['traces']} trace(s) [{marker}]")

    if not report["ok"]:
        print(f"[trace-audit] FAIL: {report['excess_contexts']} context(s) "
              "traced more than once", file=sys.stderr)
        return 1
    print(f"[trace-audit] PASS: {report['total_traces']} traces over "
          f"{len(report['contexts'])} contexts, zero excess "
          f"({sum(coverage.values())} fits)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
