"""Streaming delta re-detection: warm batched vs cold re-detect throughput.

Evolving-graph traces (``graphgen.evolving_sequence`` — small per-round
edge churn over a stream of graphs) are replayed three ways through one
Engine per mode:

  * ``cold_solo``    — full re-detection, one solo ``fit`` per graph per
    round (the PR-1 serving pattern for evolving graphs);
  * ``cold_batched`` — full re-detection, one ``fit_many`` per round
    (batching only — isolates the dispatch-amortisation share);
  * ``warm_batched`` — one ``fit_many`` per round with per-member
    warm-start labels from the previous round and the delta's affected
    frontier seeded unprocessed (batching + incremental propagation).

Every mode fits the *same* pre-materialised post-delta graphs; delta
application and graph generation stay outside the timed regions, and a
warm-up replay compiles every plan first.  The acceptance bar (asserted,
JSON artifact in CI): warm batched re-detection strictly beats cold
per-graph re-detection on small-delta traces.

    PYTHONPATH=src python benchmarks/bench_streaming_deltas.py [out.json]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import emit

from repro.core.delta import affected_frontier, apply_delta
from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import evolving_sequence

STREAMS = 8
ROUNDS = 5
SIZE = 150
AVG_DEGREE = 5.0
DELTA_EDGES = 4
REPEATS = 3
BACKEND = "segment"


def build_traces():
    """Pre-materialise per-round post-delta graphs + frontiers."""
    traces = []
    for i in range(STREAMS):
        base, deltas = evolving_sequence(SIZE, AVG_DEGREE, ROUNDS,
                                         DELTA_EDGES, seed=100 + i)
        posts, fronts, g = [], [], base
        for d in deltas:
            g = apply_delta(g, d)
            posts.append(g)
            fronts.append(affected_frontier(d, g.n))
        traces.append({"base": base, "posts": posts, "fronts": fronts})
    return traces


def replay(eng, traces, mode: str) -> float:
    """Median wall seconds to serve ROUNDS of re-detections in `mode`."""
    def once() -> float:
        prev = {i: eng.fit_many([t["base"] for t in traces])[i].labels
                for i in range(STREAMS)} if mode == "warm_batched" else None
        t0 = time.perf_counter()
        for r in range(ROUNDS):
            posts = [t["posts"][r] for t in traces]
            if mode == "cold_solo":
                for g in posts:
                    eng.fit(g)
            elif mode == "cold_batched":
                eng.fit_many(posts)
            else:
                results = eng.fit_many(
                    posts,
                    init_labels=[prev[i] for i in range(STREAMS)],
                    init_active=[t["fronts"][r] for t in traces])
                prev = {i: res.labels for i, res in enumerate(results)}
        return time.perf_counter() - t0

    once()  # warm-up: trace + compile every plan this mode touches
    times = sorted(once() for _ in range(REPEATS))
    return times[len(times) // 2]


def bench_delta_apply() -> list[dict]:
    """Tiny deltas over a large graph: full CSR rebuild vs splice patch.

    ``apply_delta`` pays a sort + unique over all m edges per update;
    ``apply_delta_patch`` edits only the touched rows and block-copies
    the rest (bit-identical output — pinned in tests/test_delta_patch.py).
    On streaming traffic the delta application is host-side serial work
    in front of every warm re-detection, so this gap is end-to-end
    latency, not a micro-benchmark curiosity.
    """
    from repro.core.delta import GraphDelta, apply_delta, apply_delta_patch
    from repro.core.delta import undirected_edges
    from repro.graphgen import rmat

    graph = rmat(14, 8, seed=9)   # ~16k vertices, ~200k directed edges
    live, _ = undirected_edges(graph)
    rng = np.random.default_rng(0)
    deltas = [GraphDelta.make(
        insert=rng.integers(0, graph.n, size=(DELTA_EDGES, 2)),
        delete=live[rng.integers(0, len(live), size=DELTA_EDGES)])
        for _ in range(10)]

    def run(fn) -> float:
        for d in deltas[:2]:
            fn(graph, d)  # warm-up (allocator, caches)
        times = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            for d in deltas:
                fn(graph, d)
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2] / len(deltas)

    rebuild_s = run(apply_delta)
    patch_s = run(apply_delta_patch)
    rows = [
        {"bench": "delta_apply_rebuild", "mode": "rebuild",
         "seconds": rebuild_s, "n": graph.n, "edges": graph.num_edges,
         "delta_edges": DELTA_EDGES},
        {"bench": "delta_apply_patch", "mode": "patch",
         "seconds": patch_s, "n": graph.n, "edges": graph.num_edges,
         "delta_edges": DELTA_EDGES,
         "speedup_vs_rebuild": round(rebuild_s / patch_s, 2)},
    ]
    assert patch_s < rebuild_s, (
        f"CSR splice patch ({patch_s * 1e3:.2f}ms) did not beat the full "
        f"rebuild ({rebuild_s * 1e3:.2f}ms) on {DELTA_EDGES}-edge deltas "
        f"over {graph.num_edges} edges")
    print(f"[bench-streaming-deltas] splice patch beats rebuild: "
          f"{rebuild_s / patch_s:.1f}x on {DELTA_EDGES}-edge deltas over "
          f"{graph.num_edges}-edge graph: OK")
    return rows


def bench_churn_crossover() -> list[dict]:
    """Sweep delta churn to find the patch-vs-rebuild crossover.

    ``StreamSession`` routes a delta through ``apply_delta_patch`` below
    ``EngineConfig.patch_churn_threshold`` (fraction of vertices the
    delta touches) and through the full ``apply_delta`` rebuild above
    it.  This sweep measures both on the same deltas across churn
    fractions and reports the first fraction where the rebuild wins —
    the config default is set from this measurement (re-run with
    different hardware to recalibrate).
    """
    from repro.core.delta import GraphDelta, apply_delta, apply_delta_patch
    from repro.graphgen import rmat

    graph = rmat(13, 8, seed=7)   # ~8k vertices, ~100k directed edges
    rng = np.random.default_rng(1)
    fractions = (0.02, 0.05, 0.10, 0.20, 0.35, 0.50, 0.70, 0.90)

    rows, crossover = [], None
    for frac in fractions:
        touched = max(int(frac * graph.n), 2)
        deltas = [GraphDelta.make(insert=rng.choice(
            graph.n, size=(touched // 2, 2), replace=False))
            for _ in range(3)]

        def run(fn) -> float:
            fn(graph, deltas[0])  # warm-up
            times = []
            for _ in range(REPEATS):
                t0 = time.perf_counter()
                for d in deltas:
                    fn(graph, d)
                times.append(time.perf_counter() - t0)
            return sorted(times)[len(times) // 2] / len(deltas)

        rebuild_s, patch_s = run(apply_delta), run(apply_delta_patch)
        actual = float(np.mean([len(d.touched_vertices()) / graph.n
                                for d in deltas]))
        if crossover is None and patch_s > rebuild_s:
            crossover = actual
        rows.append({"bench": f"churn_{frac:.2f}", "mode": "churn_sweep",
                     "seconds": patch_s, "churn_frac": round(actual, 3),
                     "rebuild_seconds": rebuild_s,
                     "patch_speedup": round(rebuild_s / patch_s, 2)})

    measured = crossover if crossover is not None else 1.0
    rows.append({"bench": "churn_crossover", "mode": "churn_sweep",
                 "seconds": 0.0, "measured_crossover": round(measured, 3)})
    from repro.engine import EngineConfig
    print(f"[bench-streaming-deltas] patch-vs-rebuild crossover at "
          f"~{measured:.0%} churn (config default "
          f"{EngineConfig().patch_churn_threshold:.0%})")
    return rows


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "streaming_deltas.json"
    traces = build_traces()
    total_edges = sum(t["posts"][r].num_edges
                      for t in traces for r in range(ROUNDS))
    frontier_frac = float(np.mean([f.mean()
                                   for t in traces for f in t["fronts"]]))

    rows = []
    for mode in ("cold_solo", "cold_batched", "warm_batched"):
        eng = Engine(EngineConfig(backend=BACKEND), cache=CompileCache())
        secs = replay(eng, traces, mode)
        rows.append({"bench": f"streaming_{mode}", "mode": mode,
                     "seconds": secs, "backend": BACKEND,
                     "streams": STREAMS, "rounds": ROUNDS,
                     "delta_edges": DELTA_EDGES,
                     "frontier_frac": round(frontier_frac, 4),
                     "edges_per_s": round(total_edges / secs, 1)})

    base = next(r for r in rows if r["mode"] == "cold_solo")
    for r in rows:
        r["speedup_vs_cold_solo"] = round(base["seconds"] / r["seconds"], 2)

    rows += bench_delta_apply()
    rows += bench_churn_crossover()
    emit(rows, "streaming_deltas")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"[bench-streaming-deltas] wrote {out_path}")

    warm = next(r for r in rows if r["mode"] == "warm_batched")
    assert warm["seconds"] < base["seconds"], (
        f"warm batched re-detection ({warm['seconds']:.3f}s) did not beat "
        f"cold per-graph re-detection ({base['seconds']:.3f}s)")
    print(f"[bench-streaming-deltas] warm batched beats cold per-graph: "
          f"{warm['speedup_vs_cold_solo']:.1f}x on "
          f"{frontier_frac:.1%}-frontier traces: OK")


if __name__ == "__main__":
    main()
