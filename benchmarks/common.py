"""Shared benchmark infrastructure.

The paper's Table-1 suite spans 25M..3.8B edges on a 64-thread Xeon; this
container has one CPU core, so every graph class is represented by a
scaled-down synthetic analogue with matching *structure* (degree profile /
community shape).  Relative claims (technique ranking, phase split,
disconnected fractions, GVE-vs-GSL overhead) are what transfer; absolute
edges/s do not (benchmarked separately in §Perf via the dry-run roofline).
"""
from __future__ import annotations

import time
from functools import lru_cache

import jax

from repro.io import datasets


def suite():
    """name -> (graph, class) for every registered dataset.

    Backed by the :mod:`repro.io.registry` dataset registry (the five
    synthetic Table-1 analogues are built-in entries; real downloaded
    graphs registered via ``datasets.register_file`` ride along
    automatically).  Deliberately *not* lru_cached here: the registry
    is mutable and already memoizes built graphs per name, so each call
    re-lists the names cheaply and picks up late registrations.
    ``suite_stats()`` exposes the §4.1 preprocessing stats for
    file-backed entries.
    """
    return {name: (datasets.get(name), datasets.entry(name).description)
            for name in datasets.names()}


def suite_stats():
    """name -> preprocessing-stats dict (None for synthetic entries)."""
    return {name: datasets.get_with_stats(name)[1]
            for name in datasets.names()}


@lru_cache(maxsize=None)
def engine_for(backend: str = "segment", split: str = "lp",
               bucketing: str = "pow2"):
    """Shared Engine per knob combo — benchmarks reuse compiled plans."""
    from repro.engine import Engine, EngineConfig
    return Engine(EngineConfig(backend=backend, split=split,
                               bucketing=bucketing))


def fit_graph(graph, backend: str = "segment", split: str = "lp"):
    """Engine-routed detection for benchmark bodies (DetectionResult)."""
    return engine_for(backend, split).fit(graph)


def timed(fn, *args, repeats: int = 3, **kw):
    """Median wall time + last result (first call excluded = compile)."""
    fn(*args, **kw)  # warmup/compile
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else None
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def emit(rows: list[dict], name: str) -> None:
    """Print benchmark rows as the harness CSV: name,us_per_call,derived."""
    for r in rows:
        us = r.get("seconds", 0.0) * 1e6
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("seconds", "bench"))
        print(f"{name}/{r.get('bench', '')},{us:.1f},{derived}")
