"""Paper Table 1: dataset statistics + communities found by GSL-LPA.

Scaled-down synthetic analogues of the SuiteSparse classes (see
benchmarks.common.suite); reports |V|, |E| (directed, post-symmetrize),
average degree, and |Gamma| — the community count from GSL-LPA.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import gsl_lpa, modularity
from benchmarks.common import emit, suite


def run(quiet: bool = False) -> list[dict]:
    rows = []
    for gname, (g, desc) in suite().items():
        gsl_lpa(g, split="lp")               # warmup (jit compile)
        res = gsl_lpa(g, split="lp")
        ncomm = len(set(res.labels.tolist()))
        rows.append({
            "bench": gname, "seconds": res.total_seconds,
            "class": desc.split(" (")[0], "V": g.n, "E": g.num_edges,
            "davg": round(g.num_edges / g.n, 1),
            "communities": ncomm,
            "Q": round(float(modularity(g, jnp.asarray(res.labels))), 4),
        })
    if not quiet:
        emit(rows, "table1_datasets")
    return rows


if __name__ == "__main__":
    run()
