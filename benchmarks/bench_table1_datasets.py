"""Paper Table 1: dataset statistics + communities found by GSL-LPA.

Datasets resolve through the :mod:`repro.io.registry` dataset registry —
the built-in entries are scaled-down synthetic analogues of the
SuiteSparse classes; real downloaded graphs registered with
``datasets.register_file`` (or passed as file paths on the command line)
join the table automatically, including their §4.1 preprocessing columns
(raw file entries vs. cleaned undirected |E|, duplicates and self-loops
removed).  Reports |V|, |E| (directed, post-symmetrize), average degree,
and |Gamma| — the community count from GSL-LPA.

    PYTHONPATH=src python benchmarks/bench_table1_datasets.py [file.mtx ...]
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax.numpy as jnp

from repro.core import gsl_lpa, modularity
from repro.io import datasets
from common import emit


def run(quiet: bool = False) -> list[dict]:
    rows = []
    for name in datasets.names():
        g, stats = datasets.get_with_stats(name)
        desc = datasets.entry(name).description
        gsl_lpa(g, split="lp")               # warmup (jit compile)
        res = gsl_lpa(g, split="lp")
        ncomm = len(set(res.labels.tolist()))
        # Preprocessing columns: synthetic generators emit clean edge
        # lists, so raw == cleaned for them by construction.
        raw_e = stats["raw_edges"] if stats else g.num_edges // 2
        cleaned_e = stats["edges"] if stats else g.num_edges // 2
        rows.append({
            "bench": name, "seconds": res.total_seconds,
            "class": (desc or name).split(" (")[0],
            "V": g.n, "E": g.num_edges,
            "E_raw": raw_e, "E_clean": cleaned_e,
            "loops_dropped": stats["self_loops"] if stats else 0,
            "dups_dropped": stats["duplicates"] if stats else 0,
            "davg": round(g.num_edges / max(g.n, 1), 1),
            "communities": ncomm,
            "Q": round(float(modularity(g, jnp.asarray(res.labels))), 4),
        })
    if not quiet:
        emit(rows, "table1_datasets")
    return rows


def main() -> None:
    # file paths on the command line join the table as registry entries
    for arg in sys.argv[1:]:
        datasets.register_file(Path(arg).stem, arg,
                               description=f"file ({Path(arg).name})",
                               overwrite=True)
    run()


if __name__ == "__main__":
    main()
