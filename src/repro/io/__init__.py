"""Real-graph ingestion: parse -> preprocess -> build -> cache.

The vertical slice that feeds real SuiteSparse/SNAP graphs to the
engine (the paper's entire evaluation corpus is such files):

  * :mod:`repro.io.formats`     chunked MatrixMarket / SNAP parsers +
    writers — multi-GB files stream in fixed-size blocks.
  * :mod:`repro.io.preprocess`  the paper's §4.1 cleaning pipeline
    (canonicalize, de-loop, dedup, unit weights, optional LCC/compact)
    with before/after stats.
  * :mod:`repro.io.store`       content-hash-keyed on-disk CSR cache;
    :func:`load_graph` is the parse-once/load-forever entry point.
  * :mod:`repro.io.registry`    named datasets (``datasets.get(name)``)
    — synthetic built-ins + registered files behind one lookup.
"""
from repro.io import registry as datasets  # noqa: F401
from repro.io.formats import (  # noqa: F401
    EdgeList,
    FormatError,
    open_graph_bytes,
    parse_edge_file,
    parse_mtx,
    parse_snap,
    sniff_format,
    write_mtx,
    write_snap,
)
from repro.io.preprocess import (  # noqa: F401
    PreprocessOptions,
    PreprocessStats,
    connected_components,
    preprocess,
)
from repro.io.store import (  # noqa: F401
    CsrStore,
    EntryHandle,
    IngestReport,
    default_cache_dir,
    file_content_hash,
    load_graph,
    open_graph,
)
