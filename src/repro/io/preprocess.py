"""The paper's §4.1 preprocessing pipeline as composable host passes.

Every graph in the paper's evaluation corpus is made undirected,
unit-weighted, and loop/duplicate-free before detection: *"we ensure
all edges are undirected and weighted with a weight of 1"*.  This
module expresses that as a sequence of pure numpy passes over a raw
:class:`repro.io.formats.EdgeList`:

  ``canonicalize``       (u, v) -> (min, max): an undirected edge has one
                         identity regardless of storage direction.
  ``drop_self_loops``    remove u == u rows (``scanCommunities``
                         excludes i == j; ``build_graph`` would drop
                         them anyway, but dropping here makes the
                         stats report them).
  ``dedup``              collapse duplicate undirected edges, keeping
                         the **max** weight — the SuiteSparse corpus
                         stores some matrices with both triangles or
                         repeated entries; max (not sum) keeps a
                         re-stored edge from doubling its weight.
  ``unit_weights``       drop weights entirely (paper default).
  ``largest_component``  optional: restrict to the largest connected
                         component (some corpora evaluate on the LCC).
  ``compact_ids``        optional: dense-relabel the vertex ids that
                         actually appear (SNAP files often have sparse
                         id spaces); implied by ``largest_component``.

:func:`preprocess` runs the passes in that order and returns the
cleaned edge list plus a :class:`PreprocessStats` with before/after
counts per pass — the raw vs. post-dedup |E| columns in the Table-1
report come straight from it.

The cleaned output feeds ``build_graph`` directly.  After ``dedup``
there are no duplicate undirected edges, so ``build_graph``'s
sum-merge of duplicates is vacuously a no-op and the resulting CSR is
bit-identical to building from a hand-cleaned list — the contract the
round-trip tests pin.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.io.formats import EdgeList


@dataclasses.dataclass(frozen=True)
class PreprocessOptions:
    """Knobs for :func:`preprocess` (all of §4.1, individually gateable).

    The defaults reproduce the paper's setup exactly: symmetrized,
    deduplicated, loop-free, unit weights, full vertex set.
    """
    drop_self_loops: bool = True
    dedup: bool = True
    unit_weights: bool = True
    largest_component: bool = False
    compact_ids: bool = False

    def cache_token(self) -> str:
        """Stable string identity for on-disk cache keys."""
        return (f"loops{int(self.drop_self_loops)}-dedup{int(self.dedup)}-"
                f"unit{int(self.unit_weights)}-"
                f"lcc{int(self.largest_component)}-"
                f"compact{int(self.compact_ids)}")


@dataclasses.dataclass
class PreprocessStats:
    """Before/after counts for each pass (the §4.1 report card)."""
    raw_edges: int = 0            # rows in the file (post storage expansion)
    raw_vertices: int = 0
    self_loops: int = 0           # rows removed as u == u
    duplicates: int = 0           # rows collapsed by dedup
    edges: int = 0                # undirected edges after cleaning
    vertices: int = 0             # vertex count after compaction (if any)
    isolated_vertices: int = 0    # ids in range that touch no edge
    component_vertices_dropped: int = 0  # LCC extraction removals
    weighted: bool = False        # cleaned list still carries weights

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def canonicalize(edges: np.ndarray) -> np.ndarray:
    """(E, 2) -> (E, 2) with u <= v per row (undirected identity)."""
    return np.stack([edges.min(axis=1), edges.max(axis=1)], axis=1)


def dedup_max_weight(edges: np.ndarray, weights: np.ndarray | None,
                     n: int) -> tuple[np.ndarray, np.ndarray | None, int]:
    """Collapse duplicate canonical edges, keeping the max weight.

    Returns (edges, weights, duplicates_removed); output is sorted by
    (u, v) — the order ``build_graph`` would sort into anyway.
    """
    if not len(edges):
        return edges, weights, 0
    key = edges[:, 0] * np.int64(n) + edges[:, 1]
    if weights is None:
        uniq = np.unique(key)
        out = np.stack([uniq // n, uniq % n], axis=1)
        return out, None, len(edges) - len(uniq)
    uniq, inv = np.unique(key, return_inverse=True)
    wmax = np.full(len(uniq), -np.inf, dtype=np.float64)
    np.maximum.at(wmax, inv, weights)
    out = np.stack([uniq // n, uniq % n], axis=1)
    return out, wmax, len(edges) - len(uniq)


def connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """(n,) component id per vertex via vectorized label shrinking.

    Pointer-jumping union over the undirected edge set: every vertex
    repeatedly adopts the minimum label in its neighborhood closure.
    O((n + E) * iterations) with numpy-level passes; iterations is the
    component diameter in the worst case but collapses fast in practice
    thanks to the path-halving jump.
    """
    labels = np.arange(n, dtype=np.int64)
    if not len(edges):
        return labels
    u, v = edges[:, 0], edges[:, 1]
    while True:
        before = labels
        # edge relaxation: both endpoints adopt the pair's min label
        m = np.minimum(labels[u], labels[v])
        labels = labels.copy()
        np.minimum.at(labels, u, m)
        np.minimum.at(labels, v, m)
        # path halving: jump each label to its label's label
        labels = labels[labels]
        if np.array_equal(labels, before):
            # fixed point: every edge has equal endpoint labels (else the
            # relaxation would have lowered one) == per-component minima
            return labels


def largest_component_mask(edges: np.ndarray, n: int) -> np.ndarray:
    """(n,) bool mask of the largest connected component's vertices.

    Isolated vertices are singleton components; ties break toward the
    smallest root id (deterministic).
    """
    comp = connected_components(edges, n)
    roots, counts = np.unique(comp, return_counts=True)
    return comp == roots[np.argmax(counts)]


def preprocess(raw: EdgeList, opts: PreprocessOptions | None = None,
               ) -> tuple[EdgeList, PreprocessStats]:
    """Run the §4.1 pipeline; returns (cleaned EdgeList, stats)."""
    opts = opts or PreprocessOptions()
    edges = np.asarray(raw.edges, dtype=np.int64).reshape(-1, 2)
    weights = None if raw.weights is None \
        else np.asarray(raw.weights, dtype=np.float64).reshape(-1)
    n = int(raw.n)
    stats = PreprocessStats(raw_edges=len(edges), raw_vertices=n)

    edges = canonicalize(edges)

    if opts.drop_self_loops:
        keep = edges[:, 0] != edges[:, 1]
        stats.self_loops = int((~keep).sum())
        edges = edges[keep]
        if weights is not None:
            weights = weights[keep]

    if opts.dedup:
        edges, weights, stats.duplicates = dedup_max_weight(edges, weights, n)

    if opts.unit_weights:
        weights = None

    def _touched(e: np.ndarray) -> np.ndarray:
        out = np.zeros(n, dtype=bool)
        if len(e):
            out[e[:, 0]] = True
            out[e[:, 1]] = True
        return out

    # Isolated count reflects the *cleaned* graph, before any LCC
    # extraction — off-LCC vertices must not re-count as "isolated"
    # just because their edges were removed (they are already reported
    # in component_vertices_dropped, which includes isolated singletons).
    touched = _touched(edges)
    stats.isolated_vertices = int(n - touched.sum())

    if opts.largest_component:
        mask = largest_component_mask(edges, n)
        stats.component_vertices_dropped = int((~mask).sum())
        keep = mask[edges[:, 0]] & mask[edges[:, 1]]
        edges = edges[keep]
        if weights is not None:
            weights = weights[keep]
        touched = _touched(edges)

    if opts.compact_ids or opts.largest_component:
        # Dense-relabel the surviving vertex ids.  After LCC extraction
        # the dropped vertices must not linger as isolated singletons —
        # they would show up as spurious size-1 communities.
        keep_ids = np.flatnonzero(touched)
        remap = -np.ones(n, dtype=np.int64)
        remap[keep_ids] = np.arange(len(keep_ids))
        edges = remap[edges]
        n = int(len(keep_ids))

    stats.edges = len(edges)
    stats.vertices = n
    stats.weighted = weights is not None
    meta = dict(raw.meta)
    meta["preprocess"] = opts.cache_token()
    return EdgeList(edges=edges, weights=weights, n=n, meta=meta), stats
