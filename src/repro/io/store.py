"""On-disk CSR cache: parse once, load forever.

Parsing a multi-GB MatrixMarket file is minutes of text tokenization;
the CSR it produces is a handful of flat arrays.  :class:`CsrStore`
persists those arrays under a key derived from the *file content hash*
plus the preprocessing options, so :func:`load_graph` pays the parse
exactly once per (file, options) pair — re-running a benchmark, a
serving process restart, or a CI job with a cache hit goes straight
from disk to a :class:`repro.core.graph.Graph`.

Layout (one directory per entry):

    <cache_dir>/<key>/meta.json      n / m_pad / num_edges / stats /
                                     fingerprint / array table / provenance
    <cache_dir>/<key>/arrays.bin     row_ptr / src / dst / wgt /
                                     edge_mask / kdeg back to back,
                                     64-byte aligned

All six arrays live in one flat binary blob that is memmapped **once**
per load and sliced into zero-copy views (offsets/dtypes/shapes from
the meta's array table).  One open + one mmap beats six ``np.load
(mmap_mode="r")`` calls by ~10x in fixed overhead, and — unlike a
zipped ``.npz``, which cannot be mmapped at all — a load never
double-buffers the arrays in host memory, which is what makes repeat
loads of multi-GB graphs effectively free.

The saved ``graph_fingerprint`` is re-attached to the loaded Graph, so
warm-start caches keyed on fingerprints (``EngineConfig.warm_start=
"auto"``) stay continuous across processes: a fit in one process and a
re-fit after restart see the same structural identity without anyone
recomputing a CRC over the edge arrays.

Writes are atomic (temp dir + ``os.replace``), so a crashed ingest
never leaves a half-written entry behind.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, build_graph
from repro.io.formats import parse_edge_file, sniff_format
from repro.io.preprocess import PreprocessOptions, preprocess

STORE_VERSION = 2  # bump to invalidate every cached entry
_ARRAYS = ("row_ptr", "src", "dst", "wgt", "edge_mask", "kdeg")
_ALIGN = 64        # per-array alignment inside arrays.bin
_HASH_BLOCK = 4 << 20


def default_cache_dir() -> Path:
    """``$REPRO_GRAPH_CACHE`` or ``~/.cache/repro/graphs``."""
    env = os.environ.get("REPRO_GRAPH_CACHE")
    if env:
        return Path(env)
    return Path(os.environ.get("XDG_CACHE_HOME",
                               Path.home() / ".cache")) / "repro" / "graphs"


def file_content_hash(path) -> str:
    """Streaming sha256 of the file bytes (hex)."""
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        while True:
            block = fh.read(_HASH_BLOCK)
            if not block:
                return h.hexdigest()
            h.update(block)


@dataclasses.dataclass
class IngestReport:
    """What :func:`load_graph` did and how long each stage took."""
    path: str
    key: str
    cache_hit: bool
    parse_seconds: float = 0.0
    preprocess_seconds: float = 0.0
    build_seconds: float = 0.0
    load_seconds: float = 0.0
    hash_seconds: float = 0.0
    stats: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class EntryHandle:
    """Windowed zero-copy reads over one cached entry's ``arrays.bin``.

    The out-of-core partition path (:mod:`repro.partition`) must slice
    ``row_ptr`` / ``src`` / ``dst`` / ``wgt`` windows of a multi-GB
    entry without ever materializing the full arrays — exactly what the
    single-mmap layout was built for.  A handle maps the blob once;
    :meth:`window` returns a zero-copy view, so the only host memory a
    read costs is the pages the caller actually touches.
    """

    def __init__(self, key: str, entry_dir: Path, meta: dict):
        self.key = key
        self.meta = meta
        self.n = int(meta["n"])
        self.m_pad = int(meta["m_pad"])
        self.num_edges = int(meta["num_edges"])
        fp = meta.get("fingerprint")
        self.fingerprint = tuple(fp) if fp is not None else None
        blob = np.memmap(entry_dir / "arrays.bin", dtype=np.uint8, mode="r")
        self._views = {}
        for name, dtype, shape, off, nbytes in meta["array_table"]:
            view = blob[off:off + nbytes].view(np.dtype(dtype))
            self._views[name] = view.reshape([int(s) for s in shape])

    def array(self, name: str) -> np.ndarray:
        """Full zero-copy view of one stored array (mmap-backed)."""
        return self._views[name]

    def window(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Zero-copy ``[lo, hi)`` slice of one stored array."""
        return self._views[name][lo:hi]

    def to_graph(self) -> Graph:
        """Materialize the full in-core :class:`Graph` from this handle.

        Same result as :meth:`CsrStore.load` on the entry, without
        re-opening or re-hashing anything — the routing path that opened
        a handle for its metadata and then decided the graph fits in
        core converts it directly.
        """
        graph = Graph(
            n=self.n, m_pad=self.m_pad, num_edges=self.num_edges,
            row_ptr=jnp.asarray(self._views["row_ptr"]),
            src=jnp.asarray(self._views["src"]),
            dst=jnp.asarray(self._views["dst"]),
            wgt=jnp.asarray(self._views["wgt"]),
            edge_mask=jnp.asarray(self._views["edge_mask"]),
            kdeg=jnp.asarray(self._views["kdeg"]),
        )
        if self.fingerprint is not None:
            object.__setattr__(graph, "_fingerprint", self.fingerprint)
        return graph


class CsrStore:
    """Directory of cached CSR graphs keyed by content + options."""

    def __init__(self, cache_dir=None):
        self.root = Path(cache_dir) if cache_dir is not None \
            else default_cache_dir()

    # --- keying ---

    @staticmethod
    def key_for(content_hash: str, opts: PreprocessOptions,
                fmt_token: str) -> str:
        blob = f"v{STORE_VERSION}|{content_hash}|{opts.cache_token()}|" \
               f"{fmt_token}"
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    def entry_dir(self, key: str) -> Path:
        return self.root / key

    def has(self, key: str) -> bool:
        return (self.entry_dir(key) / "meta.json").is_file()

    # --- load / save ---

    def load(self, key: str) -> tuple[Graph, dict] | None:
        """(Graph, meta) from a cached entry, or None on miss/corruption."""
        d = self.entry_dir(key)
        try:
            with open(d / "meta.json") as fh:
                meta = json.load(fh)
            if meta.get("store_version") != STORE_VERSION:
                return None
            blob = np.memmap(d / "arrays.bin", dtype=np.uint8, mode="r")
            arrays = {}
            for name, dtype, shape, off, nbytes in meta["array_table"]:
                view = blob[off:off + nbytes].view(np.dtype(dtype))
                arrays[name] = view.reshape([int(s) for s in shape])
            if set(arrays) != set(_ARRAYS):
                return None
        except (OSError, ValueError, json.JSONDecodeError, KeyError):
            return None
        graph = Graph(
            n=int(meta["n"]), m_pad=int(meta["m_pad"]),
            num_edges=int(meta["num_edges"]),
            row_ptr=jnp.asarray(arrays["row_ptr"]),
            src=jnp.asarray(arrays["src"]), dst=jnp.asarray(arrays["dst"]),
            wgt=jnp.asarray(arrays["wgt"]),
            edge_mask=jnp.asarray(arrays["edge_mask"]),
            kdeg=jnp.asarray(arrays["kdeg"]),
        )
        fp = meta.get("fingerprint")
        if fp is not None:
            # warm-cache continuity across processes: same structural
            # identity as the build that produced the entry, CRC-free
            object.__setattr__(graph, "_fingerprint", tuple(fp))
        return graph, meta

    def open(self, key: str) -> EntryHandle | None:
        """Windowed-read handle for an entry, or None on miss/corruption."""
        d = self.entry_dir(key)
        try:
            with open(d / "meta.json") as fh:
                meta = json.load(fh)
            if meta.get("store_version") != STORE_VERSION:
                return None
            handle = EntryHandle(key, d, meta)
            if not set(_ARRAYS) <= set(handle._views):
                return None
        except (OSError, ValueError, json.JSONDecodeError, KeyError):
            return None
        return handle

    def save(self, key: str, graph: Graph, meta: dict) -> None:
        from repro.core.graph import graph_fingerprint
        self.root.mkdir(parents=True, exist_ok=True)
        tmp = Path(tempfile.mkdtemp(dir=self.root, prefix=f".{key}-"))
        try:
            table = []
            with open(tmp / "arrays.bin", "wb") as fh:
                for name in _ARRAYS:
                    arr = np.ascontiguousarray(np.asarray(getattr(graph,
                                                                  name)))
                    pad = -fh.tell() % _ALIGN
                    fh.write(b"\0" * pad)
                    table.append([name, arr.dtype.str, list(arr.shape),
                                  fh.tell(), arr.nbytes])
                    fh.write(arr.tobytes())
            full_meta = {
                "array_table": table,
                **meta, "store_version": STORE_VERSION,
                "n": graph.n, "m_pad": graph.m_pad,
                "num_edges": graph.num_edges,
                "fingerprint": list(graph_fingerprint(graph)),
                "saved_at": time.time(),
            }
            with open(tmp / "meta.json", "w") as fh:
                json.dump(full_meta, fh, indent=1)
            final = self.entry_dir(key)
            try:
                os.replace(tmp, final)          # common case: no entry yet
            except OSError:
                # An entry already exists (stale/corrupt, or a concurrent
                # ingest's) — swap it out atomically and install ours, so
                # force=True and corruption-repair actually take effect.
                trash = Path(f"{tmp}.old")
                try:
                    os.rename(final, trash)
                except OSError:
                    # racing writer owns `final` this instant; both tmp
                    # dirs hold the same content, keep theirs
                    shutil.rmtree(tmp, ignore_errors=True)
                    return
                os.replace(tmp, final)
                shutil.rmtree(trash, ignore_errors=True)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    # --- maintenance ---

    def entries(self) -> list[dict]:
        """meta.json of every entry (for ``ingest --list`` / eviction)."""
        out = []
        if not self.root.is_dir():
            return out
        for d in sorted(self.root.iterdir()):
            mf = d / "meta.json"
            if mf.is_file():
                try:
                    with open(mf) as fh:
                        out.append({"key": d.name, **json.load(fh)})
                except (OSError, json.JSONDecodeError):
                    continue
        return out

    def evict(self, key: str) -> bool:
        d = self.entry_dir(key)
        if d.is_dir():
            shutil.rmtree(d)
            return True
        return False


def _entry_identity(path, opts: PreprocessOptions, fmt: str | None,
                    one_based: bool, n: int | None) -> tuple[str, str]:
    """(resolved format, fmt_token) for a file's cache-key identity.

    The single source of truth shared by :func:`load_graph` and
    :func:`open_graph` — the two must compute byte-identical keys or
    windowed opens would miss entries the loader just wrote.
    """
    fmt = fmt or sniff_format(path)
    if fmt == "mtx" and (one_based or n is not None):
        # .mtx is 1-based with a declared dimension by definition; a
        # caller passing these expected them to matter — and silently
        # folding them into the cache key would fork duplicate store
        # entries for byte-identical graphs.
        raise ValueError("one_based/n only apply to edge-list (snap) "
                         "files; .mtx declares both in its header")
    token = f"{fmt}-base{int(one_based)}-n{n if n is not None else 'auto'}"
    return fmt, token


def load_graph(path, options: PreprocessOptions | None = None, *,
               fmt: str | None = None, one_based: bool = False,
               n: int | None = None, cache: bool = True,
               cache_dir=None, force: bool = False,
               return_report: bool = False):
    """Parse-once/load-forever entry point: graph file -> :class:`Graph`.

    First call on a (file content, options) pair parses the file
    (:mod:`repro.io.formats`), runs the §4.1 preprocessing pipeline
    (:mod:`repro.io.preprocess`), builds the CSR, and persists it in the
    :class:`CsrStore`; every later call — same process or not — mmaps
    the cached arrays straight back.  ``force=True`` re-ingests over an
    existing entry; ``cache=False`` skips the store entirely.

    Returns the Graph, or ``(Graph, IngestReport)`` with
    ``return_report=True`` (stage timings + preprocessing stats; on a
    cache hit the stats are replayed from the entry's metadata and
    ``parse_seconds == 0``).
    """
    path = Path(path)
    opts = options or PreprocessOptions()
    fmt, fmt_token = _entry_identity(path, opts, fmt, one_based, n)

    store = CsrStore(cache_dir) if cache else None
    key = ""
    t_hash = 0.0
    if store is not None:
        t0 = time.perf_counter()
        key = CsrStore.key_for(file_content_hash(path), opts, fmt_token)
        t_hash = time.perf_counter() - t0
        if not force:
            t0 = time.perf_counter()
            hit = store.load(key)
            if hit is not None:
                graph, meta = hit
                report = IngestReport(
                    path=str(path), key=key, cache_hit=True,
                    load_seconds=time.perf_counter() - t0,
                    hash_seconds=t_hash,
                    stats=meta.get("stats", {}), meta=meta)
                return (graph, report) if return_report else graph

    t0 = time.perf_counter()
    if fmt == "snap":
        raw = parse_edge_file(path, fmt=fmt, one_based=one_based, n=n)
    else:
        raw = parse_edge_file(path, fmt=fmt)
    t_parse = time.perf_counter() - t0

    t0 = time.perf_counter()
    cleaned, stats = preprocess(raw, opts)
    t_pre = time.perf_counter() - t0

    t0 = time.perf_counter()
    graph = build_graph(cleaned.edges, cleaned.weights, n=cleaned.n)
    t_build = time.perf_counter() - t0

    meta = {"source": str(path), "format": fmt,
            "options": opts.cache_token(), "stats": stats.as_dict(),
            "file_meta": {k: v for k, v in cleaned.meta.items()
                          if isinstance(v, (str, int, float, bool))}}
    if store is not None:
        store.save(key, graph, meta)

    report = IngestReport(path=str(path), key=key, cache_hit=False,
                          parse_seconds=t_parse, preprocess_seconds=t_pre,
                          build_seconds=t_build, hash_seconds=t_hash,
                          stats=stats.as_dict(), meta=meta)
    return (graph, report) if return_report else graph


def open_graph(path, options: PreprocessOptions | None = None, *,
               fmt: str | None = None, one_based: bool = False,
               n: int | None = None, cache_dir=None,
               force: bool = False) -> EntryHandle:
    """Windowed-read handle for a graph file's cached CSR entry.

    The out-of-core entry point: where :func:`load_graph` materializes
    the full (device) arrays, ``open_graph`` returns an
    :class:`EntryHandle` whose windows are zero-copy slices of the
    store's mmap — O(1) host memory regardless of graph size.  A file
    not yet in the store is ingested first via :func:`load_graph` (the
    ingest itself holds the parsed arrays once; re-opens never do).
    """
    path = Path(path)
    opts = options or PreprocessOptions()
    fmt, fmt_token = _entry_identity(path, opts, fmt, one_based, n)
    store = CsrStore(cache_dir)
    key = CsrStore.key_for(file_content_hash(path), opts, fmt_token)
    if not force:
        handle = store.open(key)
        if handle is not None:
            return handle
    load_graph(path, opts, fmt=fmt,
               **({"one_based": one_based, "n": n} if fmt == "snap" else {}),
               cache_dir=cache_dir, force=force)
    handle = store.open(key)
    if handle is None:
        raise RuntimeError(f"ingest of {path} did not produce store "
                           f"entry {key} (cache_dir misconfigured?)")
    return handle
