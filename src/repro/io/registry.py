"""Named dataset registry: one place to say which graph a name means.

The benchmark suite used to hard-code its synthetic analogues in an
ad-hoc dict (``benchmarks/common.suite``); real files had no home at
all.  The registry unifies both: synthetic entries are builder
callables, file entries are paths routed through
:func:`repro.io.store.load_graph` (so they inherit the parse-once CSR
cache), and every consumer — Table-1 benchmarks, the ingest CLI,
``serve --graph`` — resolves names through the same table.

    from repro.io import datasets
    g = datasets.get("web_rmat")                     # built-in synthetic
    datasets.register_file("orkut", "com-orkut.mtx")  # local corpus file
    datasets.fetch("orkut", URL, SHA256)     # download + verify + register
    g, stats = datasets.get_with_stats("orkut")       # + §4.1 stats

The built-in entries are the paper's Table-1 class analogues (this
container is single-core; the real SuiteSparse graphs drop in as file
entries on hardware that fits them — same names, same call sites).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile
import urllib.parse
import urllib.request
from pathlib import Path
from typing import Callable

from repro.io.preprocess import PreprocessOptions


@dataclasses.dataclass(frozen=True)
class DatasetEntry:
    """One named dataset: a synthetic builder or a graph file."""
    name: str
    kind: str                      # "synthetic" | "file"
    description: str = ""          # Table-1 class, e.g. "web (indochina-2004)"
    builder: Callable | None = None          # kind == "synthetic"
    path: str | None = None                  # kind == "file"
    options: PreprocessOptions | None = None  # file preprocessing knobs
    load_kwargs: dict = dataclasses.field(default_factory=dict)


_REGISTRY: dict[str, DatasetEntry] = {}
_GRAPH_CACHE: dict[str, object] = {}  # name -> built Graph (per process)


def register(name: str, builder: Callable, *, description: str = "",
             overwrite: bool = False) -> DatasetEntry:
    """Register a synthetic dataset (zero-arg builder -> Graph)."""
    return _put(DatasetEntry(name=name, kind="synthetic", builder=builder,
                             description=description), overwrite)


def register_file(name: str, path, *, description: str = "",
                  options: PreprocessOptions | None = None,
                  overwrite: bool = False, **load_kwargs) -> DatasetEntry:
    """Register a graph file (``.mtx`` / SNAP edge list) by path.

    ``load_kwargs`` pass through to :func:`repro.io.store.load_graph`
    (``fmt``, ``one_based``, ``n``, ``cache_dir`` ...).  The file only
    needs to exist at first ``get``, not at registration.
    """
    return _put(DatasetEntry(name=name, kind="file", path=str(path),
                             description=description, options=options,
                             load_kwargs=dict(load_kwargs)), overwrite)


def _put(entry: DatasetEntry, overwrite: bool) -> DatasetEntry:
    if not overwrite and entry.name in _REGISTRY:
        raise ValueError(f"dataset {entry.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[entry.name] = entry
    _GRAPH_CACHE.pop(entry.name, None)
    return entry


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)
    _GRAPH_CACHE.pop(name, None)


def names() -> list[str]:
    return sorted(_REGISTRY)


def entry(name: str) -> DatasetEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; registered: "
                       f"{', '.join(names()) or '<none>'}") from None


def get(name: str):
    """Resolve a name to its built :class:`Graph` (memoized per process).

    File entries additionally hit the on-disk CSR store, so the first
    ``get`` in a *process* may still be instant if another process
    already ingested the file.
    """
    return get_with_stats(name)[0]


def get_with_stats(name: str):
    """(Graph, preprocessing-stats dict or None for synthetics)."""
    e = entry(name)
    cached = _GRAPH_CACHE.get(name)
    if cached is not None:
        return cached
    if e.kind == "synthetic":
        out = (e.builder(), None)
    else:
        from repro.io.store import load_graph
        if not Path(e.path).is_file():
            raise FileNotFoundError(
                f"dataset {name!r} points at missing file {e.path} — "
                "download it first (see README 'Loading real graphs')")
        graph, report = load_graph(e.path, e.options, return_report=True,
                                   **e.load_kwargs)
        out = (graph, report.stats)
    _GRAPH_CACHE[name] = out
    return out


def clear_graph_cache() -> None:
    """Drop memoized graphs (tests; registrations stay)."""
    _GRAPH_CACHE.clear()


# --- corpus downloads -------------------------------------------------------

_DOWNLOAD_BLOCK = 4 << 20


def download_dir() -> Path:
    """Where fetched corpus files land (sibling of the CSR store)."""
    from repro.io.store import default_cache_dir
    return default_cache_dir().parent / "downloads"


def fetch(name: str, url: str, sha256: str, *, description: str = "",
          filename: str | None = None, cache_dir=None,
          options: PreprocessOptions | None = None,
          overwrite: bool = False, timeout: float = 60.0,
          **load_kwargs) -> DatasetEntry:
    """Download a corpus file, verify its checksum, register it.

    The SuiteSparse/SNAP onboarding path: one call turns a URL +
    published sha256 into a named dataset every consumer (Table-1
    benchmarks, ``serve --graph``, the ingest CLI) can resolve.  The
    download is atomic (temp file + rename) and idempotent — a file
    already present with the right checksum is never re-fetched; a
    present file with the *wrong* checksum is treated as a damaged
    partial and re-downloaded.  A checksum mismatch on the fresh bytes
    raises and leaves nothing behind.  ``file://`` URLs work (offline
    CI exercises exactly that).  Gzipped payloads can register as-is —
    the chunked readers decompress transparently.  ``timeout`` guards
    every socket operation (a mirror that stalls mid-transfer raises
    instead of hanging the caller).
    """
    from repro.io.store import file_content_hash
    dest_dir = Path(cache_dir) if cache_dir is not None else download_dir()
    dest = dest_dir / (filename or os.path.basename(
        urllib.parse.urlparse(url).path) or name)
    if not dest.is_file() or file_content_hash(dest) != sha256.lower():
        dest_dir.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=dest_dir, prefix=f".{dest.name}-")
        try:
            h = hashlib.sha256()
            with os.fdopen(fd, "wb") as out, \
                    urllib.request.urlopen(url, timeout=timeout) as resp:
                while True:
                    block = resp.read(_DOWNLOAD_BLOCK)
                    if not block:
                        break
                    h.update(block)
                    out.write(block)
            if h.hexdigest() != sha256.lower():
                raise ValueError(
                    f"checksum mismatch for {url}: expected {sha256}, "
                    f"got {h.hexdigest()} — upstream changed or the "
                    "transfer was corrupted; nothing was registered")
            os.replace(tmp, dest)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    return register_file(name, dest, description=description,
                         options=options, overwrite=overwrite,
                         **load_kwargs)


# --- built-in synthetic suite (the paper's Table-1 class analogues) --------

def _register_builtins() -> None:
    from repro import graphgen as gg
    builtin = {
        "web_rmat": (lambda: gg.rmat(12, 12, seed=1),
                     "web (indochina-2004)"),
        "social_rmat": (lambda: gg.rmat(11, 24, seed=2),
                        "social (com-Orkut)"),
        "road_grid": (lambda: gg.grid2d(64), "road (asia_osm)"),
        "kmer_sparse": (lambda: gg.erdos_renyi(6000, 2.2, seed=3),
                        "protein k-mer (kmer_A2a)"),
        "planted": (lambda: gg.planted_partition(16, 64, 0.25, 0.002,
                                                 seed=4)[0],
                    "planted partition (quality ref)"),
    }
    for name, (builder, desc) in builtin.items():
        if name not in _REGISTRY:
            register(name, builder, description=desc)


_register_builtins()
