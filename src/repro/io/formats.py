"""Chunked parsers/writers for real graph files: MatrixMarket + SNAP.

The paper's evaluation corpus (Table 1) is SuiteSparse MatrixMarket
files up to 3.8B edges; SNAP distributes the social-network graphs as
``#``-commented whitespace edge lists.  Both parsers here stream the
file in fixed-size byte blocks and tokenize each block with NumPy-level
primitives (``bytes.split`` + one ``np.array`` over the token buffer),
so a multi-gigabyte file is never materialised as per-line Python
objects — peak host memory is one block plus the accumulated edge
arrays.

Outputs are :class:`EdgeList` — the raw on-file edge set, **exactly as
stored** (1-based ids already shifted to 0-based, symmetric-storage
mirroring already expanded, but *no* dedup / self-loop / weight
normalisation).  Cleaning is :mod:`repro.io.preprocess`'s job; keeping
the stages separate is what lets the preprocessing stats report the raw
vs. cleaned edge counts the paper's §4.1 table shows.

Format notes:

* MatrixMarket coordinate (``.mtx``): ``%%MatrixMarket matrix
  coordinate {real|integer|pattern} {general|symmetric}`` header,
  ``%``-comment lines, one ``rows cols nnz`` size line, then ``i j
  [v]`` entries, 1-based.  ``symmetric`` storage keeps one triangle;
  the parser mirrors off-diagonal entries so downstream code always
  sees the full undirected edge set.  ``pattern`` files carry no
  values (unit weights — the paper's default for every graph).
* SNAP / whitespace edge lists (``.snap.txt``, ``.edges``, ``.txt``):
  ``#``-comment lines, ``u v [w]`` per line, 0- or 1-based (SNAP files
  are 0-based; ``one_based=True`` shifts).  No vertex-count header —
  ``n`` is inferred as ``max_id + 1`` unless given.
* gzip: both parsers read through :func:`open_graph_bytes`, which
  detects the gzip magic bytes and streams the decompressed member
  block-by-block — SuiteSparse/SNAP downloads ship compressed, and a
  ``.mtx.gz`` never has to be unpacked on disk.
"""
from __future__ import annotations

import dataclasses
import gzip
from pathlib import Path

import numpy as np

DEFAULT_BLOCK_BYTES = 4 << 20  # 4 MiB per streamed block
_GZIP_MAGIC = b"\x1f\x8b"


def open_graph_bytes(path):
    """Binary reader for a graph file, transparently gunzipping.

    Detection is by magic bytes, not extension, so ``file.mtx.gz`` and a
    misnamed ``file.mtx`` that is really gzip both work.  The gzip
    member streams block-by-block through the same
    :func:`_iter_blocks` path as plain files — the decompressed file is
    never materialized.
    """
    with open(path, "rb") as probe:
        magic = probe.read(2)
    if magic == _GZIP_MAGIC:
        return gzip.open(path, "rb")
    return open(path, "rb")


@dataclasses.dataclass
class EdgeList:
    """A raw parsed edge set (host-side, pre-preprocessing).

    ``edges`` is (E, 2) int64; ``weights`` is (E,) float64 or None
    (pattern/unweighted files — unit weights downstream).  ``n`` is the
    declared or inferred vertex count.  ``meta`` records provenance
    (format, header fields, symmetric storage, comment/blank counts)
    for the ingest CLI's ``--stats`` report.
    """
    edges: np.ndarray
    weights: np.ndarray | None
    n: int
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def max_id(self) -> int:
        return int(self.edges.max()) if len(self.edges) else -1


class FormatError(ValueError):
    """Malformed graph file (bad header, ragged columns, id overflow)."""


# --- block streaming -------------------------------------------------------

def _iter_blocks(fh, block_bytes: int):
    """Yield byte blocks ending on line boundaries (tail carried over)."""
    carry = b""
    while True:
        block = fh.read(block_bytes)
        if not block:
            if carry:
                yield carry
            return
        block = carry + block
        cut = block.rfind(b"\n")
        if cut < 0:
            carry = block
            continue
        carry = block[cut + 1:]
        yield block[: cut + 1]


def _tokenize(block: bytes, comment: bytes) -> tuple[list[bytes], int]:
    """Split a block into whitespace tokens, dropping comment lines.

    Returns (tokens, lines_dropped).  The fast path — no comment marker
    anywhere in the block — is one C-level ``split``; blocks containing
    comments fall back to a per-line filter (headers cluster at the top
    of real files, so ~all payload blocks take the fast path).
    """
    if comment not in block:
        return block.split(), 0
    kept, dropped = [], 0
    for line in block.splitlines():
        if line.lstrip().startswith(comment):
            dropped += 1
        else:
            kept.append(line)
    return b" ".join(kept).split(), dropped


def _parse_columns(tokens: list[bytes], ncols: int, where: str):
    """Tokens -> (rows, ncols) float64 array (one vectorized np.array)."""
    if len(tokens) % ncols:
        raise FormatError(
            f"{where}: token count {len(tokens)} is not a multiple of "
            f"{ncols} columns — ragged or truncated entry lines")
    arr = np.array(tokens, dtype=np.float64)
    return arr.reshape(-1, ncols)


# --- MatrixMarket ----------------------------------------------------------

_MM_FIELDS = ("real", "integer", "pattern")
_MM_SYMMETRIES = ("general", "symmetric")


def _read_mtx_header(fh):
    """Consume banner + comments + size line; return (field, symmetry,
    (rows, cols, nnz), header_lines)."""
    banner = fh.readline()
    parts = banner.split()
    if len(parts) < 5 or parts[0] != b"%%MatrixMarket" \
            or parts[1] != b"matrix" or parts[2] != b"coordinate":
        raise FormatError(
            "not a MatrixMarket coordinate file (banner "
            f"{banner[:60]!r}); array-format .mtx is not a graph")
    field = parts[3].decode().lower()
    symmetry = parts[4].decode().lower()
    if field == "complex":
        raise FormatError("complex-valued .mtx is not a weighted graph")
    if field not in _MM_FIELDS:
        raise FormatError(f"unsupported .mtx field {field!r}")
    if symmetry in ("skew-symmetric", "hermitian"):
        raise FormatError(f".mtx symmetry {symmetry!r} has no undirected-"
                          "graph reading (negative/conjugate mirrors)")
    if symmetry not in _MM_SYMMETRIES:
        raise FormatError(f"unsupported .mtx symmetry {symmetry!r}")
    header_lines = 1
    while True:
        line = fh.readline()
        if not line:
            raise FormatError("missing .mtx size line")
        header_lines += 1
        stripped = line.strip()
        if not stripped or stripped.startswith(b"%"):
            continue
        dims = stripped.split()
        if len(dims) != 3:
            raise FormatError(f"bad .mtx size line {line!r}")
        rows, cols, nnz = (int(x) for x in dims)
        if rows != cols:
            raise FormatError(
                f"rectangular matrix ({rows}x{cols}) is not an adjacency "
                "matrix — row and column ids name different entity sets "
                "(bipartite data needs an explicit projection first)")
        return field, symmetry, (rows, cols, nnz), header_lines


def parse_mtx(path, block_bytes: int = DEFAULT_BLOCK_BYTES) -> EdgeList:
    """Parse a MatrixMarket coordinate file into a raw :class:`EdgeList`.

    Ids come back 0-based; symmetric storage is expanded (off-diagonal
    entries mirrored) so the edge set matches what a ``general`` file of
    the same graph would hold.  Pattern files yield ``weights=None``.
    """
    path = Path(path)
    with open_graph_bytes(path) as fh:
        field, symmetry, (rows, cols, nnz), _ = _read_mtx_header(fh)
        ncols = 2 if field == "pattern" else 3
        chunks, comment_lines = [], 0
        for block in _iter_blocks(fh, block_bytes):
            tokens, dropped = _tokenize(block, b"%")
            comment_lines += dropped
            if tokens:
                chunks.append(_parse_columns(tokens, ncols, path.name))
    data = np.concatenate(chunks, axis=0) if chunks \
        else np.zeros((0, ncols), np.float64)
    if len(data) != nnz:
        raise FormatError(f"{path.name}: header promises {nnz} entries, "
                          f"file holds {len(data)}")
    edges = data[:, :2].astype(np.int64) - 1  # 1-based -> 0-based
    if len(edges) and edges.min() < 0:
        raise FormatError(f"{path.name}: entry ids below 1 in a 1-based "
                          "coordinate file")
    weights = None if field == "pattern" else data[:, 2].copy()
    mirrored = 0
    if symmetry == "symmetric":
        off_diag = edges[:, 0] != edges[:, 1]
        mirrored = int(off_diag.sum())
        edges = np.concatenate([edges, edges[off_diag][:, ::-1]], axis=0)
        if weights is not None:
            weights = np.concatenate([weights, weights[off_diag]])
    n = rows
    if len(edges) and edges.max() >= n:
        raise FormatError(f"{path.name}: entry id {edges.max() + 1} "
                          f"exceeds declared dimension {n}")
    return EdgeList(edges=edges, weights=weights, n=n, meta={
        "format": "mtx", "field": field, "symmetry": symmetry,
        "declared_shape": (rows, cols), "declared_nnz": nnz,
        "mirrored_entries": mirrored, "comment_lines": comment_lines,
    })


# --- SNAP / whitespace edge lists -----------------------------------------

def parse_snap(path, one_based: bool = False, n: int | None = None,
               block_bytes: int = DEFAULT_BLOCK_BYTES) -> EdgeList:
    """Parse a SNAP-style whitespace edge list (``#`` comments).

    Column count (2 = unweighted, 3 = weighted) is detected from the
    first data block and enforced for the rest of the file.  ``n``
    defaults to ``max_id + 1`` after the optional 1-based shift.
    """
    path = Path(path)
    chunks, comment_lines, ncols = [], 0, None
    with open_graph_bytes(path) as fh:
        for block in _iter_blocks(fh, block_bytes):
            tokens, dropped = _tokenize(block, b"#")
            comment_lines += dropped
            if not tokens:
                continue
            if ncols is None:
                for line in block.splitlines():
                    first = line.split()
                    if first and not first[0].startswith(b"#"):
                        ncols = len(first)
                        break
                if ncols not in (2, 3):
                    raise FormatError(
                        f"{path.name}: edge lines must be 'u v' or "
                        f"'u v w', first data line has {ncols} columns")
            chunks.append(_parse_columns(tokens, ncols, path.name))
    if ncols is None:
        ncols = 2
    data = np.concatenate(chunks, axis=0) if chunks \
        else np.zeros((0, ncols), np.float64)
    edges = data[:, :2].astype(np.int64)
    if one_based:
        edges -= 1
    if len(edges) and edges.min() < 0:
        raise FormatError(f"{path.name}: negative vertex ids "
                          f"(wrong --one-based setting?)")
    weights = data[:, 2].copy() if ncols == 3 else None
    inferred = int(edges.max()) + 1 if len(edges) else 0
    if n is None:
        n = max(inferred, 1)
    elif inferred > n:
        raise FormatError(f"{path.name}: vertex id {inferred - 1} exceeds "
                          f"given n={n}")
    return EdgeList(edges=edges, weights=weights, n=int(n), meta={
        "format": "snap", "one_based": one_based,
        "weighted": weights is not None, "comment_lines": comment_lines,
    })


# --- format dispatch -------------------------------------------------------

def sniff_format(path) -> str:
    """``"mtx"`` or ``"snap"``, by extension then content.

    A trailing ``.gz`` is ignored for extension sniffing, and content
    sniffing reads through the transparent-decompression layer, so
    gzipped files resolve to the format of their payload.
    """
    path = Path(path)
    suffixes = [s.lower() for s in path.suffixes if s.lower() != ".gz"]
    if ".mtx" in suffixes:
        return "mtx"
    if any(s in suffixes for s in (".snap", ".edges", ".el")):
        return "snap"
    with open_graph_bytes(path) as fh:
        head = fh.read(64)
    return "mtx" if head.startswith(b"%%MatrixMarket") else "snap"


def parse_edge_file(path, fmt: str | None = None, **kw) -> EdgeList:
    """Dispatch to :func:`parse_mtx` / :func:`parse_snap` by format."""
    fmt = fmt or sniff_format(path)
    if fmt == "mtx":
        kw.pop("one_based", None)  # .mtx is 1-based by definition
        return parse_mtx(path, **kw)
    if fmt == "snap":
        return parse_snap(path, **kw)
    raise FormatError(f"unknown graph format {fmt!r}")


# --- writers (fixtures, benchmarks, property tests) ------------------------

def write_mtx(path, edges, weights=None, n: int | None = None,
              symmetric: bool = False) -> None:
    """Write an edge list as MatrixMarket coordinate (1-based).

    ``symmetric=True`` stores the lower triangle only (entries are
    canonicalised to ``row >= col``), the SuiteSparse convention for
    undirected graphs; the parser mirrors them back.  Weights print at
    ``%.17g`` so a float64 round-trips bit-exactly through the text.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if n is None:
        n = int(edges.max()) + 1 if len(edges) else 1
    field = "pattern" if weights is None else "real"
    symmetry = "symmetric" if symmetric else "general"
    if symmetric:
        lo = edges.min(axis=1)
        hi = edges.max(axis=1)
        edges = np.stack([hi, lo], axis=1)  # row >= col (lower triangle)
    with open(path, "w") as fh:
        fh.write(f"%%MatrixMarket matrix coordinate {field} {symmetry}\n")
        fh.write(f"% written by repro.io ({len(edges)} entries)\n")
        fh.write(f"{n} {n} {len(edges)}\n")
        if weights is None:
            for u, v in (edges + 1).tolist():
                fh.write(f"{u} {v}\n")
        else:
            weights = np.asarray(weights, dtype=np.float64).reshape(-1)
            for (u, v), w in zip((edges + 1).tolist(), weights.tolist()):
                fh.write(f"{u} {v} {w:.17g}\n")


def write_snap(path, edges, weights=None, comment: str | None = None) -> None:
    """Write a SNAP-style edge list (0-based, ``#`` header comment)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    with open(path, "w") as fh:
        fh.write(f"# {comment or 'written by repro.io'}\n")
        fh.write(f"# Nodes: {int(edges.max()) + 1 if len(edges) else 0} "
                 f"Edges: {len(edges)}\n")
        if weights is None:
            for u, v in edges.tolist():
                fh.write(f"{u}\t{v}\n")
        else:
            weights = np.asarray(weights, dtype=np.float64).reshape(-1)
            for (u, v), w in zip(edges.tolist(), weights.tolist()):
                fh.write(f"{u}\t{v}\t{w:.17g}\n")
