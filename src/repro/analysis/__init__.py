"""repro.analysis — static lint + runtime trace audit for the hot-path
contracts (one trace per bucket, no hidden host syncs, protocol
conformance, Pallas hygiene, ledger discipline).

CLI: ``python -m repro.launch.lint`` (see README "Static analysis &
trace auditing").
"""
from repro.analysis.findings import Baseline, Finding
from repro.analysis.lint import lint_paths, lint_source, rule_relpath
from repro.analysis.rules import all_rules
from repro.analysis.trace_audit import ExcessRetraceError, TraceAudit
from repro.analysis.workload import audit_workload, run_workload

__all__ = [
    "Baseline", "Finding", "lint_paths", "lint_source", "rule_relpath",
    "all_rules", "TraceAudit", "ExcessRetraceError", "audit_workload",
    "run_workload",
]
