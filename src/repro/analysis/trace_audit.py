"""Runtime trace auditor: zero excess retraces, mechanically checked.

The repo's perf contract is *one XLA trace per (backend, bucket) per
stage*: same-bucket traffic must reuse compiled executables across solo,
batched, warm-started, and out-of-core fits.  ``tests/test_engine.py``
pinned this for one solo case; :class:`TraceAudit` generalizes it into a
gate over any workload:

    with TraceAudit() as audit:
        run_workload()
    audit.assert_no_excess()          # or audit.report() / write_json()

Attribution: the engine (and the ooc driver) wrap backend dispatches in
:func:`repro.engine.cache.trace_context`, so every ``TRACE_LOG.record``
fired inside a traced function body — Python only executes those on an
actual (re)trace — lands in a (backend, bucket) bin.  A bin with more
than one trace for the same stage tag means jax retraced an executable
the compile cache was supposed to reuse: a silent recompile.

A *different* bucket tracing is fine (that is what buckets are for);
the same (stage, backend, bucket) tracing twice never is.
"""
from __future__ import annotations

import json
from typing import Any

from repro.engine.cache import TRACE_LOG, TraceLog


class ExcessRetraceError(AssertionError):
    """A (stage, backend, bucket) traced more than once under audit."""


class TraceAudit:
    """Context manager diffing per-context trace counts around a workload."""

    def __init__(self, log: TraceLog | None = None):
        self.log = log if log is not None else TRACE_LOG
        self._before: dict[tuple, int] = {}
        self._after: dict[tuple, int] | None = None

    def __enter__(self) -> "TraceAudit":
        self._before = self.log.context_snapshot()
        self._after = None
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._after = self.log.context_snapshot()

    def _snapshot_now(self) -> dict[tuple, int]:
        return self._after if self._after is not None \
            else self.log.context_snapshot()

    def deltas(self) -> dict[tuple, int]:
        """(stage tag, context) -> traces during the audited region."""
        after = self._snapshot_now()
        out = {}
        for key, count in after.items():
            d = count - self._before.get(key, 0)
            if d > 0:
                out[key] = d
        return out

    def excess(self) -> dict[tuple, int]:
        """The violations: any (stage, context) that traced > 1 time."""
        return {k: v for k, v in self.deltas().items() if v > 1}

    def report(self) -> dict[str, Any]:
        rows = []
        for (tag, ctx), count in sorted(self.deltas().items(),
                                        key=lambda kv: repr(kv[0])):
            backend, bucket = (None, None) if ctx is None else ctx
            rows.append({
                "stage": tag,
                "backend": backend,
                "bucket": list(bucket) if isinstance(bucket, tuple)
                else bucket,
                "traces": count,
                "excess": count > 1,
            })
        n_excess = sum(1 for r in rows if r["excess"])
        return {
            "contexts": rows,
            "total_traces": sum(r["traces"] for r in rows),
            "excess_contexts": n_excess,
            "ok": n_excess == 0,
        }

    def assert_no_excess(self) -> None:
        bad = self.excess()
        if bad:
            lines = [f"  {tag} @ {ctx}: {count} traces"
                     for (tag, ctx), count in sorted(bad.items(),
                                                     key=lambda kv:
                                                     repr(kv[0]))]
            raise ExcessRetraceError(
                "excess retraces — the compile cache was bypassed for:\n"
                + "\n".join(lines))

    def write_json(self, path) -> dict[str, Any]:
        report = self.report()
        with open(path, "w") as fh:
            json.dump(report, fh, indent=2, default=str)
            fh.write("\n")
        return report
