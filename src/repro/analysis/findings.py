"""Finding / baseline plumbing shared by the linter and its CLI.

A :class:`Finding` is one rule violation anchored to ``path:line:col``.
Baselines let a strict CI gate coexist with known, justified debt: a
finding whose ``(rule, path, message)`` identity appears in the committed
baseline file is reported but does not fail ``--strict``.  Line numbers
are deliberately *not* part of the identity — unrelated edits above a
baselined finding must not resurrect it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str       # "R001" .. "R005"
    path: str       # repo-relative posix path of the offending file
    line: int       # 1-based
    col: int        # 0-based (ast convention)
    message: str
    suppressed: bool = False   # matched an inline `# lint: <tag>-ok`

    def identity(self) -> tuple[str, str, str]:
        """Baseline identity: stable across unrelated line shifts."""
        return (self.rule, self.path, self.message)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1} {self.rule} {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "suppressed": self.suppressed}


class Baseline:
    """Committed set of accepted finding identities."""

    def __init__(self, entries: Iterable[dict] | None = None):
        self._identities: set[tuple[str, str, str]] = set()
        for e in entries or ():
            self._identities.add((e["rule"], e["path"], e["message"]))

    def __len__(self) -> int:
        return len(self._identities)

    def __contains__(self, finding: Finding) -> bool:
        return finding.identity() in self._identities

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as fh:
            data = json.load(fh)
        if not isinstance(data, list):
            raise ValueError(f"baseline {path} must be a JSON list of "
                             "{rule, path, message} entries")
        return cls(data)

    @staticmethod
    def dump(findings: Iterable[Finding], path: str) -> int:
        """Write the given findings as a fresh baseline; returns the count."""
        entries = sorted(
            {f.identity() for f in findings})
        payload = [{"rule": r, "path": p, "message": m}
                   for (r, p, m) in entries]
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        return len(payload)
