"""Lint driver: map files to rule-relative paths, run rules, report.

Path convention: rules scope themselves by *relpath* — the path under
``src/repro/`` (``engine/backends/segment.py``) so the same rule set
applies to the package and to test fixtures (whose directories mirror
the hot-path layout under ``tests/fixtures/lint/``).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Sequence

from repro.analysis.findings import Finding
from repro.analysis.rules import ModuleContext, Rule, all_rules

# markers whose trailing path fragment becomes the rule-relative path
_ANCHORS = ("src/repro/", "fixtures/lint/")


def rule_relpath(path: Path) -> str:
    """Rule-relative posix path for ``path`` (see module docstring)."""
    posix = path.as_posix()
    for anchor in _ANCHORS:
        idx = posix.rfind(anchor)
        if idx >= 0:
            return posix[idx + len(anchor):]
    return path.name


def lint_source(source: str, relpath: str,
                rules: Sequence[Rule] | None = None) -> list[Finding]:
    """Run the rules over one module's source. Returns ALL findings,
    suppressed ones included (callers filter on ``.suppressed``)."""
    if rules is None:
        rules = all_rules()
    try:
        ctx = ModuleContext.from_source(source, relpath)
    except SyntaxError as e:
        return [Finding(rule="E000", path=relpath, line=e.lineno or 1,
                        col=(e.offset or 1) - 1,
                        message=f"syntax error: {e.msg}")]
    findings: list[Finding] = []
    for rule in rules:
        if rule.applies(relpath):
            findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_py_files(paths: Iterable[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: Iterable[Path],
               rules: Sequence[Rule] | None = None) -> list[Finding]:
    """Lint every ``.py`` under ``paths`` (dirs recursed, sorted)."""
    if rules is None:
        rules = all_rules()
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(
            lint_source(f.read_text(), rule_relpath(f), rules))
    return findings


def parse_tree(source: str) -> ast.Module:
    """Exposed for tests that poke at rule internals."""
    return ast.parse(source)
