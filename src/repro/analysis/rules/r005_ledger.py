"""R005 — ledger discipline for edge-scale allocations.

The out-of-core path holds a hard resident-byte budget via
``partition/slices.py``'s ``MemoryLedger``; its guarantee ("we never
materialize more than ``memory_budget`` bytes of slice data") only holds
if every edge-scale allocation in the partition machinery is accounted.
This rule flags ``np.zeros/empty/...`` calls in ``partition/`` and
``engine/backends/`` whose size expression references edge-scale names
(``m``, ``m_pad``, ``m_w``, ``.num_edges``) from functions that show no
accounting evidence — no ``nbytes`` computation, no ``ledger`` mention,
no ``.acquire(`` call.

Vertex-scale allocations (``n``, ``n_loc``) are deliberately out of
scope: the semi-external model keeps all vertex-length state resident by
design; only edge arrays are budgeted.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    ModuleContext,
    Rule,
    dotted_name,
    function_map,
)

_ALLOC_FUNCS = {"zeros", "empty", "full", "ones", "concatenate", "repeat",
                "arange"}
_NP_ROOTS = {"np", "numpy"}
_EDGE_NAMES = {"m", "m_pad", "m_w"}
_EDGE_ATTRS = {"m", "m_pad", "m_w", "num_edges"}


def _is_np_alloc(node: ast.Call) -> str | None:
    name = dotted_name(node.func)
    if name is None:
        return None
    parts = name.split(".")
    if parts[0] in _NP_ROOTS and parts[-1] in _ALLOC_FUNCS:
        return name
    return None


def _edge_scale_ref(node: ast.AST) -> str | None:
    """An edge-scale size reference under the allocation's size arg."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _EDGE_NAMES:
            return sub.id
        if isinstance(sub, ast.Attribute) and sub.attr in _EDGE_ATTRS:
            base = dotted_name(sub)
            return base if base else f".{sub.attr}"
    return None


def _has_accounting(fn: ast.FunctionDef | None) -> bool:
    """Does the enclosing function show ledger/accounting evidence?"""
    if fn is None:
        return False
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and "nbytes" in node.id:
            return True
        if isinstance(node, ast.Attribute) \
                and ("nbytes" in node.attr or "ledger" in node.attr):
            return True
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("acquire", "reserve"):
            return True
        if isinstance(node, ast.FunctionDef) and "nbytes" in node.name:
            return True
    # also accept calls *to* an nbytes helper (self.partition_prepare_nbytes)
    return False


class LedgerRule(Rule):
    id = "R005"
    tag = "ledger"
    description = ("edge-scale numpy allocations in partition code must be "
                   "accounted through MemoryLedger")

    def applies(self, relpath: str) -> bool:
        return (relpath.startswith("partition/")
                or relpath.startswith("engine/backends/"))

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        owner = function_map(ctx.tree)
        accounted: dict[int, bool] = {}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            alloc = _is_np_alloc(node)
            if alloc is None or not node.args:
                continue
            ref = _edge_scale_ref(node.args[0])
            if ref is None:
                continue
            fn = owner.get(id(node))
            key = id(fn) if fn is not None else 0
            if key not in accounted:
                accounted[key] = _has_accounting(fn)
            if accounted[key]:
                continue
            where = f"'{fn.name}'" if fn else "module scope"
            findings.append(self.finding(
                ctx, node,
                f"{alloc}() sized by edge-scale '{ref}' in {where} with no "
                f"MemoryLedger accounting (no nbytes/acquire in scope) — "
                f"unbudgeted edge arrays break the resident-byte guarantee"))
        return findings
