"""R006 — telemetry discipline in hot-path sweep code.

The observability layer (``repro.obs``) is host-side bookkeeping by
contract: spans and registry writes wrap *stage boundaries* (engine
prepare/dispatch/compact, ooc phases, serving admission→settle), never
the per-sweep inner loops, and convergence profiles record device-side
into preallocated buffers precisely so no telemetry runs per sweep.
This rule enforces that contract inside the hot modules (``core/``,
``kernels/``, ``engine/backends/``):

* **traced scopes** (functions handed to ``jax.jit`` / ``shard_map`` /
  ``lax.while_loop``): any host timer (``time.perf_counter`` & friends),
  tracer span, or metrics-registry call — under trace these either fail
  or burn a host call into every sweep of the compiled loop;
* **sweep-dispatch loops**: the same calls inside a ``for``/``while``
  body that dispatches jitted sweep callables (``plan.step(...)``,
  ``sweeps.move(...)``) — a timer or counter per sweep reintroduces
  exactly the per-iteration host overhead the fused dispatch work
  removed.  Stage-boundary timing *around* such loops stays legal.

Deliberate exceptions carry ``# lint: telemetry-ok — <why>``.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import ModuleContext, Rule, dotted_name
from repro.analysis.rules.r001_host_sync import (
    _all_functions,
    _PLAN_RECEIVERS,
    _SWEEP_METHODS,
    _traced_functions,
)

_HOT_PREFIXES = ("core/", "kernels/", "engine/backends/")

# Host wall-clock reads (bare names cover `from time import perf_counter`).
_TIMER_CALLS = {"time.perf_counter", "perf_counter", "time.monotonic",
                "monotonic", "time.perf_counter_ns", "time.time"}
# Span tracer entry points (repro.obs.trace).
_SPAN_CALLS = {"span", "TRACER.span", "tracer.span"}
# Metric-handle mutators (repro.obs.registry Counter/Gauge/Histogram).
# ``.set`` is deliberately absent: ``buf.at[row].set(...)`` is the jax
# in-place update idiom all over the hot modules.
_METRIC_METHODS = {"inc", "observe"}
# Registry roots: REGISTRY.counter(...), scope.histogram(...), etc.
_REGISTRY_ROOTS = {"REGISTRY", "registry"}
_REGISTRY_METHODS = {"counter", "gauge", "histogram", "scope"}
# Quality hooks (repro.obs.quality + DetectionResult.check_connected):
# host-side reductions over the *final* labels by contract — inside a
# traced function they burn a trace-time device pass into the
# executable; inside a sweep loop they pay a full modularity /
# connectivity pass per sweep.  They run once, post-convergence, at the
# stage boundary the engine already owns.
_QUALITY_CALLS = {"compute_quality", "record_report", "label_churn",
                  "check_connected"}


def _telemetry_call(node: ast.Call) -> str | None:
    """Short description when ``node`` is a telemetry call, else None."""
    name = dotted_name(node.func)
    if name in _TIMER_CALLS:
        return f"host timer {name}()"
    if name in _SPAN_CALLS:
        return f"tracer span {name}()"
    if name in _QUALITY_CALLS:
        return f"quality hook {name}()"
    if isinstance(node.func, ast.Attribute):
        attr = node.func.attr
        if attr in _QUALITY_CALLS:
            return f"quality hook .{attr}()"
        if attr in _METRIC_METHODS:
            return f"metric write .{attr}()"
        root = dotted_name(node.func.value)
        if root in _REGISTRY_ROOTS and attr in _REGISTRY_METHODS:
            return f"registry call {root}.{attr}()"
    return None


def _is_sweep_dispatch(node: ast.Call) -> bool:
    func = node.func
    return (isinstance(func, ast.Attribute) and func.attr in _SWEEP_METHODS
            and isinstance(func.value, ast.Name)
            and func.value.id in _PLAN_RECEIVERS)


class TelemetryRule(Rule):
    id = "R006"
    tag = "telemetry"
    description = ("telemetry (perf_counter / spans / metric writes) inside "
                   "jitted or per-sweep hot-path code")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(_HOT_PREFIXES)

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        traced = _traced_functions(ctx.tree)
        for fn in _all_functions(ctx.tree):
            if fn in traced:
                findings.extend(self._check_traced(ctx, fn))
            else:
                findings.extend(self._check_sweep_loops(ctx, fn))
        return findings

    def _check_traced(self, ctx: ModuleContext,
                      fn: ast.FunctionDef) -> list[Finding]:
        out = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            what = _telemetry_call(node)
            if what:
                out.append(self.finding(
                    ctx, node,
                    f"{what} inside jit-traced '{fn.name}' — telemetry "
                    f"must stay host-side at stage boundaries (use the "
                    f"device-side profile buffer for per-sweep counts)"))
        return out

    def _check_sweep_loops(self, ctx: ModuleContext,
                           fn: ast.FunctionDef) -> list[Finding]:
        out = []
        for loop in (n for n in ast.walk(fn)
                     if isinstance(n, (ast.For, ast.While))):
            if not any(_is_sweep_dispatch(c) for c in ast.walk(loop)
                       if isinstance(c, ast.Call)):
                continue
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                what = _telemetry_call(node)
                if what:
                    out.append(self.finding(
                        ctx, node,
                        f"{what} inside a sweep-dispatch loop in "
                        f"'{fn.name}' — per-sweep telemetry reintroduces "
                        f"per-iteration host overhead; time the loop as "
                        f"one stage instead"))
        # nested loops walk the same nodes twice: one finding per site
        seen: set[tuple[int, int]] = set()
        uniq = []
        for f in out:
            if (f.line, f.col) not in seen:
                seen.add((f.line, f.col))
                uniq.append(f)
        return uniq
