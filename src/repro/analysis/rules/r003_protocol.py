"""R003 — backend protocol conformance.

``engine/registry.py`` dispatches backends dynamically (duck-typed
Protocol), so a drifted method name or a renamed positional argument in
one backend only fails at runtime, possibly deep inside an out-of-core
sweep.  This rule statically checks every ``@register_backend`` class in
``engine/backends/`` against the reference surface:

* solo quartet: ``plan_key`` / ``build`` / ``prepare`` / ``run``,
  plus a ``name`` class attribute and an explicit ``supports_batch``;
* ``supports_batch = True`` additionally requires the batched trio
  ``build_batch`` / ``prepare_batch`` / ``run_batch``;
* ``supports_partition = True`` additionally requires the eight
  partition hooks the ooc driver calls;
* ``supports_fused_partition = True`` additionally requires the fused
  pair ``partition_move_fused`` / ``partition_split_fused`` (and only
  makes sense on top of ``supports_partition``).

Positional parameter *names* must match exactly — the engine and the
partition driver pass several of these by keyword.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import ModuleContext, Rule, dotted_name

# method -> expected positional parameter names (after self)
_SOLO = {
    "plan_key": ["config"],
    "build": ["bucket", "config"],
    "prepare": ["graph", "bucket", "config"],
    "run": ["plan", "inputs", "n_real", "init_labels", "init_active"],
}
_BATCH = {
    "build_batch": ["bucket", "config"],
    "prepare_batch": ["batch", "bucket", "config"],
    "run_batch": ["plan", "inputs", "init_labels", "init_active"],
}
_PARTITION = {
    "build_partition": ["config"],
    "partition_caps": ["budget", "d_bucket"],
    "partition_prepare_nbytes": ["shapes"],
    "prepare_partition": ["resident", "shapes", "config"],
    "partition_move": ["ops_ns", "inputs", "labels_loc", "cand_owned",
                       "seed", "bound"],
    "partition_wake": ["ops_ns", "inputs", "changed_loc"],
    "partition_split": ["ops_ns", "inputs", "comm_loc", "labels_loc",
                        "active_owned", "bound"],
    "partition_split_wake": ["ops_ns", "inputs", "comm_loc", "changed_loc"],
}
_FUSED_PARTITION = {
    "partition_move_fused": ["ops_ns", "inputs", "labels_loc", "changed_loc",
                             "active_owned", "cand_prev_owned", "klass_owned",
                             "seed", "bound"],
    "partition_split_fused": ["ops_ns", "inputs", "comm_loc", "labels_loc",
                              "changed_loc", "bound"],
}


def _registered_backend(cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        if isinstance(deco, ast.Call) \
                and dotted_name(deco.func) == "register_backend":
            return True
    return False


def _class_attr(cls: ast.ClassDef, attr: str):
    """(found, constant value or None) for a class-body assignment."""
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = [t for t in stmt.targets if isinstance(t, ast.Name)]
            value = stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target] if isinstance(stmt.target,
                                                  ast.Name) else []
            value = stmt.value
        else:
            continue
        if any(t.id == attr for t in targets):
            if isinstance(value, ast.Constant):
                return True, value.value
            return True, None
    return False, None


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return names


class ProtocolRule(Rule):
    id = "R003"
    tag = "protocol"
    description = ("registered backends must implement the full "
                   "build/prepare/run x solo/batch/partition surface with "
                   "reference parameter names")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("engine/backends/")

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        for cls in ast.walk(ctx.tree):
            if isinstance(cls, ast.ClassDef) and _registered_backend(cls):
                findings.extend(self._check_class(ctx, cls))
        return findings

    def _check_class(self, ctx: ModuleContext,
                     cls: ast.ClassDef) -> list[Finding]:
        out: list[Finding] = []
        methods = {stmt.name: stmt for stmt in cls.body
                   if isinstance(stmt, ast.FunctionDef)}

        has_name, _ = _class_attr(cls, "name")
        if not has_name:
            out.append(self.finding(
                ctx, cls, f"backend '{cls.name}' has no `name` class "
                f"attribute (registry reporting relies on it)"))
        has_sb, _ = _class_attr(cls, "supports_batch")
        if not has_sb:
            out.append(self.finding(
                ctx, cls, f"backend '{cls.name}' must declare "
                f"`supports_batch` explicitly (Engine.fit_many dispatches "
                f"on it; a missing attr reads as False by accident)"))

        required = dict(_SOLO)
        _, batch_val = _class_attr(cls, "supports_batch")
        if has_sb and batch_val:
            required.update(_BATCH)
        has_sp, part_val = _class_attr(cls, "supports_partition")
        if has_sp and part_val:
            required.update(_PARTITION)
        has_sf, fused_val = _class_attr(cls, "supports_fused_partition")
        if has_sf and fused_val:
            required.update(_FUSED_PARTITION)
            if not (has_sp and part_val):
                out.append(self.finding(
                    ctx, cls,
                    f"backend '{cls.name}' declares "
                    f"supports_fused_partition without supports_partition "
                    f"— the ooc driver only reaches the fused hooks "
                    f"through the partition sweep"))

        for meth, want in required.items():
            fn = methods.get(meth)
            if fn is None:
                out.append(self.finding(
                    ctx, cls,
                    f"backend '{cls.name}' is missing `{meth}"
                    f"({', '.join(want)})` — registry dispatch fails only "
                    f"at runtime"))
                continue
            got = _positional_params(fn)
            if got != want:
                out.append(self.finding(
                    ctx, fn,
                    f"backend '{cls.name}'.{meth} positional params "
                    f"({', '.join(got)}) drift from the protocol "
                    f"({', '.join(want)}) — keyword call sites in the "
                    f"engine/ooc driver will break"))
        return out
