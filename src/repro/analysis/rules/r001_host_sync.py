"""R001 — host-sync hazard in hot-path modules.

The paper-scale throughput story (one device dispatch per sweep, no
blocking readbacks) dies quietly when a ``int()`` / ``.item()`` /
``np.asarray()`` sneaks into a sweep loop: every iteration then stalls
on a device->host transfer.  This rule flags, inside the hot modules
(``core/``, ``kernels/``, ``engine/backends/``, ``partition/ooc.py``):

* **traced scopes** (functions handed to ``jax.jit`` / ``shard_map`` /
  ``lax.while_loop``): any concretizing call applied to a function
  parameter — under trace these raise ``TracerError`` at best and force
  a silent recompile-per-call at worst;
* **host-driven sweep loops**: concretizing calls applied to values
  produced by jitted sweep callables (``plan.step(...)``,
  ``sweeps.move(...)``, a ``jax.jit``/``make_*_step`` product) inside a
  ``for``/``while`` body — each one is a blocking sync per iteration.

Deliberate host-driven convergence checks (the sharded/distributed
drivers read one scalar per exchange round by design) carry an inline
``# lint: host-sync-ok — <why>`` suppression.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    ModuleContext,
    Rule,
    assigned_names,
    dotted_name,
    names_in,
)

_HOT_PREFIXES = ("core/", "kernels/", "engine/backends/")
_HOT_FILES = ("partition/ooc.py",)

_SCALARIZERS = {"int", "float", "bool"}
_SYNC_METHODS = {"item", "tolist"}
_NP_SYNC = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}

# Jitted sweep surfaces: receiver names holding compiled plans and the
# per-stage method names the backends/drivers dispatch through.
_PLAN_RECEIVERS = {"plan", "sweeps", "ops_ns"}
_SWEEP_METHODS = {"propagate", "split", "step", "move", "wake", "split_wake"}
_STEP_FACTORY = re.compile(r"^make_\w*step$")

_TRACING_CALLS = {"jax.jit", "jit", "shard_map", "pjit", "jax.pmap", "pmap"}
_LOOP_PRIMITIVES = {"jax.lax.while_loop", "lax.while_loop",
                    "jax.lax.scan", "lax.scan",
                    "jax.lax.fori_loop", "lax.fori_loop"}


def _is_jit_wrapping(call: ast.Call) -> bool:
    """Call expression that produces a traced callable from its args:
    jax.jit(f), partial(jax.jit, ...), shard_map(f, ...)."""
    name = dotted_name(call.func)
    if name in _TRACING_CALLS:
        return True
    if name in ("partial", "functools.partial") and call.args:
        return dotted_name(call.args[0]) in _TRACING_CALLS
    return False


def _sync_call(node: ast.Call) -> tuple[str, ast.AST] | None:
    """(op description, value expression) when ``node`` forces a sync."""
    func = node.func
    if isinstance(func, ast.Name) and func.id in _SCALARIZERS and node.args:
        return f"{func.id}()", node.args[0]
    if isinstance(func, ast.Attribute) and func.attr in _SYNC_METHODS:
        return f".{func.attr}()", func.value
    name = dotted_name(func)
    if name in _NP_SYNC and node.args:
        return f"{name}()", node.args[0]
    return None


class HostSyncRule(Rule):
    id = "R001"
    tag = "host-sync"
    description = ("device->host sync hazards (int()/.item()/np.asarray on "
                   "traced or device values) in hot-path sweep code")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(_HOT_PREFIXES) or relpath in _HOT_FILES

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        traced = _traced_functions(ctx.tree)
        for fn in _all_functions(ctx.tree):
            if fn in traced:
                findings.extend(self._check_traced(ctx, fn))
            findings.extend(self._check_host_loops(ctx, fn))
        return findings

    # --- traced scopes ---

    def _check_traced(self, ctx: ModuleContext,
                      fn: ast.FunctionDef) -> list[Finding]:
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)}
        out = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            sync = _sync_call(node)
            if sync is None:
                continue
            op, value = sync
            hit = params & names_in(value)
            if hit:
                out.append(self.finding(
                    ctx, node,
                    f"{op} on traced value '{sorted(hit)[0]}' inside "
                    f"jit-traced '{fn.name}' — concretizes a tracer "
                    f"(TracerError or a recompile per call)"))
        return out

    # --- host-driven sweep loops ---

    def _check_host_loops(self, ctx: ModuleContext,
                          fn: ast.FunctionDef) -> list[Finding]:
        tainted = _device_tainted_names(fn)
        if not tainted:
            return []
        out = []
        for loop in (n for n in ast.walk(fn)
                     if isinstance(n, (ast.For, ast.While))):
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                sync = _sync_call(node)
                if sync is None:
                    continue
                op, value = sync
                hit = tainted & names_in(value)
                if hit:
                    out.append(self.finding(
                        ctx, node,
                        f"{op} on device value '{sorted(hit)[0]}' inside a "
                        f"sweep loop in '{fn.name}' — blocking device->host "
                        f"transfer every iteration"))
        # one finding per location (nested loops walk the same nodes twice)
        seen: set[tuple[int, int]] = set()
        uniq = []
        for f in out:
            if (f.line, f.col) not in seen:
                seen.add((f.line, f.col))
                uniq.append(f)
        return uniq


def _all_functions(tree: ast.Module) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]


def _traced_functions(tree: ast.Module) -> set[ast.FunctionDef]:
    """Functions whose bodies run under jax tracing.

    Detected from: ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators,
    ``jax.jit(f)`` / ``shard_map(f, ...)`` call sites naming a local
    function, and cond/body arguments of ``lax.while_loop`` & friends.
    """
    by_name: dict[str, ast.FunctionDef] = {}
    for fn in _all_functions(tree):
        by_name[fn.name] = fn

    traced: set[ast.FunctionDef] = set()
    for fn in _all_functions(tree):
        for deco in fn.decorator_list:
            if dotted_name(deco) in _TRACING_CALLS:
                traced.add(fn)
            elif isinstance(deco, ast.Call) and _is_jit_wrapping(deco):
                traced.add(fn)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_jit_wrapping(node):
            for arg in node.args:
                name = dotted_name(arg)
                if name in by_name:
                    traced.add(by_name[name])
        elif dotted_name(node.func) in _LOOP_PRIMITIVES:
            for arg in node.args[:2]:   # cond, body
                name = dotted_name(arg)
                if name in by_name:
                    traced.add(by_name[name])
    return traced


def _device_tainted_names(fn: ast.FunctionDef) -> set[str]:
    """Names in ``fn`` holding results of jitted sweep callables.

    Seeds: ``plan.step(...)``-style dispatches and calls through names
    bound to ``jax.jit(...)`` / ``make_*_step(...)`` products; taint then
    propagates through plain assignments until fixpoint.
    """
    jitted_callables: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            made = _is_jit_wrapping(call)
            fname = dotted_name(call.func)
            if fname and _STEP_FACTORY.match(fname.rsplit(".", 1)[-1]):
                made = True
            if made:
                for t in node.targets:
                    jitted_callables.update(assigned_names(t))

    def is_seed(call: ast.Call) -> bool:
        func = call.func
        if isinstance(func, ast.Attribute):
            root = func.value
            if (func.attr in _SWEEP_METHODS and isinstance(root, ast.Name)
                    and root.id in _PLAN_RECEIVERS):
                return True
        if isinstance(func, ast.Name) and func.id in jitted_callables:
            return True
        return False

    tainted: set[str] = set()
    for _ in range(10):
        before = len(tainted)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            else:
                continue
            seed = any(is_seed(c) for c in ast.walk(value)
                       if isinstance(c, ast.Call))
            if seed or (tainted & names_in(value)):
                for t in targets:
                    tainted.update(assigned_names(t))
        if len(tainted) == before:
            break
    return tainted
