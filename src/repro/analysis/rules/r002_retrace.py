"""R002 — retrace hazard: ad-hoc jit and stringified cache keys.

One XLA trace per shape bucket is the engine's core perf contract
(``tests/test_engine.py`` pins it; the trace auditor generalizes it).
Two code shapes silently break it:

* **ad-hoc ``jax.jit`` outside compile-owning modules** — a jit created
  in glue/driver code closes over raw Python shapes instead of going
  through ``engine/bucketing.py``; every new (n, m) pair is a fresh
  trace and the compile cache never sees it.  Compile-owning modules
  (``engine/backends/``, ``kernels/``, ``core/``) are allowlisted: that
  is where jits are *supposed* to be created, keyed by bucket.
* **stringified compile-cache keys** — an f-string / ``str()`` /
  ``.format()`` key handed to ``CompileCache.get_or_build`` collapses
  structurally different statics into one string (or worse, embeds a
  repr that differs per object identity).  Keys must stay structured
  hashable tuples so bucket/config equality is what drives reuse.

Justified one-off jits (e.g. a serving session's prefill/decode pair,
jitted once per process) carry ``# lint: retrace-ok — <why>``.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import ModuleContext, Rule, dotted_name

# Modules whose whole purpose is creating jitted executables keyed by
# shape bucket.  Everything else in src/repro is glue and must route
# compilation through the engine.
_COMPILE_OWNING = ("engine/backends/", "kernels/", "core/",
                   "parallel/", "models/", "train/", "optim/")

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pmap", "pmap"}


def _is_jit_site(node: ast.AST) -> bool:
    if dotted_name(node) in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name in _JIT_NAMES:
            return True
        if name in ("partial", "functools.partial") and node.args:
            return dotted_name(node.args[0]) in _JIT_NAMES
    return False


def _stringified(node: ast.AST) -> str | None:
    """Describe the first string-building construct under ``node``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.JoinedStr):
            return "f-string"
        if isinstance(sub, ast.Call):
            if isinstance(sub.func, ast.Name) and sub.func.id in ("str",
                                                                  "repr"):
                return f"{sub.func.id}()"
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr == "format":
                return ".format()"
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
            left = sub.left
            if isinstance(left, ast.Constant) and isinstance(left.value, str):
                return "%-format"
    return None


class RetraceRule(Rule):
    id = "R002"
    tag = "retrace"
    description = ("retrace hazards: jax.jit outside compile-owning modules "
                   "and stringified compile-cache keys bypassing bucketing")

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        if not ctx.relpath.startswith(_COMPILE_OWNING):
            findings.extend(self._check_adhoc_jit(ctx))
        findings.extend(self._check_cache_keys(ctx))
        return findings

    def _check_adhoc_jit(self, ctx: ModuleContext) -> list[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            site = None
            if isinstance(node, ast.FunctionDef):
                for deco in node.decorator_list:
                    if _is_jit_site(deco):
                        site = deco
                        break
            elif isinstance(node, ast.Call) and _is_jit_site(node):
                site = node
            if site is not None:
                out.append(self.finding(
                    ctx, site,
                    f"jax.jit created in non-compile-owning module "
                    f"'{ctx.relpath}' — specializes on raw Python shapes, "
                    f"bypassing engine/bucketing.py and the CompileCache; "
                    f"route through Engine/backend build() instead"))
        return out

    def _check_cache_keys(self, ctx: ModuleContext) -> list[Finding]:
        out = []
        # function-local (and module-level) Name -> assigned value, for
        # resolving `key = (...); cache.get_or_build(key, ...)`
        assigns: dict[str, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                assigns[node.targets[0].id] = node.value
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "get_or_build" and node.args):
                continue
            key = node.args[0]
            if isinstance(key, ast.Name) and key.id in assigns:
                key = assigns[key.id]
            how = _stringified(key)
            if how:
                out.append(self.finding(
                    ctx, node.args[0],
                    f"compile-cache key built with {how} — stringified keys "
                    f"collapse distinct statics (or embed per-object reprs) "
                    f"and defeat bucket reuse; use a structured tuple key"))
        return out
