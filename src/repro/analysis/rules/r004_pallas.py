"""R004 — Pallas kernel hygiene.

Four checks on every ``pl.pallas_call`` site in ``kernels/``:

* **divisibility guard**: the wrapper function must assert (or
  if-raise) a ``%``-divisibility relation before launching — a grid of
  ``n // tile`` with ``n % tile != 0`` silently drops the tail rows on
  TPU rather than erroring (guide: grid x BlockSpec must tile the padded
  array exactly).
* **host ops in the kernel body**: ``np.*`` / ``print`` / ``.item()``
  inside the kernel function run at trace time on the host — at best a
  constant bake-in, at worst a TracerError on Mosaic lowering.
* **VMEM footprint**: when every BlockSpec block shape resolves to int
  literals (directly or via module constants), the per-step resident
  estimate (4 bytes/elem across in+out blocks) must stay under a
  configurable ceiling (default 16 MB of the ~64 MB/core budget —
  headroom for double-buffering and scratch).  Symbolic shapes (the
  production kernels size blocks from runtime args) are skipped.
* **equality-cube budget**: a kernel that materialises the (B, D, D)
  equality cube (``lab[:, :, None] == lab[:, None, :]``, directly or via
  the shared ``argmax_tile_math`` tile math) allocates VMEM the
  BlockSpecs never see — its wrapper must assert the cube product
  against a budget (``tile_b * d * d * 4 <= CUBE_BUDGET_BYTES``) before
  launching, or an oversized tile choice OOMs only at Mosaic compile
  time on hardware.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    ModuleContext,
    Rule,
    _const_int,
    dotted_name,
    function_map,
    module_int_constants,
)

_DEFAULT_VMEM_CEILING = 16 * 2 ** 20   # bytes per grid step, in+out blocks

_HOST_ROOTS = {"np", "numpy"}
_HOST_METHODS = {"item", "tolist"}


def _is_pallas_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name is not None and name.split(".")[-1] == "pallas_call"


def _resolve_kernel(call: ast.Call,
                    by_name: dict[str, ast.FunctionDef]
                    ) -> ast.FunctionDef | None:
    """The kernel function passed as pallas_call's first argument
    (through a ``partial(kernel, ...)`` wrapper if present)."""
    if not call.args:
        return None
    target = call.args[0]
    if isinstance(target, ast.Call) \
            and dotted_name(target.func) in ("partial", "functools.partial") \
            and target.args:
        target = target.args[0]
    name = dotted_name(target)
    return by_name.get(name) if name else None


# Shared tile-math helpers known to build the (B, D, D) equality cube;
# fused_sweep.py imports argmax_tile_math so the cube never appears
# literally in its kernel bodies.
_CUBE_HELPERS = {"argmax_tile_math"}


def _is_rank3_broadcast(node: ast.expr) -> bool:
    """``x[:, :, None]``-style subscript: >=3-elt slice tuple with None."""
    if not isinstance(node, ast.Subscript) \
            or not isinstance(node.slice, ast.Tuple) \
            or len(node.slice.elts) < 3:
        return False
    return any(isinstance(e, ast.Constant) and e.value is None
               for e in node.slice.elts)


def _materialises_cube(fn: ast.FunctionDef,
                       by_name: dict[str, ast.FunctionDef],
                       _seen: set[str] | None = None) -> bool:
    """Equality-cube pattern in ``fn``, directly (a compare of two rank-3
    broadcast subscripts) or through module-local / shared helpers."""
    _seen = set() if _seen is None else _seen
    if fn.name in _seen:
        return False
    _seen.add(fn.name)
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            sides = [node.left, *node.comparators]
            if sum(_is_rank3_broadcast(s) for s in sides) >= 2:
                return True
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            leaf = name.split(".")[-1] if name else None
            if leaf in _CUBE_HELPERS:
                return True
            local = by_name.get(leaf) if leaf else None
            if local is not None \
                    and _materialises_cube(local, by_name, _seen):
                return True
    return False


def _has_cube_budget_assert(fn: ast.FunctionDef) -> bool:
    """An assert bounding a product: contains both a ``*`` and a
    ``<``/``<=`` (the ``tile_b * d * d * 4 <= BUDGET`` shape)."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assert):
            continue
        sub = list(ast.walk(node.test))
        has_mult = any(isinstance(s, ast.BinOp)
                       and isinstance(s.op, ast.Mult) for s in sub)
        has_bound = any(isinstance(s, ast.Compare)
                        and any(isinstance(op, (ast.Lt, ast.LtE))
                                for op in s.ops) for s in sub)
        if has_mult and has_bound:
            return True
    return False


def _has_divisibility_guard(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        test = None
        if isinstance(node, ast.Assert):
            test = node.test
        elif isinstance(node, ast.If) \
                and any(isinstance(b, ast.Raise) for b in node.body):
            test = node.test
        if test is not None and any(
                isinstance(s, ast.BinOp) and isinstance(s.op, ast.Mod)
                for s in ast.walk(test)):
            return True
    return False


def _block_nbytes(call: ast.Call, env: dict[str, int]) -> int | None:
    """Summed in+out block bytes when every BlockSpec shape is concrete;
    None as soon as one dimension stays symbolic."""
    total = 0
    seen = False
    for node in ast.walk(call):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func) is not None
                and dotted_name(node.func).split(".")[-1] == "BlockSpec"
                and node.args):
            continue
        shape = node.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return None
        elems = 1
        for dim in shape.elts:
            v = _const_int(dim, env)
            if v is None:
                return None
            elems *= v
        total += elems * 4
        seen = True
    return total if seen else None


class PallasRule(Rule):
    id = "R004"
    tag = "pallas"
    description = ("pallas_call hygiene: grid divisibility guard, no host "
                   "ops in kernel bodies, VMEM block footprint ceiling, "
                   "equality-cube budget assert")

    def __init__(self, vmem_ceiling: int = _DEFAULT_VMEM_CEILING):
        self.vmem_ceiling = int(vmem_ceiling)

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("kernels/")

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings: list[Finding] = []
        owner = function_map(ctx.tree)
        consts = module_int_constants(ctx.tree)
        by_name = {n.name: n for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.FunctionDef)}
        checked_kernels: set[int] = set()

        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
                continue

            wrapper = owner.get(id(node))
            if wrapper is None or not _has_divisibility_guard(wrapper):
                where = f"'{wrapper.name}'" if wrapper else "module scope"
                findings.append(self.finding(
                    ctx, node,
                    f"pallas_call in {where} without a grid-divisibility "
                    f"guard (assert/raise on `% tile == 0`) — a non-tiling "
                    f"grid silently drops tail rows on TPU"))

            kernel = _resolve_kernel(node, by_name)
            if kernel is not None and id(kernel) not in checked_kernels:
                checked_kernels.add(id(kernel))
                findings.extend(self._check_kernel_body(ctx, kernel))

            if kernel is not None \
                    and _materialises_cube(kernel, by_name) \
                    and (wrapper is None
                         or not _has_cube_budget_assert(wrapper)):
                findings.append(self.finding(
                    ctx, node,
                    f"kernel '{kernel.name}' materialises the (B, D, D) "
                    f"equality cube — VMEM the BlockSpecs never see — but "
                    f"its wrapper has no cube-budget assert "
                    f"(`tile_b * d * d * 4 <= CUBE_BUDGET_BYTES`)"))

            nbytes = _block_nbytes(node, consts)
            if nbytes is not None and nbytes > self.vmem_ceiling:
                findings.append(self.finding(
                    ctx, node,
                    f"pallas_call block footprint ~{nbytes // 1024} KiB "
                    f"exceeds the VMEM ceiling "
                    f"({self.vmem_ceiling // 1024} KiB) — shrink the block "
                    f"shapes or raise --vmem-ceiling with a justification"))
        return findings

    def _check_kernel_body(self, ctx: ModuleContext,
                           kernel: ast.FunctionDef) -> list[Finding]:
        out = []
        for node in ast.walk(kernel):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            bad = None
            if name and name.split(".")[0] in _HOST_ROOTS:
                bad = f"{name}()"
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                bad = "print()"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _HOST_METHODS:
                bad = f".{node.func.attr}()"
            if bad:
                out.append(self.finding(
                    ctx, node,
                    f"host op {bad} inside pallas kernel '{kernel.name}' — "
                    f"kernel bodies lower through Mosaic; host calls run at "
                    f"trace time (constant bake-in) or fail to lower"))
        return out
