"""Rule framework: per-module AST context + the Rule base class.

Each rule is a stateless object with an ``id`` (``R001``..), a
``tag`` (the suppression token: ``# lint: host-sync-ok`` silences a
``host-sync`` finding on that line or the line above), an ``applies``
path predicate, and a ``check(ctx)`` returning findings.

Suppression syntax (checked against the finding's line and the line
immediately above it, so it works for multi-line expressions)::

    if int(dn) <= threshold:  # lint: host-sync-ok — host-driven loop
        break

A suppression should always carry a justification after the token; the
linter reports suppressed findings separately so reviewers can audit
them (``python -m repro.launch.lint --show-suppressed``).
"""
from __future__ import annotations

import ast
import re
import tokenize
from io import StringIO

from repro.analysis.findings import Finding

_SUPPRESS_RE = re.compile(r"lint:\s*([a-z0-9-]+(?:\s*,\s*[a-z0-9-]+)*)")


def _parse_suppressions(source: str) -> dict[int, set[str]]:
    """Map line number -> suppression tags found in comments on that line.

    Tokenized rather than regexed over raw lines so a ``# lint: ...-ok``
    inside a string literal is not treated as a suppression.
    """
    out: dict[int, set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                tags = {t.strip() for t in m.group(1).split(",")}
                out.setdefault(tok.start[0], set()).update(tags)
    except (tokenize.TokenError, IndentationError):
        pass
    return out


class ModuleContext:
    """Parsed view of one module handed to every rule."""

    def __init__(self, relpath: str, source: str, tree: ast.Module):
        self.relpath = relpath
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.suppressions = _parse_suppressions(source)

    @classmethod
    def from_source(cls, source: str, relpath: str) -> "ModuleContext":
        return cls(relpath, source, ast.parse(source))

    def is_suppressed(self, line: int, tag: str) -> bool:
        token = f"{tag}-ok"
        for ln in (line, line - 1):
            if token in self.suppressions.get(ln, ()):
                return True
        return False


class Rule:
    """Base class: concrete rules set id/tag/description and check()."""

    id: str = ""
    tag: str = ""
    description: str = ""

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, ctx: ModuleContext) -> list[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST,
                message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule=self.id, path=ctx.relpath, line=line, col=col,
                       message=message,
                       suppressed=ctx.is_suppressed(line, self.tag))


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> set[str]:
    """All Name identifiers referenced anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def assigned_names(target: ast.AST) -> list[str]:
    """Flat list of Name targets in an assignment target (handles
    tuple/list unpacking and starred targets)."""
    out: list[str] = []
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.append(n.id)
    return out


def function_map(tree: ast.Module) -> dict[int, ast.FunctionDef]:
    """``id(node) -> innermost enclosing FunctionDef`` for every node.

    ``ast.walk`` yields outer functions before nested ones, so a nested
    function's sweep overwrites its subtree with the tighter owner.
    """
    owner: dict[int, ast.FunctionDef] = {}
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef):
            for sub in ast.walk(fn):
                owner[id(sub)] = fn
    return owner


def module_int_constants(tree: ast.Module) -> dict[str, int]:
    """Module-level ``NAME = <int literal or shift/mult expr>`` bindings."""
    out: dict[str, int] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            val = _const_int(stmt.value, {})
            if val is not None:
                out[stmt.targets[0].id] = val
    return out


def _const_int(node: ast.AST, env: dict[str, int]) -> int | None:
    """Evaluate an int-valued literal expression (+-*//<<** over literals
    and names in ``env``); None when symbolic."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.BinOp):
        left = _const_int(node.left, env)
        right = _const_int(node.right, env)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.FloorDiv):
                return left // right
            if isinstance(node.op, ast.LShift):
                return left << right
            if isinstance(node.op, ast.Pow):
                return left ** right
        except (ZeroDivisionError, OverflowError, ValueError):
            return None
    return None
