"""Rule registry: the six hot-path contract rules, in ID order."""
from repro.analysis.rules.base import ModuleContext, Rule
from repro.analysis.rules.r001_host_sync import HostSyncRule
from repro.analysis.rules.r002_retrace import RetraceRule
from repro.analysis.rules.r003_protocol import ProtocolRule
from repro.analysis.rules.r004_pallas import PallasRule
from repro.analysis.rules.r005_ledger import LedgerRule
from repro.analysis.rules.r006_telemetry import TelemetryRule


def all_rules(vmem_ceiling: int | None = None) -> list[Rule]:
    """Fresh rule instances (PallasRule carries the VMEM ceiling knob)."""
    pallas = PallasRule() if vmem_ceiling is None \
        else PallasRule(vmem_ceiling)
    return [HostSyncRule(), RetraceRule(), ProtocolRule(), pallas,
            LedgerRule(), TelemetryRule()]


__all__ = ["ModuleContext", "Rule", "HostSyncRule", "RetraceRule",
           "ProtocolRule", "PallasRule", "LedgerRule", "TelemetryRule",
           "all_rules"]
