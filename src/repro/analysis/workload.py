"""The representative audit workload: every execution path, twice.

Each leg runs a *cold* fit (one trace per stage is expected) followed by
same-bucket / warm / repeat traffic that must be trace-free:

* solo cold + same-bucket second graph + warm refit (segment, tile);
* batched ``fit_many`` twice over the same batch bucket;
* fused tile sweeps (``fuse_sweeps="on"``): solo cold + same-bucket,
  batched, and an ooc fit — their own dispatch family and trace tags;
* sharded solo (single-device mesh) cold + same-bucket;
* out-of-core partitioned fit, cold + warm repeat (segment, tile) —
  segment auto-fuses its partition sweeps, tile under kernel_mode=ref
  does not, so an explicit ``fuse_sweeps="off"`` segment leg keeps the
  unfused ``part_move``/``part_wake`` stages covered too.

Sized to stay cheap enough for CI (a few hundred vertices per graph)
while still exercising the compile cache across every dispatch family.
"""
from __future__ import annotations

from typing import Any

from repro.analysis.trace_audit import TraceAudit


def _tight_budget(graph, backend: str) -> int:
    """Well under the in-core edge bytes, so the fit must partition
    (tile's floor covers one dense (8, d_bucket) tile)."""
    from repro.partition.ooc import IN_CORE_EDGE_BYTES
    in_core = graph.m_pad * IN_CORE_EDGE_BYTES
    if backend == "tile":
        return max(in_core // 2, 20_000)
    return in_core // 3


def run_workload(include_sharded: bool = True,
                 include_ooc: bool = True) -> dict[str, Any]:
    """Run the audit workload; returns simple coverage counters."""
    from repro.engine import CompileCache, Engine, EngineConfig
    from repro.graphgen import erdos_renyi

    eng = Engine(EngineConfig(warm_start="auto"), cache=CompileCache())
    g1 = erdos_renyi(200, 5.0, seed=1)
    g2 = erdos_renyi(230, 5.0, seed=2)   # same pow2 bucket as g1
    fits = 0

    for backend in ("segment", "tile"):
        eng.fit(g1, backend=backend)             # cold: traces expected
        eng.fit(g2, backend=backend)             # same bucket: cache hit
        r = eng.fit(g2, backend=backend)         # warm refit
        assert r.warm_started and r.cache_hit
        eng.fit_many([g1, g2], backend=backend)  # batched cold
        eng.fit_many([g2, g1], backend=backend)  # same batch bucket
        fits += 7

    # fused tile sweeps (fuse_sweeps="on" forces fusion under the ref
    # dispatch): solo cold + same-bucket + batched — the *_fused stages
    feng = Engine(EngineConfig(warm_start="auto", fuse_sweeps="on"),
                  cache=CompileCache())
    feng.fit(g1, backend="tile")
    r = feng.fit(g2, backend="tile")
    assert r.cache_hit
    feng.fit_many([g1, g2], backend="tile")
    fits += 3

    if include_sharded:
        eng.fit(g1, backend="sharded")
        r = eng.fit(g2, backend="sharded")
        assert r.cache_hit
        fits += 2

    if include_ooc:
        # denser graph: tile's budget floor (one dense tile, ~20 KB) must
        # stay well under the in-core edge bytes or nothing partitions
        g3 = erdos_renyi(400, 16.0, seed=4)
        for backend in ("segment", "tile"):
            budget = _tight_budget(g3, backend)
            r = eng.fit(g3, backend=backend, memory_budget=budget)
            assert r.partitions > 1, "budget did not force partitioning"
            r = eng.fit(g3, backend=backend, memory_budget=budget)
            assert r.warm_started
            fits += 2
        # the other half of the fused matrix: under fuse_sweeps="auto"
        # segment fused above (jnp compositions profit everywhere) while
        # tile stayed unfused (ref dispatch) — so run unfused segment
        # and fused tile partition sweeps explicitly
        oeng = Engine(EngineConfig(warm_start="auto", fuse_sweeps="off"),
                      cache=CompileCache())
        r = oeng.fit(g3, backend="segment",
                     memory_budget=_tight_budget(g3, "segment"))
        assert r.partitions > 1
        r = feng.fit(g3, backend="tile",
                     memory_budget=_tight_budget(g3, "tile"))
        assert r.partitions > 1
        fits += 2

    return {"fits": fits, "sharded": include_sharded, "ooc": include_ooc}


def audit_workload(include_sharded: bool = True,
                   include_ooc: bool = True) -> TraceAudit:
    """Run the workload under a :class:`TraceAudit`; caller inspects
    ``report()`` / ``assert_no_excess()``."""
    with TraceAudit() as audit:
        coverage = run_workload(include_sharded=include_sharded,
                                include_ooc=include_ooc)
    audit.coverage = coverage
    return audit
