"""Logical->physical sharding rules per (arch, shape, mesh) — DESIGN.md §6.

Baseline policy:
  batch        -> ('pod', 'data')     (DP; pod is just more DP)
  heads/ff/vocab -> 'model'           (TP)
  kv_heads     -> 'model' iff divisible, else replicated (GQA kv < TP)
  expert       -> 'model' (<= TP experts) or 'data' (Arctic 128e: EP over
                  data, ff stays TP over model -> 256-way expert weights)
  seq_kv       -> ('pod', 'data') only for batch-1 long-context decode (SP)
  everything else replicated

Optimizer state (ZeRO-1): same as the parameter but with ('pod','data')
claimed on the first divisible unsharded dim — grads reduce-scatter, the
update runs on 1/DP of the state, params all-gather back.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES
from repro.parallel.api import MeshRules


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, a) for a in name]))
    return mesh.shape[name]


def data_axes(mesh: Mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def make_rules(mesh: Mesh, cfg: ArchConfig, shape: str) -> MeshRules:
    tp = _axis_size(mesh, "model")
    sp = SHAPES[shape]
    batch_axes = data_axes(mesh)
    dp = _axis_size(mesh, batch_axes)

    mapping: dict = {
        "embed": None,
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "layers": None,
        "heads": "model" if (cfg.n_heads_padded % tp == 0) else None,
        "kv_heads": "model" if (cfg.n_kv_padded % tp == 0) else None,
    }
    if cfg.moe_experts:
        # Prefer EP over 'data' with TP over 'ff' inside each expert:
        # expert weights then shard dp x tp ways (Arctic: 937 GB bf16 ->
        # 3.7 GB/device) and dispatch lowers to a data-axis all-to-all.
        # Fallback: EP over 'model' (ff replicated within the expert).
        ep = _axis_size(mesh, "data")
        ff = cfg.moe_ff or cfg.d_ff
        if cfg.moe_experts_padded % ep == 0 and ff % tp == 0:
            mapping["expert"] = "data"
        elif cfg.moe_experts_padded % tp == 0:
            mapping["expert"] = "model"
        else:
            mapping["expert"] = "data"
    # Serving with replicated kv heads (GQA kv < TP): shard the cache on
    # head_dim instead — the model axis otherwise idles while the KV cache
    # (the dominant serving state) is replicated 16x.  The per-step cost is
    # a tiny partial-sum all-reduce of (B,1,...) logits; the win is cache
    # bytes/device / tp (§Perf decode iteration 2).
    if sp.step in ("prefill", "decode") and mapping["kv_heads"] is None \
            and cfg.head_dim % tp == 0:
        mapping["head_dim"] = "model"
    if sp.global_batch % dp == 0 and sp.global_batch >= dp:
        mapping["batch"] = batch_axes
        mapping["seq_kv"] = None
    else:
        # batch-1 long-context decode: sequence-parallel cache (SP)
        mapping["batch"] = None
        mapping["seq_kv"] = batch_axes
    return MeshRules(mesh=mesh, mapping=mapping)


def param_shardings(rules: MeshRules, axes_tree):
    """Pytree of NamedShardings from a logical-axes pytree."""
    import jax
    return jax.tree.map(
        lambda ax: rules.sharding(tuple(ax)), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def zero1_shardings(rules: MeshRules, axes_tree, shapes_tree):
    """Optimizer-state shardings: param spec + 'data' on a divisible dim."""
    import jax
    mesh = rules.mesh
    dp_axes = data_axes(mesh)
    dp = _axis_size(mesh, dp_axes)

    def one(ax, shaped):
        spec = list(rules.spec(tuple(ax)))
        spec += [None] * (len(shaped.shape) - len(spec))
        used = set()
        for s in spec:
            used.update(s if isinstance(s, tuple) else (s,))
        if not any(a in used for a in dp_axes):
            for i, (s, dim) in enumerate(zip(spec, shaped.shape)):
                shard = _axis_size(mesh, s) if s else 1
                if dim % (shard * dp) == 0:
                    spec[i] = (tuple([*(s if isinstance(s, tuple) else
                                        ([s] if s else []))] + list(dp_axes))
                               if s else dp_axes)
                    break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def cache_logical_axes(cfg: ArchConfig, caches_tree):
    """Logical axes for decode caches, by array rank/shape heuristics.

    KV caches are (G, B, S_max, K, hd) (stacked over scan groups); mamba
    states (G, B, d_inner, d_state); rwkv (G, B, H, hd, hd) / (G, B, d).
    Leaves are PartitionSpecs of *logical* names (P is a safe pytree leaf;
    plain tuples collide with NamedTuple cache nodes).
    """
    import jax

    def one(x):
        shp = x.shape
        if len(shp) == 5 and shp[4] == 1:          # (G,B,S,K,1) int8 scales
            return P("layers", "batch", "seq_kv", "kv_heads", None)
        if len(shp) == 5 and shp[2] > shp[3]:      # (G,B,S,K,hd) kv cache
            return P("layers", "batch", "seq_kv", "kv_heads", "head_dim")
        if len(shp) == 5:                          # (G,B,H,hd,hd) rwkv wkv
            return P("layers", "batch", "heads", None, None)
        if len(shp) == 4 and shp[2] == cfg.d_inner:  # (G,B,di,ds) mamba h
            return P("layers", "batch", "ff", None)
        if len(shp) == 4:                          # (G,B,conv,di)
            return P("layers", "batch", None, "ff")
        if len(shp) == 3:                          # (G,B,d) shifts
            return P("layers", "batch", None)
        if len(shp) == 2:
            return P("layers", "batch")
        return P(*([None] * len(shp)))

    return jax.tree.map(one, caches_tree)
