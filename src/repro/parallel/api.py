"""Logical-axis sharding hints usable from pure model code.

Model code calls ``shard_hint(x, 'batch', None, 'embed')`` with *logical*
axis names; the active :class:`MeshRules` context (installed by the step
builders in ``repro.train``) translates them to physical
``with_sharding_constraint``s.  With no context installed the hint is a
no-op, so model code runs unmodified on a single CPU device in tests.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("mesh_rules",
                                                         default=None)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical -> physical axis mapping (DESIGN.md §6)."""
    mesh: Mesh
    mapping: dict

    def spec(self, logical: tuple) -> P:
        phys = []
        used = set()
        for ax in logical:
            m = self.mapping.get(ax) if ax is not None else None
            # an axis may be claimed at most once per spec
            if m is None or (isinstance(m, str) and m in used) or (
                    isinstance(m, tuple) and any(a in used for a in m)):
                phys.append(None)
            else:
                phys.append(m)
                used.update(m if isinstance(m, tuple) else (m,))
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def sharding(self, logical: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_rules() -> MeshRules | None:
    return _ACTIVE.get()


def shard_hint(x, *logical):
    rules = _ACTIVE.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(tuple(logical)))
