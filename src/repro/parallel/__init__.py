from repro.parallel.api import (  # noqa: F401
    MeshRules,
    active_rules,
    shard_hint,
    use_rules,
)
