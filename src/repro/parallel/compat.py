"""Version-compat shims for jax APIs that moved between releases.

The repo targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); on older jax
(0.4.x, as baked into this container) those fall back to
``jax.experimental.shard_map`` / ``check_rep`` and an ``axis_types``-free
``make_mesh``.  All mesh and shard_map construction in the repo goes
through this module so the compat logic lives in exactly one place.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with the replication-check kwarg spelled per-version."""
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n_axes`` where supported, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (jax 0.4.x returns a one-element list of dicts, newer jax a dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across its two historical signatures."""
    cls = jax.sharding.AbstractMesh
    try:  # modern: (axis_sizes, axis_names, axis_types=...)
        axis_types = auto_axis_types(len(axes))
        kw = {} if axis_types is None else {"axis_types": axis_types}
        return cls(tuple(shape), tuple(axes), **kw)
    except TypeError:  # jax 0.4.x: (((name, size), ...),)
        return cls(tuple(zip(axes, shape)))


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types when the jax version has them."""
    axis_types = auto_axis_types(len(axes))
    kw = {} if axis_types is None else {"axis_types": axis_types}
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)
