"""Topology-agnostic, atomic, keep-k checkpointing (fault-tolerance core).

Design (DESIGN.md §6):
  * checkpoints store *logical* (unsharded) named arrays + a JSON manifest
    with content hashes — restart may use a different mesh shape (elastic):
    the loader ``device_put``s every leaf onto the *new* shardings;
  * writes go to ``<dir>/tmp-<step>`` then atomically ``rename`` to
    ``step-<step>`` — a crash mid-write never corrupts the latest visible
    checkpoint;
  * ``save(..., blocking=False)`` hands the host copy to a writer thread so
    the train loop overlaps checkpoint I/O with the next steps;
  * ``keep`` retains the newest k checkpoints (the restart window).

Arrays are gathered to host numpy before writing — on a real pod this is
the per-host shard gather; in this container it is a trivial copy.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten_named(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        v = np.asarray(leaf)
        if v.dtype.kind == "V" or "bfloat16" in str(v.dtype):
            # npz cannot store ml_dtypes types; store the raw bits
            v = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
        out[name] = v
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = True) -> None:
        named = _flatten_named(tree)   # host copy happens here (sync point)
        if self._thread is not None:
            self._thread.join()        # one in-flight write at a time
            self._thread = None
        if blocking:
            self._write(step, named, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, named, extra or {}),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, named: dict, extra: dict) -> None:
        tmp = self.dir / f"tmp-{step}"
        final = self.dir / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "arrays": {}}
        with open(tmp / "arrays.npz", "wb") as f:
            np.savez(f, **named)
        digest = hashlib.sha256((tmp / "arrays.npz").read_bytes()).hexdigest()
        for k, v in named.items():
            manifest["arrays"][k] = {"shape": list(v.shape),
                                     "dtype": str(v.dtype)}
        manifest["sha256"] = digest
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)              # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("-", 1)[1])
                      for p in self.dir.glob("step-*") if p.is_dir())

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_named(self, step: int | None = None, verify: bool = True
                   ) -> tuple[dict[str, np.ndarray], int, dict]:
        """Load a checkpoint's raw named arrays without a target tree.

        ``restore`` needs a structurally-matching template with known
        shapes/dtypes; state whose shape only the checkpoint knows (the
        serving tier's per-tenant warm labels — one array per tenant,
        lengths set by each tenant's graph) loads through this instead.
        Returns ``(name -> host array, step, extra)`` with the same
        content-hash verification as ``restore``.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        if verify:
            digest = hashlib.sha256((d / "arrays.npz").read_bytes()
                                    ).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"checkpoint step-{step} hash mismatch")
        with np.load(d / "arrays.npz") as data:
            named = {k: data[k] for k in data.files}
        return named, step, manifest.get("extra", {})

    def restore(self, target_tree, step: int | None = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``target_tree``; optional reshard
        onto ``shardings`` (same structure) — the elastic-restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        if verify:
            digest = hashlib.sha256((d / "arrays.npz").read_bytes()
                                    ).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"checkpoint step-{step} hash mismatch")
        data = np.load(d / "arrays.npz")
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path) for path, _ in flat]
        sh_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(names))
        leaves = []
        for name, (path, ref), sh in zip(names, flat, sh_leaves):
            arr = data[name]
            ref_np = np.dtype(jax.numpy.dtype(ref.dtype))
            if arr.dtype != ref_np and arr.dtype.kind == "u" and \
                    arr.dtype.itemsize == ref_np.itemsize:
                arr = arr.view(ref_np)   # bit-exact ml_dtypes roundtrip
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {ref.shape}")
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        extra = manifest.get("extra", {})
        return jax.tree_util.tree_unflatten(treedef, leaves), step, extra
