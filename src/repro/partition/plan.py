"""Degree-balanced vertex-range partition planning for out-of-core fits.

A :class:`PartitionPlan` cuts a CSR graph into P contiguous vertex ranges
whose *edge windows* are as equal as possible — the unit of residency for
the out-of-core driver (:mod:`repro.partition.ooc`).  Because the CSR
edge arrays are sorted by source vertex, a contiguous vertex range
``[lo, hi)`` owns exactly the contiguous edge window
``[row_ptr[lo], row_ptr[hi])``: a partition is a pure *slice* of the
on-disk arrays, never a gather — which is what lets
:mod:`repro.partition.slices` load it zero-copy off the store's mmap.

The cut points are computed from ``row_ptr`` (i.e. the degree sequence)
alone — O(n) host memory, no edge array ever touched.  Per-partition
**halo** sets (the out-of-partition neighbors whose labels a partition
must import each sweep) do need the ``dst`` array, so
:func:`attach_halos` streams it one partition window at a time — peak
resident edge bytes during planning is a single window.

The same (range, halo) bookkeeping is what a multi-device sharded layout
needs per shard; the plan is deliberately backend-agnostic.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """One contiguous CSR slice: vertex range + edge window + halo."""
    index: int
    lo: int        # first owned vertex (inclusive)
    hi: int        # last owned vertex (exclusive)
    e_lo: int      # first edge of the window == row_ptr[lo]
    e_hi: int      # one past the last edge == row_ptr[hi]
    # Sorted unique global ids of out-of-partition neighbors.  Their
    # labels are gathered into the partition's local row space each
    # sweep (the halo exchange); local rows are [owned vertices | halo].
    halo: np.ndarray | None = None

    @property
    def size(self) -> int:
        return self.hi - self.lo

    @property
    def num_edges(self) -> int:
        return self.e_hi - self.e_lo

    @property
    def halo_size(self) -> int:
        return 0 if self.halo is None else len(self.halo)

    @property
    def n_local(self) -> int:
        """Local row count: owned vertices followed by halo rows."""
        return self.size + self.halo_size

    def local_ids(self) -> np.ndarray:
        """(n_local,) global vertex id of every local row."""
        owned = np.arange(self.lo, self.hi, dtype=np.int32)
        if self.halo is None or not len(self.halo):
            return owned
        return np.concatenate([owned, self.halo.astype(np.int32)])


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """P contiguous CSR slices covering ``[0, n)`` / ``[0, num_edges)``."""
    n: int
    num_edges: int
    d_max: int                     # max degree (from row_ptr — plan input)
    parts: tuple[Partition, ...]

    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    @property
    def max_part_size(self) -> int:
        return max(p.size for p in self.parts)

    @property
    def max_part_edges(self) -> int:
        return max(p.num_edges for p in self.parts)

    @property
    def max_n_local(self) -> int:
        return max(p.n_local for p in self.parts)

    @property
    def halo_vertices(self) -> int:
        """Total halo rows across partitions (label-exchange volume)."""
        return sum(p.halo_size for p in self.parts)

    def stats(self) -> dict:
        edges = [p.num_edges for p in self.parts]
        return {
            "partitions": self.num_partitions,
            "n": self.n, "edges": self.num_edges, "d_max": self.d_max,
            "edges_per_partition_max": max(edges),
            "edges_per_partition_min": min(edges),
            "halo_vertices": self.halo_vertices,
            "halo_fraction": self.halo_vertices / max(self.n, 1),
        }


def plan_partitions(row_ptr: np.ndarray, *,
                    max_edges: int | None = None,
                    max_vertices: int | None = None,
                    num_partitions: int | None = None) -> PartitionPlan:
    """Cut ``[0, n)`` into degree-balanced contiguous vertex ranges.

    Exactly one of ``max_edges`` / ``num_partitions`` sizes the plan;
    ``max_vertices`` optionally caps the rows per partition on top (the
    tile backend's dense-tile residency is row-proportional).  Balancing
    targets ``ceil(num_edges / P)`` edges per partition, found by binary
    search on the cumulative degree sequence (``row_ptr`` itself), so a
    partition never splits a vertex's row: a vertex whose degree alone
    exceeds the target still lands in one partition, just an oversized
    one (the budget assertion downstream catches it if it cannot fit).
    """
    row_ptr = np.asarray(row_ptr)
    n = len(row_ptr) - 1
    num_edges = int(row_ptr[-1])
    if n < 1:
        raise ValueError("cannot partition an empty vertex set")
    if (max_edges is None) == (num_partitions is None):
        raise ValueError("pass exactly one of max_edges / num_partitions")
    if max_edges is not None:
        if max_edges < 1:
            raise ValueError("max_edges must be >= 1")
        num_partitions = max(-(-num_edges // max_edges), 1)
    num_partitions = min(max(int(num_partitions), 1), n)
    target = -(-max(num_edges, 1) // num_partitions)

    degrees = row_ptr[1:] - row_ptr[:-1]
    d_max = int(degrees.max()) if n else 1

    cuts = [0]
    while cuts[-1] < n:
        lo = cuts[-1]
        hi = int(np.searchsorted(row_ptr, row_ptr[lo] + target, side="left"))
        hi = max(hi, lo + 1)           # always advance at least one vertex
        if max_vertices is not None:
            hi = min(hi, lo + max_vertices)
        cuts.append(min(hi, n))
    parts = tuple(
        Partition(index=i, lo=lo, hi=hi,
                  e_lo=int(row_ptr[lo]), e_hi=int(row_ptr[hi]))
        for i, (lo, hi) in enumerate(zip(cuts[:-1], cuts[1:])))
    return PartitionPlan(n=n, num_edges=num_edges, d_max=max(d_max, 1),
                         parts=parts)


def halo_of(part: Partition, dst_window: np.ndarray) -> np.ndarray:
    """Sorted unique out-of-partition neighbor ids of one edge window."""
    dst_window = np.asarray(dst_window)
    outside = dst_window[(dst_window < part.lo) | (dst_window >= part.hi)]
    return np.unique(outside).astype(np.int32)


def attach_halos(plan: PartitionPlan, dst_reader) -> PartitionPlan:
    """Compute every partition's halo set, one edge window at a time.

    ``dst_reader(e_lo, e_hi)`` must return that window of the global
    ``dst`` array (e.g. a zero-copy store slice).  Windows are consumed
    sequentially and released before the next is read, so planning peaks
    at a single partition's edge bytes.
    """
    parts = tuple(
        dataclasses.replace(p, halo=halo_of(p, dst_reader(p.e_lo, p.e_hi)))
        for p in plan.parts)
    return dataclasses.replace(plan, parts=parts)


_SIZE_RE = re.compile(r"^\s*(\d+(?:\.\d+)?)\s*([KMGT]?I?B?)\s*$", re.I)
_SIZE_UNITS = {"": 1, "B": 1,
               "K": 10 ** 3, "KB": 10 ** 3, "KI": 2 ** 10, "KIB": 2 ** 10,
               "M": 10 ** 6, "MB": 10 ** 6, "MI": 2 ** 20, "MIB": 2 ** 20,
               "G": 10 ** 9, "GB": 10 ** 9, "GI": 2 ** 30, "GIB": 2 ** 30,
               "T": 10 ** 12, "TB": 10 ** 12, "TI": 2 ** 40, "TIB": 2 ** 40}


def parse_bytes(text) -> int:
    """``"64MB"`` / ``"1GiB"`` / ``65536`` -> bytes (int)."""
    if isinstance(text, (int, np.integer)):
        return int(text)
    m = _SIZE_RE.match(str(text))
    unit = _SIZE_UNITS.get(m.group(2).upper()) if m else None
    if unit is None:
        raise ValueError(f"cannot parse byte size {text!r} "
                         "(expected e.g. 64MB, 1GiB, 65536)")
    return int(float(m.group(1)) * unit)
