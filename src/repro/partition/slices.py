"""Zero-copy partition loading under an explicit resident-byte budget.

The out-of-core contract is *semi-external*: O(n) vertex-indexed state
(labels, active flags, ``row_ptr``, degrees) stays resident for the whole
fit, while the O(m) edge arrays only ever appear as per-partition
windows.  This module owns that edge side:

* an :class:`ArraySource` yields ``src`` / ``dst`` / ``wgt`` windows —
  either zero-copy slices of the store's single-mmap ``arrays.bin``
  (:class:`StoreEntrySource`) or host views of an already-built
  :class:`~repro.core.graph.Graph` (:class:`InMemorySource`, the
  parity-testing path);
* a :class:`MemoryLedger` accounts every edge-proportional allocation
  the driver makes (local index remaps, padded device inputs, neighbor
  tiles) and **hard-fails** past the budget — the acceptance tests and
  ``BENCH_ooc.json`` assert on its ``peak``;
* a :class:`SliceLoader` LRU-caches resident partitions inside the
  budget: a generous budget keeps every partition warm after the first
  sweep, a tight one degrades gracefully to one-resident-at-a-time.

Window *reads* from an mmap are lazily paged by the OS; the ledger
charges them while held because a sweep actually touches every byte.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.partition.plan import Partition, PartitionPlan

EDGE_ARRAYS = ("src", "dst", "wgt")


class PartitionShapes:
    """Uniform padded shapes shared by every partition of one run.

    All partitions pad to one (rows, edges, labels) shape so each jitted
    sweep stage compiles exactly once per run (and reuses across runs
    that land in the same shapes — jax's jit cache keys on them).

    n_loc: padded local row count (owned + halo rows; the label/active
      buffers' length, and the segment backend's local-Graph ``n``).
    m: padded edge-window length (multiple of 128).
    rows: padded owned-row count (the tile backend's tile height).
    d: padded max-degree (tile width; matches the in-core d bucket so
      tile sweeps reduce over identical widths).
    """

    def __init__(self, n_loc: int, m: int, rows: int, d: int):
        self.n_loc, self.m, self.rows, self.d = n_loc, m, rows, d

    def __repr__(self):
        return (f"PartitionShapes(n_loc={self.n_loc}, m={self.m}, "
                f"rows={self.rows}, d={self.d})")


class MemoryBudgetExceeded(RuntimeError):
    """A single partition's resident set cannot fit the byte budget."""


class MemoryLedger:
    """Tracks resident edge-proportional bytes against a hard budget."""

    def __init__(self, budget: int | None):
        self.budget = None if budget is None else int(budget)
        self.current = 0
        self.peak = 0

    def acquire(self, nbytes: int, what: str = "") -> int:
        nbytes = int(nbytes)
        if self.budget is not None and self.current + nbytes > self.budget:
            raise MemoryBudgetExceeded(
                f"acquiring {nbytes} bytes for {what or 'a partition'} "
                f"would put {self.current + nbytes} resident edge bytes "
                f"over the {self.budget}-byte budget")
        self.current += nbytes
        self.peak = max(self.peak, self.current)
        return nbytes

    def release(self, nbytes: int) -> None:
        self.current -= int(nbytes)

    def stats(self) -> dict:
        return {"budget": self.budget, "current": self.current,
                "peak": self.peak}


# --- array sources ---------------------------------------------------------

class StoreEntrySource:
    """Windows straight off a :class:`repro.io.store.CsrStore` entry.

    Wraps an ``EntryHandle`` (one mmap of ``arrays.bin``); every window
    is a zero-copy slice of that mapping — the full edge arrays are
    never materialized in host memory.
    """

    def __init__(self, handle):
        self.handle = handle
        self.n = int(handle.n)
        self.num_edges = int(handle.num_edges)
        self.m_pad = int(handle.m_pad)

    def row_ptr(self) -> np.ndarray:
        return self.handle.array("row_ptr")

    def window(self, name: str, lo: int, hi: int) -> np.ndarray:
        return self.handle.window(name, lo, hi)

    def fingerprint(self):
        return self.handle.fingerprint

    def to_graph(self):
        """Materialize the full in-core Graph (no re-open, no re-hash)."""
        return self.handle.to_graph()

    def describe(self) -> str:
        return f"store:{self.handle.key}"


class InMemorySource:
    """Windows over an already-built Graph's host arrays.

    The graph is by definition already in core, so this source exists
    for parity tests and for partitioned fits of graphs that *fit* in
    RAM but whose per-fit working set (device copies, tiles) should not
    — the ledger still only charges the per-partition windows.
    """

    def __init__(self, graph):
        self.graph = graph
        self.n = int(graph.n)
        self.num_edges = int(graph.num_edges)
        self.m_pad = int(graph.m_pad)
        self._arrays = {
            "row_ptr": np.asarray(graph.row_ptr),
            "src": np.asarray(graph.src),
            "dst": np.asarray(graph.dst),
            "wgt": np.asarray(graph.wgt),
        }

    def row_ptr(self) -> np.ndarray:
        return self._arrays["row_ptr"]

    def window(self, name: str, lo: int, hi: int) -> np.ndarray:
        return self._arrays[name][lo:hi]

    def fingerprint(self):
        from repro.core.graph import graph_fingerprint
        return graph_fingerprint(self.graph)

    def to_graph(self):
        return self.graph

    def describe(self) -> str:
        return f"graph:n={self.n}:m={self.num_edges}"


# --- resident partitions ---------------------------------------------------

@dataclasses.dataclass
class ResidentPartition:
    """One partition's loaded, locally-indexed slice (+ prepared inputs).

    Local row space: rows ``[0, size)`` are the owned vertices
    ``[lo, hi)``, rows ``[size, n_local)`` the halo imports.  ``src`` /
    ``dst`` are remapped into that space; ``wgt`` is the raw window.
    ``inputs`` caches the backend's device-side preparation (padded
    local CSR or neighbor tiles) for as long as the partition stays
    resident.
    """
    part: Partition
    local_ids: np.ndarray   # (n_local,) int32 global id per local row
    row_ptr: np.ndarray     # (size + 1,) int32 window offsets per owned row
    src: np.ndarray         # (window,) int32 local source rows
    dst: np.ndarray         # (window,) int32 local destination rows
    wgt: np.ndarray         # (window,) float32
    nbytes: int             # ledger charge for the arrays above
    inputs: object = None   # backend-prepared device inputs
    inputs_nbytes: int = 0

    @property
    def size(self) -> int:
        return self.part.size

    @property
    def n_local(self) -> int:
        return self.part.n_local


def load_partition(source, part: Partition) -> ResidentPartition:
    """Slice + locally remap one partition's edge window.

    Owned destinations shift by ``-lo``; halo destinations map to
    ``size + rank`` via binary search in the (sorted) halo set.  The
    remap is recomputed on every load rather than persisted — it is
    edge-proportional, so caching it for *all* partitions is exactly
    what the budget forbids.
    """
    if part.halo is None:
        raise ValueError(f"partition {part.index} has no halo set; run "
                         "attach_halos on the plan first")
    lo, hi = part.lo, part.hi
    src_w = source.window("src", part.e_lo, part.e_hi)
    dst_w = source.window("dst", part.e_lo, part.e_hi)
    wgt_w = np.asarray(source.window("wgt", part.e_lo, part.e_hi),
                       dtype=np.float32)
    row_ptr = (np.asarray(source.window("row_ptr", lo, hi + 1),
                          dtype=np.int64) - part.e_lo).astype(np.int32)

    src = (np.asarray(src_w, dtype=np.int64) - lo).astype(np.int32)
    dst_g = np.asarray(dst_w, dtype=np.int64)
    owned = (dst_g >= lo) & (dst_g < hi)
    dst = np.where(
        owned, dst_g - lo,
        part.size + np.searchsorted(part.halo, dst_g)).astype(np.int32)

    local_ids = part.local_ids()
    nbytes = (src.nbytes + dst.nbytes + wgt_w.nbytes + local_ids.nbytes
              + row_ptr.nbytes)
    return ResidentPartition(part=part, local_ids=local_ids, row_ptr=row_ptr,
                             src=src, dst=dst, wgt=wgt_w, nbytes=nbytes)


def slice_nbytes(part: Partition) -> int:
    """A-priori ledger charge of :func:`load_partition`'s arrays."""
    return part.num_edges * 12 + part.n_local * 4 + (part.size + 1) * 4


class SliceLoader:
    """Budget-bounded LRU of resident partitions.

    ``load(i, prepare)`` returns partition *i* resident with its
    backend inputs built; least-recently-used partitions are evicted
    until the newcomer fits.  Sizes are predictable from plan metadata
    (``slice_nbytes`` + ``prepare.estimate``), so eviction happens
    *before* allocation — residency never transiently overshoots the
    budget.  With a budget covering every partition the loader converges
    to zero reloads; with a tight budget it streams.

    ``prepare``: optional object with ``estimate(part) -> int`` and
    ``build(resident) -> (inputs, nbytes)`` — the backend's device-side
    preparation (padded local CSR / neighbor tiles), cached on the
    resident entry.
    """

    def __init__(self, source, plan: PartitionPlan, ledger: MemoryLedger):
        self.source = source
        self.plan = plan
        self.ledger = ledger
        self._resident: OrderedDict[int, ResidentPartition] = OrderedDict()
        self.loads = 0          # partition loads actually performed
        self.requests = 0       # load() calls (hits + misses)

    def load(self, index: int, prepare=None) -> ResidentPartition:
        self.requests += 1
        res = self._resident.get(index)
        if res is None:
            part = self.plan.parts[index]
            incoming = slice_nbytes(part)
            if prepare is not None:
                incoming += prepare.estimate(part)
            self._fit(incoming, keep=None)
            res = load_partition(self.source, part)
            self.ledger.acquire(res.nbytes, f"partition {index}")
            self._resident[index] = res
            self.loads += 1
        else:
            self._resident.move_to_end(index)
        if prepare is not None and res.inputs is None:
            self._fit(prepare.estimate(res.part), keep=index)
            inputs, nbytes = prepare.build(res)
            self.ledger.acquire(nbytes, f"partition {index} inputs")
            res.inputs, res.inputs_nbytes = inputs, nbytes
        return res

    def _fit(self, incoming: int, keep: int | None) -> None:
        """Evict LRU residents until ``incoming`` more bytes fit."""
        if self.ledger.budget is None:
            return
        while self.ledger.current + incoming > self.ledger.budget:
            victim = next((i for i in self._resident if i != keep), None)
            if victim is None:
                # nothing left to evict: the ledger raises with context
                break
            self.evict(victim)

    def evict(self, index: int) -> None:
        res = self._resident.pop(index, None)
        if res is not None:
            self.ledger.release(res.nbytes + res.inputs_nbytes)

    def clear(self) -> None:
        for index in list(self._resident):
            self.evict(index)

    def stats(self) -> dict:
        return {**self.ledger.stats(), "resident": len(self._resident),
                "loads": self.loads, "requests": self.requests}
