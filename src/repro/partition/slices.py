"""Zero-copy partition loading under an explicit resident-byte budget.

The out-of-core contract is *semi-external*: O(n) vertex-indexed state
(labels, active flags, ``row_ptr``, degrees) stays resident for the whole
fit, while the O(m) edge arrays only ever appear as per-partition
windows.  This module owns that edge side:

* an :class:`ArraySource` yields ``src`` / ``dst`` / ``wgt`` windows —
  either zero-copy slices of the store's single-mmap ``arrays.bin``
  (:class:`StoreEntrySource`) or host views of an already-built
  :class:`~repro.core.graph.Graph` (:class:`InMemorySource`, the
  parity-testing path);
* a :class:`MemoryLedger` accounts every edge-proportional allocation
  the driver makes (local index remaps, padded device inputs, neighbor
  tiles) and **hard-fails** past the budget — the acceptance tests and
  ``BENCH_ooc.json`` assert on its ``peak``;
* a :class:`SliceLoader` LRU-caches resident partitions inside the
  budget: a generous budget keeps every partition warm after the first
  sweep, a tight one degrades gracefully to one-resident-at-a-time.
  ``prefetch=True`` adds a one-slot background stage: the *next*
  partition's mmap window and host→device prep are built on a worker
  thread while the current one sweeps, with the staged bytes reserved in
  the ledger **before** the thread starts (a-priori accounting — the
  budget is never transiently overshot, and a prefetch that cannot fit
  is simply skipped);
* a :class:`HaloLabelCache` keeps device-resident per-partition label
  views keyed by partition id, refreshed by epoch: when a resident
  partition re-sweeps, only entries whose owning vertex changed since
  the cached epoch are re-uploaded (`.at[idx].set`) — the full host
  gather is skipped.  Cache bytes are ledger-charged and spill (LRU)
  whenever a window load needs the room, so windows always win.

Window *reads* from an mmap are lazily paged by the OS; the ledger
charges them while held because a sweep actually touches every byte.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.partition.plan import Partition, PartitionPlan

EDGE_ARRAYS = ("src", "dst", "wgt")


class PartitionShapes:
    """Uniform padded shapes shared by every partition of one run.

    All partitions pad to one (rows, edges, labels) shape so each jitted
    sweep stage compiles exactly once per run (and reuses across runs
    that land in the same shapes — jax's jit cache keys on them).

    n_loc: padded local row count (owned + halo rows; the label/active
      buffers' length, and the segment backend's local-Graph ``n``).
    m: padded edge-window length (multiple of 128).
    rows: padded owned-row count (the tile backend's tile height).
    d: padded max-degree (tile width; matches the in-core d bucket so
      tile sweeps reduce over identical widths).
    """

    def __init__(self, n_loc: int, m: int, rows: int, d: int):
        self.n_loc, self.m, self.rows, self.d = n_loc, m, rows, d

    def __repr__(self):
        return (f"PartitionShapes(n_loc={self.n_loc}, m={self.m}, "
                f"rows={self.rows}, d={self.d})")


class MemoryBudgetExceeded(RuntimeError):
    """A single partition's resident set cannot fit the byte budget."""


class MemoryLedger:
    """Tracks resident edge-proportional bytes against a hard budget.

    Thread-safe: the prefetching :class:`SliceLoader` reserves staged
    bytes from the driver thread before its worker runs, but the lock
    keeps the invariant airtight if callers ever account from both.
    """

    def __init__(self, budget: int | None, scope=None):
        self.budget = None if budget is None else int(budget)
        self.current = 0
        self.peak = 0
        self._lock = threading.Lock()
        # Optional metrics-registry write-through (``repro.obs``): the
        # int fields above stay authoritative; ``stats()`` is a thin view
        # of them, the gauges mirror them for ``REGISTRY.snapshot()``.
        self._g_current = scope.gauge("bytes_current") if scope else None
        self._g_peak = scope.gauge("bytes_peak") if scope else None
        if scope and self.budget is not None:
            scope.gauge("bytes_budget").set(self.budget)

    def _publish(self) -> None:
        if self._g_current is not None:
            self._g_current.set(self.current)
            self._g_peak.set(self.peak)

    def acquire(self, nbytes: int, what: str = "") -> int:
        nbytes = int(nbytes)
        with self._lock:
            if (self.budget is not None
                    and self.current + nbytes > self.budget):
                raise MemoryBudgetExceeded(
                    f"acquiring {nbytes} bytes for {what or 'a partition'} "
                    f"would put {self.current + nbytes} resident edge bytes "
                    f"over the {self.budget}-byte budget")
            self.current += nbytes
            self.peak = max(self.peak, self.current)
        self._publish()
        return nbytes

    def try_acquire(self, nbytes: int, what: str = "") -> bool:
        """Non-raising :meth:`acquire`: False when it would not fit.

        For callers with their own eviction policy (the serving tier's
        cross-tenant warm-cache spill) that loop "evict LRU, retry"
        instead of treating over-budget as fatal.
        """
        nbytes = int(nbytes)
        with self._lock:
            if (self.budget is not None
                    and self.current + nbytes > self.budget):
                return False
            self.current += nbytes
            self.peak = max(self.peak, self.current)
        self._publish()
        return True

    def release(self, nbytes: int) -> None:
        with self._lock:
            self.current -= int(nbytes)
        self._publish()

    def stats(self) -> dict:
        return {"budget": self.budget, "current": self.current,
                "peak": self.peak}


# --- array sources ---------------------------------------------------------

class StoreEntrySource:
    """Windows straight off a :class:`repro.io.store.CsrStore` entry.

    Wraps an ``EntryHandle`` (one mmap of ``arrays.bin``); every window
    is a zero-copy slice of that mapping — the full edge arrays are
    never materialized in host memory.
    """

    def __init__(self, handle):
        self.handle = handle
        self.n = int(handle.n)
        self.num_edges = int(handle.num_edges)
        self.m_pad = int(handle.m_pad)

    def row_ptr(self) -> np.ndarray:
        return self.handle.array("row_ptr")

    def window(self, name: str, lo: int, hi: int) -> np.ndarray:
        return self.handle.window(name, lo, hi)

    def fingerprint(self):
        return self.handle.fingerprint

    def to_graph(self):
        """Materialize the full in-core Graph (no re-open, no re-hash)."""
        return self.handle.to_graph()

    def describe(self) -> str:
        return f"store:{self.handle.key}"


class InMemorySource:
    """Windows over an already-built Graph's host arrays.

    The graph is by definition already in core, so this source exists
    for parity tests and for partitioned fits of graphs that *fit* in
    RAM but whose per-fit working set (device copies, tiles) should not
    — the ledger still only charges the per-partition windows.
    """

    def __init__(self, graph):
        self.graph = graph
        self.n = int(graph.n)
        self.num_edges = int(graph.num_edges)
        self.m_pad = int(graph.m_pad)
        self._arrays = {
            "row_ptr": np.asarray(graph.row_ptr),
            "src": np.asarray(graph.src),
            "dst": np.asarray(graph.dst),
            "wgt": np.asarray(graph.wgt),
        }

    def row_ptr(self) -> np.ndarray:
        return self._arrays["row_ptr"]

    def window(self, name: str, lo: int, hi: int) -> np.ndarray:
        return self._arrays[name][lo:hi]

    def fingerprint(self):
        from repro.core.graph import graph_fingerprint
        return graph_fingerprint(self.graph)

    def to_graph(self):
        return self.graph

    def describe(self) -> str:
        return f"graph:n={self.n}:m={self.num_edges}"


# --- resident partitions ---------------------------------------------------

@dataclasses.dataclass
class ResidentPartition:
    """One partition's loaded, locally-indexed slice (+ prepared inputs).

    Local row space: rows ``[0, size)`` are the owned vertices
    ``[lo, hi)``, rows ``[size, n_local)`` the halo imports.  ``src`` /
    ``dst`` are remapped into that space; ``wgt`` is the raw window.
    ``inputs`` caches the backend's device-side preparation (padded
    local CSR or neighbor tiles) for as long as the partition stays
    resident.
    """
    part: Partition
    local_ids: np.ndarray   # (n_local,) int32 global id per local row
    row_ptr: np.ndarray     # (size + 1,) int32 window offsets per owned row
    src: np.ndarray         # (window,) int32 local source rows
    dst: np.ndarray         # (window,) int32 local destination rows
    wgt: np.ndarray         # (window,) float32
    nbytes: int             # ledger charge for the arrays above
    inputs: object = None   # backend-prepared device inputs
    inputs_nbytes: int = 0

    @property
    def size(self) -> int:
        return self.part.size

    @property
    def n_local(self) -> int:
        return self.part.n_local


def load_partition(source, part: Partition) -> ResidentPartition:
    """Slice + locally remap one partition's edge window.

    Owned destinations shift by ``-lo``; halo destinations map to
    ``size + rank`` via binary search in the (sorted) halo set.  The
    remap is recomputed on every load rather than persisted — it is
    edge-proportional, so caching it for *all* partitions is exactly
    what the budget forbids.
    """
    if part.halo is None:
        raise ValueError(f"partition {part.index} has no halo set; run "
                         "attach_halos on the plan first")
    lo, hi = part.lo, part.hi
    src_w = source.window("src", part.e_lo, part.e_hi)
    dst_w = source.window("dst", part.e_lo, part.e_hi)
    wgt_w = np.asarray(source.window("wgt", part.e_lo, part.e_hi),
                       dtype=np.float32)
    row_ptr = (np.asarray(source.window("row_ptr", lo, hi + 1),
                          dtype=np.int64) - part.e_lo).astype(np.int32)

    src = (np.asarray(src_w, dtype=np.int64) - lo).astype(np.int32)
    dst_g = np.asarray(dst_w, dtype=np.int64)
    owned = (dst_g >= lo) & (dst_g < hi)
    dst = np.where(
        owned, dst_g - lo,
        part.size + np.searchsorted(part.halo, dst_g)).astype(np.int32)

    local_ids = part.local_ids()
    nbytes = (src.nbytes + dst.nbytes + wgt_w.nbytes + local_ids.nbytes
              + row_ptr.nbytes)
    return ResidentPartition(part=part, local_ids=local_ids, row_ptr=row_ptr,
                             src=src, dst=dst, wgt=wgt_w, nbytes=nbytes)


def slice_nbytes(part: Partition) -> int:
    """A-priori ledger charge of :func:`load_partition`'s arrays."""
    return part.num_edges * 12 + part.n_local * 4 + (part.size + 1) * 4


class SliceLoader:
    """Budget-bounded LRU of resident partitions.

    ``load(i, prepare)`` returns partition *i* resident with its
    backend inputs built; least-recently-used partitions are evicted
    until the newcomer fits.  Sizes are predictable from plan metadata
    (``slice_nbytes`` + ``prepare.estimate``), so eviction happens
    *before* allocation — residency never transiently overshoots the
    budget.  With a budget covering every partition the loader converges
    to zero reloads; with a tight budget it streams.

    ``prepare``: optional object with ``estimate(part) -> int`` and
    ``build(resident) -> (inputs, nbytes)`` — the backend's device-side
    preparation (padded local CSR / neighbor tiles), cached on the
    resident entry.

    ``prefetch=True`` enables the one-slot background stage (see the
    module docstring): ``prefetch(k, prepare, keep=...)`` reserves the
    staged bytes a-priori and builds window + inputs on a worker thread;
    the matching ``load(k)`` joins the future instead of paying the
    load.  ``spillers`` is a list of ``spill(nbytes) -> freed`` hooks
    (e.g. :meth:`HaloLabelCache.spill`) tried after LRU eviction when a
    load still does not fit — windows always win over caches.
    """

    def __init__(self, source, plan: PartitionPlan, ledger: MemoryLedger,
                 prefetch: bool = False, scope=None):
        self.source = source
        self.plan = plan
        self.ledger = ledger
        self._resident: OrderedDict[int, ResidentPartition] = OrderedDict()
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="slice-prefetch")
                      if prefetch else None)
        self._staged: dict[int, tuple[Future, int]] = {}
        self.spillers: list = []
        self.loads = 0          # partition loads actually performed
        self.requests = 0       # load() calls (hits + misses)
        self.prefetches = 0     # prefetches staged on the worker
        self.prefetch_hits = 0  # loads served by joining a staged future
        # Optional registry write-through; the counters above stay
        # authoritative and ``stats()`` reads only them.
        self._m_loads = scope.counter("loads") if scope else None
        self._m_requests = scope.counter("requests") if scope else None
        self._m_prefetches = scope.counter("prefetches") if scope else None
        self._m_pf_hits = scope.counter("prefetch_hits") if scope else None

    def load(self, index: int, prepare=None) -> ResidentPartition:
        self.requests += 1
        if self._m_requests is not None:
            self._m_requests.inc()
        res = self._resident.get(index)
        if res is None and index in self._staged:
            res = self._adopt_staged(index)
        if res is None:
            part = self.plan.parts[index]
            incoming = slice_nbytes(part)
            if prepare is not None:
                incoming += prepare.estimate(part)
            self._fit(incoming, keep=None)
            res = load_partition(self.source, part)
            self.ledger.acquire(res.nbytes, f"partition {index}")
            self._resident[index] = res
            self.loads += 1
            if self._m_loads is not None:
                self._m_loads.inc()
        else:
            self._resident.move_to_end(index)
        if prepare is not None and res.inputs is None:
            self._fit(prepare.estimate(res.part), keep=index)
            inputs, nbytes = prepare.build(res)
            self.ledger.acquire(nbytes, f"partition {index} inputs")
            res.inputs, res.inputs_nbytes = inputs, nbytes
        return res

    def prefetch(self, index: int, prepare=None,
                 keep: int | None = None) -> bool:
        """Stage partition ``index`` on the worker thread.

        Reserves the a-priori byte estimate (window + prepared inputs)
        in the ledger *before* the thread starts, evicting LRU residents
        other than ``keep`` (the partition currently sweeping) to make
        room.  Returns False — skipping the prefetch, never the budget —
        when the staged bytes cannot fit.
        """
        if (self._pool is None or index in self._resident
                or index in self._staged):
            return False
        part = self.plan.parts[index]
        incoming = slice_nbytes(part)
        if prepare is not None:
            incoming += prepare.estimate(part)
        if self.ledger.budget is not None:
            while self.ledger.current + incoming > self.ledger.budget:
                victim = next((i for i in self._resident if i != keep),
                              None)
                if victim is None:
                    if not self._spill(incoming):
                        return False
                    break
                self.evict(victim)
            if self.ledger.current + incoming > self.ledger.budget:
                return False
        self.ledger.acquire(incoming, f"partition {index} prefetch")

        def work() -> ResidentPartition:
            res = load_partition(self.source, part)
            if prepare is not None:
                inputs, nbytes = prepare.build(res)
                res.inputs, res.inputs_nbytes = inputs, nbytes
            return res

        self._staged[index] = (self._pool.submit(work), incoming)
        self.prefetches += 1
        if self._m_prefetches is not None:
            self._m_prefetches.inc()
        return True

    def _adopt_staged(self, index: int) -> ResidentPartition:
        """Join a staged future and reconcile its reservation."""
        fut, reserved = self._staged.pop(index)
        try:
            res = fut.result()
        except BaseException:
            self.ledger.release(reserved)
            raise
        actual = res.nbytes + res.inputs_nbytes
        if actual > reserved:
            self._fit(actual - reserved, keep=index)
            self.ledger.acquire(actual - reserved,
                                f"partition {index} staged excess")
        elif actual < reserved:
            self.ledger.release(reserved - actual)
        self._resident[index] = res
        self.loads += 1
        self.prefetch_hits += 1
        if self._m_loads is not None:
            self._m_loads.inc()
            self._m_pf_hits.inc()
        return res

    def _drop_staged(self, index: int) -> None:
        fut, reserved = self._staged.pop(index)
        try:
            fut.result()
        except BaseException:
            pass
        self.ledger.release(reserved)

    def _fit(self, incoming: int, keep: int | None) -> None:
        """Evict LRU residents until ``incoming`` more bytes fit."""
        if self.ledger.budget is None:
            return
        while self.ledger.current + incoming > self.ledger.budget:
            victim = next((i for i in self._resident if i != keep), None)
            if victim is not None:
                self.evict(victim)
                continue
            staged = next((i for i in self._staged if i != keep), None)
            if staged is not None:
                self._drop_staged(staged)
                continue
            if self._spill(incoming):
                break
            # nothing left to evict: the ledger raises with context
            break

    def _spill(self, incoming: int) -> bool:
        """Ask registered caches to free room; True once it fits."""
        if self.ledger.budget is None:
            return True
        for spill in self.spillers:
            need = self.ledger.current + incoming - self.ledger.budget
            if need <= 0:
                return True
            spill(need)
        return self.ledger.current + incoming <= self.ledger.budget

    def evict(self, index: int) -> None:
        res = self._resident.pop(index, None)
        if res is not None:
            self.ledger.release(res.nbytes + res.inputs_nbytes)

    def clear(self) -> None:
        for index in list(self._staged):
            self._drop_staged(index)
        for index in list(self._resident):
            self.evict(index)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def stats(self) -> dict:
        return {**self.ledger.stats(), "resident": len(self._resident),
                "loads": self.loads, "requests": self.requests,
                "prefetches": self.prefetches,
                "prefetch_hits": self.prefetch_hits}


class HaloLabelCache:
    """Device-resident per-partition label views, keyed by partition id.

    ``gather(index, local_ids, arr)`` returns the same padded local view
    ``Exchange.gather`` would build — owned rows then halo imports, padded
    to ``n_loc`` — but keeps it resident on device between visits.  A
    per-vertex epoch array tracks when each vertex last changed
    (``advance(changed)`` after every assembled sweep); on a re-visit only
    the stale entries are re-uploaded via ``.at[idx].set`` — the changed
    labels are scattered into the cached view instead of re-gathering the
    whole partition.  One instance caches one global array (labels during
    propagation; the frozen community assignment and the split labels get
    their own instances so epochs never mix).

    Entries are ledger-charged (``n_loc`` * 4 B each) and spill LRU-first
    via :meth:`spill` — registered on ``SliceLoader.spillers`` so window
    loads always win the budget.  When an entry cannot fit, ``gather``
    falls back to the caller's plain host gather by returning None.
    """

    def __init__(self, ledger: MemoryLedger, n: int, n_loc: int,
                 what: str = "labels"):
        self.ledger = ledger
        self.n_loc = int(n_loc)
        self.what = what
        self.epoch = 0
        self._epoch_of = np.zeros(n, dtype=np.int64)
        self._entries: OrderedDict[int, list] = OrderedDict()  # [arr, epoch]
        self.nbytes = 0
        self.bytes = 0        # label bytes actually uploaded to device
        self.bytes_saved = 0  # gather bytes skipped thanks to the cache
        self.hits = 0         # visits served without any upload

    def advance(self, changed: np.ndarray) -> None:
        """Record one assembled sweep: ``changed`` rows now carry the new
        epoch; everything else stays valid in every cached view."""
        self.epoch += 1
        self._epoch_of[changed] = self.epoch

    def gather(self, index: int, local_ids: np.ndarray, arr: np.ndarray):
        import jax.numpy as jnp
        k = len(local_ids)
        entry = self._entries.get(index)
        if entry is None:
            nb = self.n_loc * 4
            if not self._make_room(nb):
                return None          # caller falls back to the host gather
            self.ledger.acquire(nb, f"halo {self.what} cache p{index}")
            self.nbytes += nb
            out = np.zeros(self.n_loc, dtype=arr.dtype)
            out[:k] = arr[local_ids]
            entry = [jnp.asarray(out), self.epoch]
            self._entries[index] = entry
            self.bytes += k * arr.itemsize
            return entry[0]
        self._entries.move_to_end(index)
        stale = np.nonzero(self._epoch_of[local_ids] > entry[1])[0]
        if len(stale):
            entry[0] = entry[0].at[jnp.asarray(stale)].set(
                jnp.asarray(arr[local_ids[stale]]))
            self.bytes += len(stale) * arr.itemsize
        else:
            self.hits += 1
        self.bytes_saved += (k - len(stale)) * arr.itemsize
        entry[1] = self.epoch
        return entry[0]

    def _make_room(self, nbytes: int) -> bool:
        if self.ledger.budget is None:
            return True
        while self.ledger.current + nbytes > self.ledger.budget:
            if not self._entries:
                return False
            self._evict_one()
        return True

    def _evict_one(self) -> None:
        _, _entry = self._entries.popitem(last=False)
        self.ledger.release(self.n_loc * 4)
        self.nbytes -= self.n_loc * 4

    def spill(self, nbytes: int) -> int:
        """Free >= ``nbytes`` if possible (LRU-first); returns freed."""
        freed = 0
        while freed < nbytes and self._entries:
            self._evict_one()
            freed += self.n_loc * 4
        return freed

    def drop(self) -> None:
        while self._entries:
            self._evict_one()

    def stats(self) -> dict:
        return {"entries": len(self._entries), "nbytes": self.nbytes,
                "bytes": self.bytes, "bytes_saved": self.bytes_saved,
                "hits": self.hits}
