"""Out-of-core partitioned GSL-LPA: detect graphs bigger than RAM.

The driver sweeps a :class:`~repro.partition.plan.PartitionPlan` one
resident partition at a time through a backend's partition-sweep kernels
(``segment`` / ``tile`` — see their ``build_partition`` hooks), keeping
only O(n) vertex-indexed state resident (the shared global label array,
active flags, ``row_ptr``) while the O(m) edge windows stream under a
hard byte budget (:class:`~repro.partition.slices.MemoryLedger`).

**Bit-parity with the in-core fit is by construction, not by luck.**
Every in-core sweep — ``lpa_move`` sub-sweeps and the §3.3 split's
min-label sweeps — is *synchronous*: new labels are a pure function of
the pre-sweep label snapshot.  So processing partitions sequentially
against that same snapshot (halo labels gathered from the shared global
array) and double-buffering the results reproduces the in-core sweep
exactly, whatever the partition count; the per-partition split phase
converges to one label per (community x component) through the outer
fixed-point loop, which *is* the cross-partition label-unification pass.
Three details make it exact rather than approximate:

* pruning reactivation is evaluated **lazily**: a sweep's wake-up mask
  depends on the sweep's final changed flags, which are only complete
  after the last partition — so each partition refreshes its own rows'
  active flags at the start of its *next* sweep, from its own edge
  window (the rule reads each vertex's own neighborhood, so no second
  edge pass is needed);
* the Shiloach-Vishkin pointer shortcut gathers at arbitrary label
  values, so it runs as a global O(n) vertex pass after each assembled
  sweep — the exact position it occupies in the in-core sweep body;
* convergence thresholds replicate the in-core float semantics per
  (backend, bucketing) combination.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.lpa import _label_hash
from repro.engine.cache import trace_context
from repro.engine.config import EngineConfig
from repro.obs import REGISTRY, span
from repro.obs.convergence import ConvergenceProfile, phase_from_rows
from repro.partition.plan import (
    PartitionPlan,
    attach_halos,
    parse_bytes,
    plan_partitions,
)
from repro.partition.slices import (
    HaloLabelCache,
    InMemorySource,
    MemoryLedger,
    PartitionShapes,
    SliceLoader,
    StoreEntrySource,
)

# In-core residency of one directed edge slot: src + dst + wgt + mask.
IN_CORE_EDGE_BYTES = 13

# Shared registry scope for all out-of-core fits in this process: ooc
# infrastructure counters are cumulative across fits (like the engine's
# warm-cache counters), so one scope serves every ``fit_out_of_core``
# call instead of leaking a labeled child scope per fit.
_OOC = REGISTRY.scope("ooc")
_M_FITS = _OOC.counter("fits")
_M_EXCHANGE = _OOC.counter("exchange_bytes")


@dataclasses.dataclass
class OocRun:
    """Raw out-of-core run result + observability counters."""
    labels: np.ndarray            # (n,) int32 — uncompacted global labels
    backend: str
    lpa_iterations: int
    split_iterations: int
    lpa_seconds: float
    split_seconds: float
    plan_seconds: float           # partitioning + halo scan + first prep
    num_partitions: int
    peak_resident_bytes: int
    budget: int
    halo_vertices: int            # total halo rows across partitions
    exchange_bytes: int           # label bytes gathered/scattered, all sweeps
    partition_loads: int          # slice loads actually paid (LRU misses)
    cache_hit: bool               # sweep kernels came from the engine cache
    plan_stats: dict
    fused: bool = False           # partition sweeps ran the fused kernels
    prefetches: int = 0           # windows staged on the prefetch worker
    prefetch_hits: int = 0        # loads served by a staged window
    halo_cache_bytes_saved: int = 0  # gather bytes skipped via label cache
    halo_cache_hits: int = 0      # partition visits with zero re-upload
    profile: object | None = None  # ConvergenceProfile when cfg.profile on

    def stats(self) -> dict:
        return {
            "backend": self.backend, "partitions": self.num_partitions,
            "budget": self.budget,
            "peak_resident_bytes": self.peak_resident_bytes,
            "halo_vertices": self.halo_vertices,
            "exchange_bytes": self.exchange_bytes,
            "partition_loads": self.partition_loads,
            "lpa_iterations": self.lpa_iterations,
            "split_iterations": self.split_iterations,
            "fused": self.fused,
            "prefetches": self.prefetches,
            "prefetch_hits": self.prefetch_hits,
            "halo_cache_bytes_saved": self.halo_cache_bytes_saved,
            "halo_cache_hits": self.halo_cache_hits,
            **{f"plan_{k}": v for k, v in self.plan_stats.items()},
        }


def open_source(graph, **load_kwargs):
    """Graph -> :class:`InMemorySource`; path -> store-backed windows.

    Paths route through :func:`repro.io.store.open_graph`, which ingests
    on first contact and afterwards serves zero-copy windows off the
    store's single mmap — the only path that truly never materializes
    the edge arrays.
    """
    from repro.core.graph import Graph
    if isinstance(graph, Graph):
        return InMemorySource(graph)
    if isinstance(graph, str) or hasattr(graph, "__fspath__"):
        from repro.io.store import open_graph
        return StoreEntrySource(open_graph(graph, **load_kwargs))
    raise TypeError(f"expected a Graph or a graph-file path, "
                    f"got {type(graph).__name__}")


def in_core_edge_bytes(source) -> int:
    """Edge-array bytes an in-core fit would hold resident."""
    return int(source.m_pad) * IN_CORE_EDGE_BYTES


def choose_partition_backend(config: EngineConfig, d_bucket: int,
                             n: int) -> str:
    """OOC flavor of the engine's auto policy (sharded never applies:
    the driver is a single-device streaming loop)."""
    import jax

    from repro.engine.registry import _TILE_MAX_CELLS, _TILE_MAX_DEGREE
    if (jax.default_backend() == "tpu" and d_bucket <= _TILE_MAX_DEGREE
            and n * d_bucket <= _TILE_MAX_CELLS):
        return "tile"
    return "segment"


def _host_parity(n: int) -> np.ndarray:
    """The semi-synchronous sub-sweep classes, via the real device hash
    (zero drift risk vs. a host reimplementation)."""
    return np.asarray((_label_hash(jnp.arange(n, dtype=jnp.int32),
                                   jnp.int32(-1)) & 1).astype(bool))


def _host_threshold(n: int, tau: float, backend: str,
                    bucketing: str) -> int:
    """Replicate the in-core convergence threshold bit-for-bit.

    The segment backend in ``exact`` bucketing bakes ``tau * n`` in with
    Python float semantics; every other combination computes
    ``float32(tau) * float32(n)`` from the traced real vertex count.
    Both truncate toward zero on the int cast.
    """
    if backend == "segment" and bucketing == "exact":
        return int(np.int32(tau * n))
    return int(np.int32(np.float32(tau) * np.float32(n)))


def _shapes_for(plan: PartitionPlan, bucketing: str) -> PartitionShapes:
    from repro.core.graph import _LANE, _round_up
    from repro.engine.bucketing import next_pow2
    rows = next_pow2(plan.max_part_size, 8)
    n_loc = max(next_pow2(plan.max_n_local, 8), rows)
    m = max(_round_up(next_pow2(plan.max_part_edges), _LANE), _LANE)
    if bucketing == "exact":
        d = _round_up(plan.d_max, _LANE)
    else:
        d = _round_up(next_pow2(plan.d_max), _LANE)
    return PartitionShapes(n_loc=n_loc, m=m, rows=rows, d=d)


def fit_out_of_core(source, config: EngineConfig | None = None, *,
                    memory_budget, backend: str | None = None,
                    cache=None, num_partitions: int | None = None,
                    init_labels: np.ndarray | None = None,
                    init_active: np.ndarray | None = None,
                    prefetch: bool | None = None,
                    halo_cache: bool = True) -> OocRun:
    """Detect communities with edge residency capped at ``memory_budget``.

    ``source``: an array source from :func:`open_source`.  ``config``:
    the usual :class:`EngineConfig` algorithm knobs (``split`` must be
    device-side — ``bfs_host`` needs the full adjacency in host memory).
    ``cache``: optional engine :class:`CompileCache` for the partition
    sweep kernels.  ``num_partitions`` overrides the budget-derived
    partition count (benchmarks); the byte budget stays enforced either
    way.  Warm starts (``init_labels`` / ``init_active``) behave exactly
    like ``Engine.fit``'s — they are O(n) vertex state, which the
    semi-external model keeps resident anyway.

    ``prefetch`` stages partition ``k+1``'s window + device prep on a
    worker thread while partition ``k`` sweeps (ledger-reserved before
    the thread starts); the ``None`` default enables it exactly when a
    second CPU exists for the worker to overlap on.  ``halo_cache``
    (default on) keeps device-resident local label views per partition
    and re-uploads only changed entries on re-visits.  Both degrade to
    the serial path under budget pressure, and neither changes a single
    label — the parity suite runs with them toggled both ways.

    Returns an :class:`OocRun`; ``labels`` are bit-identical to the
    in-core ``Engine.fit`` labels for the same (backend, config).
    """
    cfg = config if config is not None else EngineConfig()
    if cfg.split == "bfs_host":
        raise ValueError(
            "split='bfs_host' walks the full adjacency in host memory and "
            "cannot run out-of-core; use split='lp' or 'lpp'")
    budget = parse_bytes(memory_budget)
    if prefetch is None:
        cores = (len(os.sched_getaffinity(0))
                 if hasattr(os, "sched_getaffinity")
                 else (os.cpu_count() or 1))
        prefetch = cores > 1

    t0 = time.perf_counter()
    row_ptr = np.asarray(source.row_ptr())
    n = int(source.n)

    from repro.core.graph import _LANE, _round_up
    from repro.engine.bucketing import next_pow2
    degrees = row_ptr[1:] - row_ptr[:-1]
    d_real = int(degrees.max()) if n else 1
    d_bucket = _round_up(next_pow2(max(d_real, 1)), _LANE)

    name = backend or cfg.backend
    if name == "auto":
        name = choose_partition_backend(cfg, d_bucket, n)
    import repro.engine.backends  # noqa: F401  (registers built-ins)
    from repro.engine.registry import get_backend
    be = get_backend(name)
    if not getattr(be, "supports_partition", False):
        raise ValueError(f"backend {name!r} has no partition sweeps; "
                         "out-of-core fits support segment and tile")

    with span("ooc.plan", n=n, backend=name) as sp_plan:
        if num_partitions is not None:
            plan = plan_partitions(row_ptr, num_partitions=num_partitions)
        else:
            max_edges, max_vertices = be.partition_caps(budget, d_bucket)
            plan = plan_partitions(row_ptr, max_edges=max_edges,
                                   max_vertices=max_vertices)
        plan = attach_halos(plan,
                            lambda lo, hi: source.window("dst", lo, hi))
        shapes = _shapes_for(plan, cfg.bucketing)

        if cache is not None:
            key = ("partition", name, cfg.algo_key(), be.plan_key(cfg))
            sweeps, cache_hit = cache.get_or_build(
                key, lambda: be.build_partition(cfg))
        else:
            sweeps, cache_hit = be.build_partition(cfg), False
        sp_plan.set(partitions=plan.num_partitions,
                    halo_vertices=plan.halo_vertices, cache_hit=cache_hit)

    fused = bool(getattr(be, "supports_fused_partition", False)
                 and getattr(sweeps, "fuse", False))

    ledger = MemoryLedger(budget, scope=_OOC)
    loader = SliceLoader(source, plan, ledger,
                         prefetch=prefetch and plan.num_partitions > 1,
                         scope=_OOC)
    prepare = _Prepare(be, shapes, cfg)

    # Device-resident halo-label caches, one per global array so epochs
    # never mix (labels evolve per sub-sweep; comm is frozen during the
    # split; slab evolves per split iteration).  Registered as spillers:
    # window loads reclaim cache bytes before the ledger would fail.
    caches: list[HaloLabelCache] = []
    lab_cache = comm_cache = slab_cache = None
    if halo_cache:
        lab_cache = HaloLabelCache(ledger, n, shapes.n_loc, "labels")
        comm_cache = HaloLabelCache(ledger, n, shapes.n_loc, "comm")
        slab_cache = HaloLabelCache(ledger, n, shapes.n_loc, "slab")
        caches = [lab_cache, comm_cache, slab_cache]
        loader.spillers.extend(c.spill for c in caches)

    # --- resident O(n) vertex state (the semi-external model's half) ---
    labels = (np.arange(n, dtype=np.int32) if init_labels is None
              else np.asarray(init_labels, dtype=np.int32).copy())
    active = (np.ones(n, dtype=bool) if init_active is None
              else np.asarray(init_active, dtype=bool).copy())
    parity = _host_parity(n)
    threshold = _host_threshold(n, cfg.tau, name, cfg.bucketing)
    bound = jnp.int32(n)
    exchange = Exchange(shapes)
    # trace-audit attribution: every partition sweep dispatch of this fit
    # lands in one (backend, partition-shape-bucket) context
    part_ctx = ("partition", shapes.n_loc, shapes.m, shapes.rows, shapes.d)
    t_plan = time.perf_counter() - t0

    def gather(cache, arr, res):
        """Cached local view when possible, plain host gather otherwise."""
        if cache is not None:
            out = cache.gather(res.part.index, res.local_ids, arr)
            if out is not None:
                return out
        return exchange.gather(arr, res.local_ids)

    def visit(i):
        """Load partition ``i`` and stage ``i+1`` behind it."""
        res = loader.load(i, prepare)
        loader.prefetch((i + 1) % plan.num_partitions, prepare, keep=i)
        return res

    zeros_loc = np.zeros(shapes.n_loc, dtype=bool)
    ones_loc = np.ones(shapes.n_loc, dtype=bool)

    # --- propagation: Algorithm 3 lines 1-6, partitioned ---
    # Profile rows accumulate host-side at the driver's existing sync
    # points (the per-sub-sweep changed reductions already drive the
    # convergence loop), so cfg.profile adds zero new host syncs here.
    do_profile = cfg.profile != "off"
    prop_rows: list[tuple[int, int, int]] = []
    split_rows: list[tuple[int, int, int]] = []
    t0 = time.perf_counter()
    changed_prev: np.ndarray | None = None
    klass_prev: np.ndarray | None = None
    it, delta = 0, n
    with trace_context(name, part_ctx), \
            span("ooc.propagation", backend=name) as sp_lpa:
        while delta > threshold and it < cfg.max_iterations:
            delta = 0
            for sweep in (0, 1):
                klass = parity if sweep else ~parity
                seed = 2 * it + sweep
                labels_next = labels.copy()
                changed_next = np.zeros(n, dtype=bool)
                sweep_delta = 0
                cand_count = 0
                for i in range(plan.num_partitions):
                    res = visit(i)
                    part, rng = res.part, slice(res.part.lo, res.part.hi)
                    loc = res.local_ids
                    lab_loc = gather(lab_cache, labels, res)
                    if fused:
                        # one dispatch: lazy active refresh + candidate
                        # pick + move (kernels/fused_sweep.py)
                        if changed_prev is not None:
                            chg_loc = exchange.gather(changed_prev, loc)
                            candp = active[rng] & klass_prev[rng]
                        else:
                            chg_loc = zeros_loc
                            candp = np.zeros(part.size, dtype=bool)
                        new, act = be.partition_move_fused(
                            sweeps, res.inputs, lab_loc, chg_loc,
                            active[rng], candp, klass[rng], seed, bound)
                        active[rng] = act[: part.size]
                        new = new[: part.size]
                        if do_profile:
                            # the returned act is post-wake, pre-move —
                            # act & klass is the exact candidate set the
                            # fused kernel swept (same count as unfused)
                            cand_count += int(
                                (active[rng] & klass[rng]).sum())
                    else:
                        if changed_prev is not None:
                            # lazy pruning update: finish the previous
                            # sweep's active refresh for this partition
                            wake = be.partition_wake(
                                sweeps, res.inputs,
                                exchange.gather(changed_prev,
                                                loc))[: part.size]
                            was_cand = active[rng] & klass_prev[rng]
                            active[rng] = (active[rng] & ~was_cand) | wake
                        cand = active[rng] & klass[rng]
                        if do_profile:
                            cand_count += int(cand.sum())
                        new = be.partition_move(
                            sweeps, res.inputs, lab_loc,
                            cand, seed, bound)[: part.size]
                    exchange.scatter(labels_next, rng, new)
                    ch = new != labels[rng]
                    changed_next[rng] = ch
                    sweep_delta += int(ch.sum())
                delta += sweep_delta
                if do_profile:
                    prop_rows.append((seed, cand_count, sweep_delta))
                labels = labels_next
                if lab_cache is not None:
                    lab_cache.advance(changed_next)
                changed_prev, klass_prev = changed_next, klass
            it += 1
    lpa_iterations = it
    sp_lpa.set(iterations=it, partitions=plan.num_partitions)
    t_lpa = time.perf_counter() - t0

    # --- §3.3 split phase, per-partition with cross-partition
    # unification via the shared global label array ---
    t0 = time.perf_counter()
    split_iterations = 0
    if cfg.split in ("lp", "lpp"):
        prune = cfg.split == "lpp"
        comm = labels                      # frozen community assignment
        slab = np.arange(n, dtype=np.int32)
        sactive = np.ones(n, dtype=bool)
        changed_prev = None
        delta = 1
        with trace_context(name, part_ctx), \
                span("ooc.split", backend=name) as sp_split:
            while delta > 0:
                # frontier proxy: the split worklist is not materialized
                # host-side (LP sweeps everyone; LPP wakes lazily inside
                # partition visits), so record n for the first sweep and
                # the previous sweep's changed count after — the same
                # proxy the fused in-core split profile uses.
                active_proxy = n if changed_prev is None else delta
                slab_next = slab.copy()
                for i in range(plan.num_partitions):
                    res = visit(i)
                    part, rng = res.part, slice(res.part.lo, res.part.hi)
                    loc = res.local_ids
                    comm_loc = gather(comm_cache, comm, res)
                    slab_loc = gather(slab_cache, slab, res)
                    if fused:
                        # one dispatch: lazy wake + same-community min
                        # (first iteration: everyone awake => chg all-ones)
                        chg_loc = (exchange.gather(changed_prev, loc)
                                   if changed_prev is not None else ones_loc)
                        new = be.partition_split_fused(
                            sweeps, res.inputs, comm_loc, slab_loc,
                            chg_loc, bound)[: part.size]
                    else:
                        if prune and changed_prev is not None:
                            sactive[rng] = be.partition_split_wake(
                                sweeps, res.inputs, comm_loc,
                                exchange.gather(changed_prev,
                                                loc))[: part.size]
                        new = be.partition_split(
                            sweeps, res.inputs, comm_loc, slab_loc,
                            sactive[rng], bound)[: part.size]
                    exchange.scatter(slab_next, rng, new)
                if cfg.shortcut:
                    # global pointer jump — O(n) vertex pass, same position
                    # as the in-core sweep body's `min(new, new[new])`
                    slab_next = np.minimum(slab_next, slab_next[slab_next])
                changed = slab_next != slab
                delta = int(changed.sum())
                if do_profile and cfg.profile == "full":
                    split_rows.append((split_iterations, active_proxy,
                                       delta))
                changed_prev = changed
                slab = slab_next
                if slab_cache is not None:
                    slab_cache.advance(changed)
                split_iterations += 1
        sp_split.set(iterations=split_iterations)
        labels = slab
    t_split = time.perf_counter() - t0

    peak = ledger.peak
    loads = loader.loads
    # Cached gathers bypass the Exchange accounting; fold the bytes the
    # caches did move (builds + changed-entry refreshes) back in so
    # exchange_bytes stays "label traffic a wire layout would carry".
    exchange_bytes = exchange.bytes + sum(c.bytes for c in caches)
    saved = sum(c.bytes_saved for c in caches)
    hits = sum(c.hits for c in caches)
    for c in caches:
        c.drop()
    loader.clear()
    profile = None
    if do_profile:
        profile = ConvergenceProfile(
            propagation=phase_from_rows("propagation", prop_rows),
            split=(phase_from_rows("split", split_rows)
                   if split_rows else None),
            n=n)
    _M_FITS.inc()
    _M_EXCHANGE.inc(exchange_bytes)
    return OocRun(
        labels=labels, backend=name, lpa_iterations=lpa_iterations,
        split_iterations=split_iterations, lpa_seconds=t_lpa,
        split_seconds=t_split, plan_seconds=t_plan,
        num_partitions=plan.num_partitions, peak_resident_bytes=peak,
        budget=budget, halo_vertices=plan.halo_vertices,
        exchange_bytes=exchange_bytes, partition_loads=loads,
        cache_hit=cache_hit, plan_stats=plan.stats(),
        fused=fused, prefetches=loader.prefetches,
        prefetch_hits=loader.prefetch_hits,
        halo_cache_bytes_saved=saved, halo_cache_hits=hits,
        profile=profile,
    )


class _Prepare:
    """Adapter handing the loader the backend's device-side prep."""

    def __init__(self, backend, shapes: PartitionShapes,
                 config: EngineConfig):
        self.backend, self.shapes, self.config = backend, shapes, config

    def estimate(self, part) -> int:
        return self.backend.partition_prepare_nbytes(self.shapes)

    def build(self, resident):
        return self.backend.prepare_partition(resident, self.shapes,
                                              self.config)


class Exchange:
    """Per-sweep halo-label gather/scatter, with byte accounting.

    ``gather`` pulls a partition's local view (owned rows followed by
    halo imports) out of a shared global array, padded to the run's
    uniform local length; ``scatter`` writes the owned rows back.  The
    accumulated byte count is the label traffic a multi-process layout
    would put on the wire — reported in ``OocRun.exchange_bytes``.
    """

    def __init__(self, shapes: PartitionShapes):
        self.shapes = shapes
        self.bytes = 0

    def gather(self, global_arr: np.ndarray, local_ids: np.ndarray,
               ) -> np.ndarray:
        out = np.zeros(self.shapes.n_loc, dtype=global_arr.dtype)
        out[: len(local_ids)] = global_arr[local_ids]
        self.bytes += int(len(local_ids)) * global_arr.itemsize
        return out

    def scatter(self, global_arr: np.ndarray, rng: slice,
                values: np.ndarray) -> None:
        global_arr[rng] = values
        self.bytes += values.nbytes
