"""Out-of-core partitioned detection: graphs bigger than RAM.

The vertical slice behind ``Engine.fit(path, memory_budget=...)``:

  * :mod:`repro.partition.plan`    degree-balanced contiguous CSR
    partitioning + per-partition halo sets, from ``row_ptr`` alone.
  * :mod:`repro.partition.slices`  zero-copy partition windows off the
    store's single mmap, under a hard resident-byte budget (ledger +
    budget-bounded LRU of resident partitions).
  * :mod:`repro.partition.ooc`     the sweep driver: shared global label
    array, halo-label gather/scatter per sweep, per-partition §3.3 split
    with cross-partition unification — labels bit-identical to the
    in-core fit.
"""
from repro.partition.ooc import (  # noqa: F401
    OocRun,
    fit_out_of_core,
    in_core_edge_bytes,
    open_source,
)
from repro.partition.plan import (  # noqa: F401
    Partition,
    PartitionPlan,
    attach_halos,
    halo_of,
    parse_bytes,
    plan_partitions,
)
from repro.partition.slices import (  # noqa: F401
    InMemorySource,
    MemoryBudgetExceeded,
    MemoryLedger,
    SliceLoader,
    StoreEntrySource,
    load_partition,
)
