"""Dense padded-tile LPA path — the kernel-backed formulation.

``to_padded_neighbors`` materialises each vertex's neighbor list as a row of
a (n_pad, d_max) tile; ``lpa_move_dense`` then scores labels with the
``label_argmax`` kernel (Pallas on TPU / jnp oracle elsewhere) and applies
the identical adopt/prune semantics as the sparse ``core.lpa`` path.  This
is the layout the distributed engine uses per shard: every row is fixed
width, so per-device work is perfectly load-balanced after degree bucketing.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph, to_padded_neighbors
from repro.core.lpa import _label_hash  # shared tie-break hash
from repro.kernels import ops


@partial(jax.tree_util.register_dataclass,
         data_fields=("nbr", "nw", "nmask"),
         meta_fields=("n", "n_pad", "d_max"))
@dataclasses.dataclass(frozen=True)
class PaddedGraph:
    n: int
    n_pad: int
    d_max: int
    nbr: jnp.ndarray    # (n_pad, d_max) int32 neighbor ids (self on padding)
    nw: jnp.ndarray     # (n_pad, d_max) float32 weights (0 on padding)
    nmask: jnp.ndarray  # (n_pad, d_max) bool


def pad_graph(graph: Graph, d_max: int | None = None) -> PaddedGraph:
    nbr, nw, nmask = to_padded_neighbors(graph, d_max)
    return PaddedGraph(n=graph.n, n_pad=nbr.shape[0], d_max=nbr.shape[1],
                       nbr=jnp.asarray(nbr), nw=jnp.asarray(nw),
                       nmask=jnp.asarray(nmask))


def lpa_move_dense(pg: PaddedGraph, labels: jnp.ndarray, active: jnp.ndarray,
                   iteration, mode: str = "auto"):
    """Tile-path twin of ``core.lpa.lpa_move`` (labels padded to n_pad)."""
    nbr_lab = labels[pg.nbr]
    best_lab, best_w, cur_w = ops.label_argmax(
        nbr_lab, pg.nw, pg.nmask, labels,
        jnp.asarray(iteration, jnp.int32), mode=mode)
    adopt = active & (best_w > jnp.maximum(cur_w, 0.0))
    new_labels = jnp.where(adopt, best_lab, labels)
    changed = new_labels != labels
    return new_labels, changed, jnp.sum(changed.astype(jnp.int32))


def neighbors_of_dense(pg: PaddedGraph, mask: jnp.ndarray) -> jnp.ndarray:
    """Rows having any true-masked neighbor (reactivation for pruning)."""
    return jnp.any(mask[pg.nbr] & pg.nmask, axis=1)


@partial(jax.jit, static_argnames=("max_iterations", "mode"))
def lpa_run_dense(pg: PaddedGraph, tau: float = 0.05,
                  max_iterations: int = 20, mode: str = "auto"):
    """Semi-synchronous LPA on the tile path (mirrors ``core.lpa.lpa_run``)."""
    n_pad, n = pg.n_pad, pg.n
    real = jnp.arange(n_pad) < n
    labels0 = jnp.arange(n_pad, dtype=jnp.int32)
    parity = (_label_hash(jnp.arange(n_pad, dtype=jnp.int32),
                          jnp.int32(-1)) & 1).astype(bool)
    state = (labels0, jnp.ones(n_pad, bool) & real, jnp.int32(0), jnp.int32(n))

    def cond(s):
        return (s[3] > jnp.int32(tau * n)) & (s[2] < max_iterations)

    def body(s):
        labels, active, it, _ = s
        dn_total = jnp.int32(0)
        for sweep, klass in enumerate((~parity, parity)):
            cand = active & klass & real
            labels, changed, dn = lpa_move_dense(pg, labels, cand,
                                                 2 * it + sweep, mode)
            active = (active & ~cand) | (neighbors_of_dense(pg, changed) & real)
            dn_total = dn_total + dn
        return (labels, active, it + 1, dn_total)

    labels, active, iters, dn = jax.lax.while_loop(cond, body, state)
    return labels[:n], iters


def split_lp_dense(pg: PaddedGraph, comm: jnp.ndarray, mode: str = "auto"):
    """Tile-path SL-LP split (kernel-backed min-label sweeps to fixpoint)."""
    n_pad, n = pg.n_pad, pg.n
    comm_pad = (jnp.concatenate([comm.astype(jnp.int32),
                                 jnp.full((n_pad - n,), -1, jnp.int32)])
                if n_pad > n else comm.astype(jnp.int32))
    labels0 = jnp.arange(n_pad, dtype=jnp.int32)
    state = (labels0, jnp.int32(0), jnp.int32(1))

    def cond(s):
        return s[2] > 0

    def body(s):
        labels, it, _ = s
        new = ops.min_label(labels[pg.nbr], comm_pad[pg.nbr], pg.nmask,
                            labels, comm_pad, mode=mode)
        dn = jnp.sum((new != labels).astype(jnp.int32))
        return (new, it + 1, dn)

    labels, iters, _ = jax.lax.while_loop(cond, body, state)
    return labels[:n], iters
