"""Parallel Label Propagation (the paper's GVE-LPA core), TPU-native.

The paper's ``lpaMove`` accumulates per-neighbor-community weights in
per-thread hashtables.  Hashtables do not vectorise; the TPU-native
formulation here is **sort + segment-reduce** (the same family of tricks the
paper cites for GPU LPA [Soman & Narang, bitonic sort]):

  1. for every directed edge (u, v, w) form the key pair (u, C[v]);
  2. lexicographically sort edges by that pair (``lax.sort`` with 2 keys —
     no 64-bit packing, so it works under JAX's default 32-bit ints);
  3. segment-sum weights over key runs -> K_{u -> c} for every (u, c) that
     actually occurs;
  4. per-source segment-max over the run sums -> best community weight, with
     deterministic tie-breaks: max weight, then max label-hash (a per-
     iteration integer mix).  The paper's hashtable iteration order is
     effectively random among equal-weight labels; a *fixed* min-label
     tie-break would cascade every unweighted graph into one monster
     community, so we keep randomness but make it a pure function of
     (label, iteration) — bit-reproducible across runs and hosts;
  5. a vertex adopts the best label only if it is *strictly* better connected
     than its current label (prevents synchronous-update oscillation and
     makes runs bit-reproducible — see DESIGN.md §2 "Determinism").

Vertex pruning (the paper's processed/unprocessed flags) is a dense boolean
``active`` mask: masked vertices keep their label; a vertex is reactivated
exactly when a neighbor changed label — identical semantics, SIMD-friendly.

GVE-LPA updates a shared label array in place (asynchronous); a fully
synchronous vectorised sweep instead oscillates and fragments (monster
communities / 2-cycles).  We adopt the *semi-synchronous* scheme the paper
cites (Cordasco & Gargano): vertices are statically split into two hashed
parity classes and each ``lpa_run`` iteration performs one sub-sweep per
class — updates in sweep A are visible to sweep B, recovering most of the
asynchronous behaviour while staying data-parallel and deterministic.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

_NEG = jnp.float32(-1.0)  # weights are positive; -1 marks "no run"


class LpaState(NamedTuple):
    labels: jnp.ndarray    # (n,) int32 community of each vertex
    active: jnp.ndarray    # (n,) bool   unprocessed flags (pruning)
    iteration: jnp.ndarray  # () int32
    delta_n: jnp.ndarray   # () int32   label changes in last iteration


def _scan_communities(graph: Graph, labels: jnp.ndarray,
                      label_bound: jnp.ndarray | int | None = None):
    """Steps 1-3: per-(src, community) connecting weights via sort+segments.

    Returns (run_src, run_label, run_wgt, run_valid), each (m_pad,).

    ``label_bound``: exclusive upper bound on real label *values*, used as
    the padding sentinel.  Defaults to ``graph.n`` — the solo/in-core case
    where labels are vertex ids of this very graph.  The out-of-core
    partition path runs sweeps over compact local row spaces whose labels
    are *global* vertex ids, so the bound there is the full graph's vertex
    count (may be traced; one executable serves every partition).
    """
    n, m_pad = graph.n, graph.m_pad
    bound = n if label_bound is None else label_bound
    # Padding edges get the label sentinel so they sort last and never match.
    lab_dst = jnp.where(graph.edge_mask, labels[graph.dst],
                        bound).astype(jnp.int32)
    src = jnp.where(graph.edge_mask, graph.src, n).astype(jnp.int32)
    src_s, lab_s, wgt_s = jax.lax.sort((src, lab_dst, graph.wgt), num_keys=2)

    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), src_s[:-1]])
    prev_lab = jnp.concatenate([jnp.full((1,), -1, jnp.int32), lab_s[:-1]])
    is_start = (src_s != prev_src) | (lab_s != prev_lab)
    run_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1  # (m_pad,) in [0, R)

    run_wgt = jax.ops.segment_sum(wgt_s, run_id, num_segments=m_pad)
    run_src = jax.ops.segment_max(src_s, run_id, num_segments=m_pad)
    run_lab = jax.ops.segment_max(lab_s, run_id, num_segments=m_pad)
    run_valid = (jax.ops.segment_max(is_start.astype(jnp.int32), run_id,
                                     num_segments=m_pad) > 0)
    run_valid &= (run_lab < bound) & (run_src < n)
    return run_src, run_lab, run_wgt, run_valid


def _label_hash(labels: jnp.ndarray, iteration: jnp.ndarray) -> jnp.ndarray:
    """Deterministic per-iteration label priority (Knuth multiplicative mix)."""
    x = labels.astype(jnp.uint32) * jnp.uint32(2654435761)
    x ^= iteration.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    return x.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)  # non-negative


def neighbors_of(graph: Graph, mask: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask of vertices adjacent to any vertex in ``mask``."""
    return jax.ops.segment_max(
        (mask[graph.dst] & graph.edge_mask).astype(jnp.int32),
        graph.src, num_segments=graph.n) > 0


def lpa_move(graph: Graph, labels: jnp.ndarray, active: jnp.ndarray,
             iteration: jnp.ndarray | int = 0,
             label_bound: jnp.ndarray | int | None = None,
             ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One synchronous LPA sweep (the paper's ``lpaMove``) over ``active``.

    Returns (new_labels, changed_mask, delta_n).  ``label_bound``: see
    :func:`_scan_communities` — only the partition path passes it.
    """
    n = graph.n
    bound = n if label_bound is None else label_bound
    run_src, run_lab, run_wgt, run_valid = _scan_communities(graph, labels,
                                                             label_bound)
    seg_src = jnp.where(run_valid, run_src, n - 1)  # dump invalid runs on a real id
    w = jnp.where(run_valid, run_wgt, _NEG)

    # Step 4: per-source best community weight; tie-break max label hash.
    best_w = jax.ops.segment_max(w, seg_src, num_segments=n)
    is_best = run_valid & (run_wgt >= best_w[seg_src]) & (best_w[seg_src] > 0)
    run_h = _label_hash(run_lab, jnp.asarray(iteration, jnp.int32))
    best_h = jax.ops.segment_max(jnp.where(is_best, run_h, -1), seg_src,
                                 num_segments=n)
    pick = is_best & (run_h == best_h[seg_src])
    best_lab = jax.ops.segment_min(jnp.where(pick, run_lab, bound), seg_src,
                                   num_segments=n)

    # Connecting weight to the *current* community (keep unless strictly worse).
    to_cur = run_valid & (run_lab == labels[seg_src])
    cur_w = jax.ops.segment_max(jnp.where(to_cur, run_wgt, _NEG), seg_src,
                                num_segments=n)

    adopt = active & (best_lab < bound) & (best_w > jnp.maximum(cur_w, 0.0))
    new_labels = jnp.where(adopt, best_lab.astype(labels.dtype), labels)
    changed = new_labels != labels
    delta_n = jnp.sum(changed.astype(jnp.int32))
    return new_labels, changed, delta_n


@partial(jax.jit, static_argnames=("max_iterations", "profile"))
def lpa_run(graph: Graph, tau: float = 0.05, max_iterations: int = 20,
            init_labels: jnp.ndarray | None = None,
            n_real: jnp.ndarray | None = None,
            init_active: jnp.ndarray | None = None,
            profile: bool = False):
    """Run LPA to convergence: ``delta_n / n <= tau`` or iteration cap.

    Faithful to Algorithm 3 lines 1-6 (the propagation phase of GSL-LPA).

    ``n_real``: optional traced scalar with the *unpadded* vertex count.
    The engine's shape-bucketed path pads graphs with isolated vertices up
    to a bucket size; those vertices can never change label, but the
    convergence threshold must still be ``tau * n_real``, not
    ``tau * n_bucket`` — passing it as a traced value keeps one compiled
    executable valid for every graph in the bucket.

    ``init_active``: optional (n,) seed for the unprocessed flags —
    GVE-LPA's pruning rule for incremental re-detection: after an edge
    delta, only the vertices whose neighborhoods changed (the affected
    frontier) start unprocessed; everything else sleeps until a neighbor
    actually changes label.  Default: all vertices unprocessed (a full
    cold/warm detection sweep).

    ``profile`` (static): additionally carry a ``(2 * max_iterations, 3)``
    int32 buffer through the loop, writing per sub-sweep at row
    ``2*it + sweep``: [candidate count, changed count, sub-sweep index].
    The buffer never feeds back into labels or the convergence test, so
    profiled runs are bit-identical; the caller fetches it once after
    convergence (no host sync in here — R001 discipline).  Returns
    ``(LpaState, buffer)`` instead of the bare state.
    """
    n = graph.n
    labels0 = (jnp.arange(n, dtype=jnp.int32) if init_labels is None
               else init_labels.astype(jnp.int32))
    active0 = (jnp.ones(n, dtype=bool) if init_active is None
               else init_active.astype(bool))
    state = LpaState(labels=labels0, active=active0,
                     iteration=jnp.int32(0), delta_n=jnp.int32(n))

    if n_real is None:
        threshold = jnp.int32(tau * n)
    else:
        threshold = (jnp.float32(tau)
                     * n_real.astype(jnp.float32)).astype(jnp.int32)

    # Static hashed parity classes for the semi-synchronous sub-sweeps.
    parity = (_label_hash(jnp.arange(n, dtype=jnp.int32), jnp.int32(-1))
              & 1).astype(bool)
    # Profile counts describe the *graph's* frontier, not the padded
    # executable's: mask bucket-padding vertices out of the candidate tally.
    real = (jnp.ones(n, dtype=bool) if n_real is None
            else jnp.arange(n, dtype=jnp.int32) < n_real)

    def cond(carry):
        s = carry[0] if profile else carry
        return (s.delta_n > threshold) & (s.iteration < max_iterations)

    def body(carry):
        s, buf = carry if profile else (carry, None)
        labels, active = s.labels, s.active
        dn_total = jnp.int32(0)
        for sweep, klass in enumerate((~parity, parity)):
            cand = active & klass
            labels, changed, dn = lpa_move(graph, labels, cand,
                                           2 * s.iteration + sweep)
            # pruning: processed vertices sleep; neighbors of changed wake up
            active = (active & ~cand) | neighbors_of(graph, changed)
            dn_total = dn_total + dn
            if profile:
                row = 2 * s.iteration + sweep
                buf = buf.at[row].set(jnp.stack(
                    [jnp.sum((cand & real).astype(jnp.int32)), dn, row]))
        nxt = LpaState(labels, active, s.iteration + 1, dn_total)
        return (nxt, buf) if profile else nxt

    if profile:
        buf0 = jnp.full((2 * max_iterations, 3), -1, jnp.int32)
        return jax.lax.while_loop(cond, body, (state, buf0))
    return jax.lax.while_loop(cond, body, state)


def lpa_move_reference(graph: Graph, labels: jnp.ndarray, active: jnp.ndarray,
                       iteration: jnp.ndarray | int = 0):
    """O(n * n) dense oracle of ``lpa_move`` for small-graph tests.

    Builds the full (n, n) vertex x community weight matrix:
    W[i, c] = sum of w(i,j) over neighbors j with C[j] = c.
    """
    n = graph.n
    w_ic = jnp.zeros((n, n), dtype=jnp.float32)
    lab_dst = labels[graph.dst]
    flat = graph.src * n + lab_dst
    w_ic = w_ic.reshape(-1).at[flat].add(
        jnp.where(graph.edge_mask, graph.wgt, 0.0)).reshape(n, n)
    best_w = jnp.max(w_ic, axis=1)
    # same tie-break as lpa_move: max weight, then max label hash
    is_best = (w_ic >= best_w[:, None]) & (best_w[:, None] > 0)
    h = _label_hash(jnp.arange(n, dtype=jnp.int32),
                    jnp.asarray(iteration, jnp.int32))
    best_h = jnp.max(jnp.where(is_best, h[None, :], -1), axis=1)
    pick = is_best & (h[None, :] == best_h[:, None])
    best_lab = jnp.argmax(pick, axis=1).astype(labels.dtype)
    cur_w = jnp.take_along_axis(w_ic, labels[:, None].astype(jnp.int32),
                                axis=1)[:, 0]
    adopt = active & (best_w > cur_w) & (best_w > 0)
    new_labels = jnp.where(adopt, best_lab, labels)
    changed = new_labels != labels
    return new_labels, changed, jnp.sum(changed.astype(jnp.int32))
