"""GSL-LPA (Algorithm 3): parallel LPA + Split-Last post-processing.

``gsl_lpa`` is the paper's headline algorithm; ``gve_lpa`` is the base
parallel LPA without splitting (the paper's own ablation baseline, §A.2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.lpa import lpa_run
from repro.core.split import (
    compact_labels,
    split_bfs_host,
    split_lp,
    split_lpp,
)

SPLIT_METHODS = ("none", "lp", "lpp", "bfs_host")


@dataclass
class GslResult:
    labels: np.ndarray          # final community membership, dense [0, K)
    lpa_iterations: int
    split_iterations: int       # 0 for none / bfs_host
    lpa_seconds: float
    split_seconds: float

    @property
    def total_seconds(self) -> float:
        return self.lpa_seconds + self.split_seconds


def gsl_lpa(graph: Graph, tau: float = 0.05, max_iterations: int = 20,
            split: str = "lp", shortcut: bool = False,
            init_labels: jnp.ndarray | None = None) -> GslResult:
    """Run GSL-LPA end to end (host-facing wrapper with phase timing).

    split: 'none' -> GVE-LPA; 'lp' / 'lpp' -> Algorithm 1 (TPU path);
           'bfs_host' -> Algorithm 2 (the paper's CPU choice; host oracle).
    """
    if split not in SPLIT_METHODS:
        raise ValueError(f"split must be one of {SPLIT_METHODS}, got {split!r}")

    t0 = time.perf_counter()
    state = lpa_run(graph, tau=tau, max_iterations=max_iterations,
                    init_labels=init_labels)
    labels = jax.block_until_ready(state.labels)
    lpa_iters = int(state.iteration)
    t1 = time.perf_counter()

    split_iters = 0
    if split == "none":
        out = labels
    elif split in ("lp", "lpp"):
        fn = split_lpp if split == "lpp" else split_lp
        st = fn(graph, labels, shortcut=shortcut)
        out = jax.block_until_ready(st.labels)
        split_iters = int(st.iterations)
    else:  # bfs_host
        out = jnp.asarray(split_bfs_host(graph, np.asarray(labels)))
    out = jax.block_until_ready(compact_labels(jnp.asarray(out)))
    t2 = time.perf_counter()

    return GslResult(labels=np.asarray(out), lpa_iterations=lpa_iters,
                     split_iterations=split_iters,
                     lpa_seconds=t1 - t0, split_seconds=t2 - t1)


def gve_lpa(graph: Graph, **kw) -> GslResult:
    """The paper's base parallel LPA (no splitting) — ablation baseline."""
    kw.pop("split", None)
    return gsl_lpa(graph, split="none", **kw)
