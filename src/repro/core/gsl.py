"""GSL-LPA (Algorithm 3): thin compatibility wrappers over the Engine.

``gsl_lpa`` is the paper's headline algorithm; ``gve_lpa`` is the base
parallel LPA without splitting (the paper's own ablation baseline, §A.2).

Both are now facades over :class:`repro.engine.Engine` with
``bucketing="exact"`` (bit-identical to the historical standalone
implementation) and the shared process-wide compile cache, so mixed use
of the wrappers and the Engine reuses the same compiled executables.
New code should use the Engine directly — it adds backend selection,
shape-bucketed compile caching, and warm starts (see README.md).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graph import Graph

SPLIT_METHODS = ("none", "lp", "lpp", "bfs_host")


@dataclass
class GslResult:
    labels: np.ndarray          # final community membership, dense [0, K)
    lpa_iterations: int
    split_iterations: int       # 0 for none / bfs_host
    lpa_seconds: float
    split_seconds: float
    # Underlying Engine result (timings, backend, cache_hit, metrics) so
    # facade users keep full observability without switching APIs.
    detail: "object | None" = None

    @property
    def total_seconds(self) -> float:
        return self.lpa_seconds + self.split_seconds


def gsl_lpa(graph: Graph, tau: float = 0.05, max_iterations: int = 20,
            split: str = "lp", shortcut: bool = False,
            init_labels=None) -> GslResult:
    """Run GSL-LPA end to end (host-facing wrapper with phase timing).

    split: 'none' -> GVE-LPA; 'lp' / 'lpp' -> Algorithm 1 (TPU path);
           'bfs_host' -> Algorithm 2 (the paper's CPU choice; host oracle).
    """
    from repro.engine import Engine, EngineConfig

    if split not in SPLIT_METHODS:
        raise ValueError(f"split must be one of {SPLIT_METHODS}, got {split!r}")

    eng = Engine(EngineConfig(backend="segment", tau=tau,
                              max_iterations=max_iterations, split=split,
                              shortcut=shortcut, bucketing="exact"))
    res = eng.fit(graph, init_labels=init_labels)
    return GslResult(labels=res.labels,
                     lpa_iterations=res.lpa_iterations,
                     split_iterations=res.split_iterations,
                     lpa_seconds=res.lpa_seconds,
                     split_seconds=res.split_seconds,
                     detail=res)


def gve_lpa(graph: Graph, **kw) -> GslResult:
    """The paper's base parallel LPA (no splitting) — ablation baseline."""
    kw.pop("split", None)
    return gsl_lpa(graph, split="none", **kw)
