"""Partition-quality metrics: NMI and ARI (ground-truth evaluation).

Used to score recovered communities against planted SBM partitions —
complements modularity (which needs no ground truth).  Pure numpy (host
metric code; runs once per experiment, not in the hot loop).
"""
from __future__ import annotations

import numpy as np


def _contingency(a: np.ndarray, b: np.ndarray):
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    assert a.shape == b.shape
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    na, nb = ai.max() + 1, bi.max() + 1
    m = np.zeros((na, nb), dtype=np.int64)
    np.add.at(m, (ai, bi), 1)
    return m


def normalized_mutual_info(a, b) -> float:
    """NMI with arithmetic-mean normalisation (0..1)."""
    m = _contingency(a, b)
    n = m.sum()
    pa = m.sum(1) / n
    pb = m.sum(0) / n
    pab = m / n
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(pab * (np.log(pab)
                              - np.log(pa[:, None] * pb[None, :])))
        ha = -np.nansum(np.where(pa > 0, pa * np.log(pa), 0.0))
        hb = -np.nansum(np.where(pb > 0, pb * np.log(pb), 0.0))
    denom = 0.5 * (ha + hb)
    return float(mi / denom) if denom > 1e-12 else 1.0


def adjusted_rand_index(a, b) -> float:
    """ARI (chance-corrected; 1 = identical partitions, ~0 = random)."""
    m = _contingency(a, b)
    n = m.sum()
    comb = lambda x: x * (x - 1) / 2.0
    sum_ij = comb(m).sum()
    sum_a = comb(m.sum(1)).sum()
    sum_b = comb(m.sum(0)).sum()
    total = comb(np.asarray(n, dtype=np.float64))
    expected = sum_a * sum_b / max(total, 1e-12)
    max_index = 0.5 * (sum_a + sum_b)
    denom = max_index - expected
    return float((sum_ij - expected) / denom) if abs(denom) > 1e-12 else 1.0
