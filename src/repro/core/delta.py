"""Streaming graph deltas: edge insertions/deletions on evolving graphs.

Real serving traffic is rarely a stream of fresh graphs — it is a stream
of *updates* to graphs already detected.  A :class:`GraphDelta` captures
one update (undirected edge insertions with weights, plus deletions);
:func:`apply_delta` rebuilds the CSR :class:`Graph` after the update, and
:func:`affected_frontier` computes the vertices whose neighborhoods
changed.  Per GVE-LPA's pruning rule those are exactly the vertices to
seed *unprocessed* on re-detection: restricting propagation to the
frontier (plus whatever it wakes) is where the asymptotic win of
incremental LPA lives (Traag & Šubelj, arXiv:2209.13338) — the engine
accepts the frontier as ``init_active`` alongside warm-start labels.

Delta semantics (host-side numpy, mirroring ``build_graph``):

* edges are undirected and canonicalised to ``(min, max)`` endpoint
  pairs; self loops are dropped (``scanCommunities`` excludes i == j);
* deleting an edge removes it entirely (whatever its weight); deleting
  an edge that does not exist is a silent no-op (streaming traces may
  retire edges more than once);
* inserting an edge that already exists merges weights by summation —
  the same rule ``build_graph`` applies to duplicate input edges;
* vertex counts may grow (``num_vertices`` or an endpoint beyond the
  current range) but never shrink: community ids are vertex ids, and
  removing vertices would invalidate every cached warm start.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph, build_graph


def _canonical_pairs(edges, weights=None):
    """Normalise an undirected edge array: (E, 2) int64 with u < v rows,
    self loops dropped.  Weights (if given) ride along the same filter."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if np.any(edges < 0):
        raise ValueError("edge endpoints must be non-negative vertex ids")
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    keep = lo != hi
    pairs = np.stack([lo[keep], hi[keep]], axis=1)
    if weights is None:
        return pairs, None
    weights = np.asarray(weights, dtype=np.float32).reshape(-1)
    if len(weights) != len(edges):
        raise ValueError(f"weights has {len(weights)} entries for "
                         f"{len(edges)} inserted edges")
    return pairs, weights[keep]


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One update to an evolving graph: insert/delete undirected edges.

    Construct via :meth:`make` (normalises endpoint order, drops self
    loops, defaults weights to 1.0 — the paper's unit-weight default).
    """
    insertions: np.ndarray      # (I, 2) int64 canonical (u < v) pairs
    insert_weights: np.ndarray  # (I,) float32
    deletions: np.ndarray       # (D, 2) int64 canonical (u < v) pairs
    num_vertices: int | None = None  # grow the vertex count to at least this

    @classmethod
    def make(cls, insert=None, delete=None, weights=None,
             num_vertices: int | None = None) -> "GraphDelta":
        ins, w = _canonical_pairs(
            insert if insert is not None else np.zeros((0, 2), np.int64),
            weights)
        if w is None:
            w = np.ones(len(ins), dtype=np.float32)
        dels, _ = _canonical_pairs(
            delete if delete is not None else np.zeros((0, 2), np.int64))
        return cls(insertions=ins, insert_weights=w, deletions=dels,
                   num_vertices=num_vertices)

    @property
    def num_insertions(self) -> int:
        return len(self.insertions)

    @property
    def num_deletions(self) -> int:
        return len(self.deletions)

    def is_empty(self) -> bool:
        return not (self.num_insertions or self.num_deletions)

    def touched_vertices(self) -> np.ndarray:
        """Sorted unique endpoints of every inserted/deleted edge."""
        ends = np.concatenate([self.insertions.reshape(-1),
                               self.deletions.reshape(-1)])
        return np.unique(ends)


def undirected_edges(graph: Graph) -> tuple[np.ndarray, np.ndarray]:
    """Extract the (E, 2) undirected edge list + weights from a Graph.

    ``build_graph`` materialises both directions with equal weight, so
    the u < v half is the full undirected edge set.
    """
    src = np.asarray(graph.src)[: graph.num_edges].astype(np.int64)
    dst = np.asarray(graph.dst)[: graph.num_edges].astype(np.int64)
    wgt = np.asarray(graph.wgt)[: graph.num_edges]
    keep = src < dst
    return np.stack([src[keep], dst[keep]], axis=1), wgt[keep]


def apply_delta(graph: Graph, delta: GraphDelta) -> Graph:
    """Rebuild the CSR graph after a delta (host-side, O(m + |delta|)).

    Returns a fresh :class:`Graph` over the post-delta edge set; the
    input graph is untouched (Graphs are immutable pytrees).  An empty
    delta reproduces the exact same structure (same fingerprint).
    """
    n_new = graph.n
    if delta.num_vertices is not None:
        if delta.num_vertices < graph.n:
            raise ValueError(
                f"delta shrinks the graph ({delta.num_vertices} < "
                f"{graph.n} vertices); vertex removal is unsupported")
        n_new = delta.num_vertices
    if delta.num_insertions:
        n_new = max(n_new, int(delta.insertions.max()) + 1)

    edges, weights = undirected_edges(graph)
    if delta.num_deletions:
        # Only pairs with both endpoints inside the vertex range can name
        # a real edge; dropping the rest up front keeps them true no-ops
        # (an out-of-range endpoint in a (u * n + v) key would otherwise
        # collide with an unrelated in-range edge's key).
        dels = delta.deletions[(delta.deletions < n_new).all(axis=1)]
        if len(dels):
            key = edges[:, 0] * n_new + edges[:, 1]
            dkey = dels[:, 0] * n_new + dels[:, 1]
            keep = ~np.isin(key, dkey)
            edges, weights = edges[keep], weights[keep]
    if delta.num_insertions:
        edges = np.concatenate([edges, delta.insertions], axis=0)
        weights = np.concatenate(
            [weights, delta.insert_weights.astype(weights.dtype)])
    return build_graph(edges, weights, n=n_new)


def affected_frontier(delta: GraphDelta, n: int) -> np.ndarray:
    """(n,) bool mask of vertices whose neighborhoods the delta changed.

    These are the endpoints of every inserted or deleted edge — the
    vertices GVE-LPA's pruning rule seeds *unprocessed* for incremental
    re-detection.  Pass as ``init_active`` together with warm-start
    labels: propagation then starts from the changed neighborhoods and
    wakes outward only as labels actually move.
    """
    out = np.zeros(n, dtype=bool)
    touched = delta.touched_vertices()
    out[touched[touched < n]] = True
    return out
