"""Streaming graph deltas: edge insertions/deletions on evolving graphs.

Real serving traffic is rarely a stream of fresh graphs — it is a stream
of *updates* to graphs already detected.  A :class:`GraphDelta` captures
one update (undirected edge insertions with weights, plus deletions);
:func:`apply_delta` rebuilds the CSR :class:`Graph` after the update, and
:func:`affected_frontier` computes the vertices whose neighborhoods
changed.  Per GVE-LPA's pruning rule those are exactly the vertices to
seed *unprocessed* on re-detection: restricting propagation to the
frontier (plus whatever it wakes) is where the asymptotic win of
incremental LPA lives (Traag & Šubelj, arXiv:2209.13338) — the engine
accepts the frontier as ``init_active`` alongside warm-start labels.

Delta semantics (host-side numpy, mirroring ``build_graph``):

* edges are undirected and canonicalised to ``(min, max)`` endpoint
  pairs; self loops are dropped (``scanCommunities`` excludes i == j);
* deleting an edge removes it entirely (whatever its weight); deleting
  an edge that does not exist is a silent no-op (streaming traces may
  retire edges more than once);
* inserting an edge that already exists merges weights by summation —
  the same rule ``build_graph`` applies to duplicate input edges;
* vertex counts may grow (``num_vertices`` or an endpoint beyond the
  current range) but never shrink: community ids are vertex ids, and
  removing vertices would invalidate every cached warm start.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import (
    _LANE,
    Graph,
    _round_up,
    _set_fingerprint,
    build_graph,
)


def _canonical_pairs(edges, weights=None):
    """Normalise an undirected edge array: (E, 2) int64 with u < v rows,
    self loops dropped.  Weights (if given) ride along the same filter."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if np.any(edges < 0):
        raise ValueError("edge endpoints must be non-negative vertex ids")
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    keep = lo != hi
    pairs = np.stack([lo[keep], hi[keep]], axis=1)
    if weights is None:
        return pairs, None
    weights = np.asarray(weights, dtype=np.float32).reshape(-1)
    if len(weights) != len(edges):
        raise ValueError(f"weights has {len(weights)} entries for "
                         f"{len(edges)} inserted edges")
    return pairs, weights[keep]


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One update to an evolving graph: insert/delete undirected edges.

    Construct via :meth:`make` (normalises endpoint order, drops self
    loops, defaults weights to 1.0 — the paper's unit-weight default).
    """
    insertions: np.ndarray      # (I, 2) int64 canonical (u < v) pairs
    insert_weights: np.ndarray  # (I,) float32
    deletions: np.ndarray       # (D, 2) int64 canonical (u < v) pairs
    num_vertices: int | None = None  # grow the vertex count to at least this

    @classmethod
    def make(cls, insert=None, delete=None, weights=None,
             num_vertices: int | None = None) -> "GraphDelta":
        ins, w = _canonical_pairs(
            insert if insert is not None else np.zeros((0, 2), np.int64),
            weights)
        if w is None:
            w = np.ones(len(ins), dtype=np.float32)
        dels, _ = _canonical_pairs(
            delete if delete is not None else np.zeros((0, 2), np.int64))
        return cls(insertions=ins, insert_weights=w, deletions=dels,
                   num_vertices=num_vertices)

    @property
    def num_insertions(self) -> int:
        return len(self.insertions)

    @property
    def num_deletions(self) -> int:
        return len(self.deletions)

    def is_empty(self) -> bool:
        return not (self.num_insertions or self.num_deletions)

    def touched_vertices(self) -> np.ndarray:
        """Sorted unique endpoints of every inserted/deleted edge."""
        ends = np.concatenate([self.insertions.reshape(-1),
                               self.deletions.reshape(-1)])
        return np.unique(ends)


def undirected_edges(graph: Graph) -> tuple[np.ndarray, np.ndarray]:
    """Extract the (E, 2) undirected edge list + weights from a Graph.

    ``build_graph`` materialises both directions with equal weight, so
    the u < v half is the full undirected edge set.
    """
    src = np.asarray(graph.src)[: graph.num_edges].astype(np.int64)
    dst = np.asarray(graph.dst)[: graph.num_edges].astype(np.int64)
    wgt = np.asarray(graph.wgt)[: graph.num_edges]
    keep = src < dst
    return np.stack([src[keep], dst[keep]], axis=1), wgt[keep]


def apply_delta(graph: Graph, delta: GraphDelta) -> Graph:
    """Rebuild the CSR graph after a delta (host-side, O(m + |delta|)).

    Returns a fresh :class:`Graph` over the post-delta edge set; the
    input graph is untouched (Graphs are immutable pytrees).  An empty
    delta reproduces the exact same structure (same fingerprint).
    """
    n_new = graph.n
    if delta.num_vertices is not None:
        if delta.num_vertices < graph.n:
            raise ValueError(
                f"delta shrinks the graph ({delta.num_vertices} < "
                f"{graph.n} vertices); vertex removal is unsupported")
        n_new = delta.num_vertices
    if delta.num_insertions:
        n_new = max(n_new, int(delta.insertions.max()) + 1)

    edges, weights = undirected_edges(graph)
    if delta.num_deletions:
        # Only pairs with both endpoints inside the vertex range can name
        # a real edge; dropping the rest up front keeps them true no-ops
        # (an out-of-range endpoint in a (u * n + v) key would otherwise
        # collide with an unrelated in-range edge's key).
        dels = delta.deletions[(delta.deletions < n_new).all(axis=1)]
        if len(dels):
            key = edges[:, 0] * n_new + edges[:, 1]
            dkey = dels[:, 0] * n_new + dels[:, 1]
            keep = ~np.isin(key, dkey)
            edges, weights = edges[keep], weights[keep]
    if delta.num_insertions:
        edges = np.concatenate([edges, delta.insertions], axis=0)
        weights = np.concatenate(
            [weights, delta.insert_weights.astype(weights.dtype)])
    return build_graph(edges, weights, n=n_new)


def apply_delta_patch(graph: Graph, delta: GraphDelta) -> Graph:
    """In-place-style CSR splice: bit-identical to :func:`apply_delta`,
    without the full sort/unique rebuild.

    ``apply_delta`` re-derives the CSR from scratch — extract the
    undirected edge list, concatenate the delta, then ``build_graph``'s
    O((m + |delta|) log m) sort + unique + scatter.  This patch instead
    edits only the adjacency rows the delta touches (amortised
    O(|delta| · d) dictionary splices), then reassembles the arrays with
    a handful of bulk ``memcpy`` segments — O(n + m) straight-line copy,
    no sort, no unique, no key materialisation.  On tiny deltas over
    large graphs the rebuild is dominated by the sort; the patch is
    dominated by the copy (see ``benchmarks/bench_streaming_deltas.py``
    for the measured gap).

    Bit-parity notes (pinned in tests/test_delta_patch.py): weight
    merges accumulate in float64 in the exact order ``build_graph``'s
    ``np.add.at`` would (existing edge first, then insertions in delta
    order), per-edge float64 values — not their float32 casts — feed the
    degree sums, and deletions apply before insertions, so every array
    (``row_ptr``/``src``/``dst``/``wgt``/``edge_mask``/``kdeg``) comes
    out byte-identical to the rebuild's.  The one deliberate exception:
    an empty delta returns the *input graph object* unchanged — the
    rebuild would instead re-round any sum-merged duplicate weights
    through float32 and so can perturb ``kdeg`` by an ulp; skipping the
    no-op keeps the original (higher-precision) values and all of the
    graph's cached state.
    """
    n_old = graph.n
    n_new = n_old
    if delta.num_vertices is not None:
        if delta.num_vertices < n_old:
            raise ValueError(
                f"delta shrinks the graph ({delta.num_vertices} < "
                f"{n_old} vertices); vertex removal is unsupported")
        n_new = delta.num_vertices
    if delta.num_insertions:
        n_new = max(n_new, int(delta.insertions.max()) + 1)
    if delta.is_empty() and n_new == n_old:
        return graph  # structure unchanged; Graphs are immutable anyway

    m_old = graph.num_edges
    rp = np.asarray(graph.row_ptr)
    dst = np.asarray(graph.dst)[:m_old]
    # float64 views of the stored float32 weights: exactly the values
    # build_graph would see as input on a rebuild
    w64 = np.asarray(graph.wgt)[:m_old].astype(np.float64)

    # --- collect per-row edit scripts (None = delete marker) -----------
    edits: dict[int, dict[int, list]] = {}

    def _ops(r: int, t: int) -> list:
        return edits.setdefault(r, {}).setdefault(t, [])

    if delta.num_deletions:
        dels = delta.deletions[(delta.deletions < n_new).all(axis=1)]
        for u, v in dels.tolist():
            _ops(u, v).append(None)
            _ops(v, u).append(None)
    for (u, v), w in zip(delta.insertions.tolist(),
                         delta.insert_weights.tolist()):
        _ops(u, v).append(w)
        _ops(v, u).append(w)

    # --- splice each touched row's adjacency ---------------------------
    new_rows: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for r, row_ops in edits.items():
        lo, hi = (int(rp[r]), int(rp[r + 1])) if r < n_old else (0, 0)
        cur = dict(zip(dst[lo:hi].tolist(), w64[lo:hi].tolist()))
        for tgt, ops in row_ops.items():
            ins = [w for w in ops if w is not None]
            if len(ins) < len(ops):     # a deletion: drop the old edge
                cur.pop(tgt, None)      # (missing edge: silent no-op)
                acc = None
            else:
                acc = cur.get(tgt)
            for w in ins:               # float64, build_graph's add order
                acc = w if acc is None else acc + w
            if ins:
                cur[tgt] = acc
        order = sorted(cur)
        new_rows[r] = (np.array(order, dtype=np.int32),
                       np.array([cur[t] for t in order], dtype=np.float64))

    # --- reassemble: bulk segments around the touched rows -------------
    deg = np.zeros(n_new, dtype=np.int64)
    deg[:n_old] = rp[1:] - rp[:-1]
    for r, (rd, _) in new_rows.items():
        deg[r] = len(rd)
    row_ptr = np.zeros(n_new + 1, dtype=np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    row_ptr = row_ptr.astype(np.int32)

    dst_segs, w_segs = [], []
    pos = 0  # read position in the old arrays
    for r in sorted(new_rows):
        lo, hi = (int(rp[r]), int(rp[r + 1])) if r < n_old else (m_old, m_old)
        dst_segs.append(dst[pos:lo])
        w_segs.append(w64[pos:lo])
        rd, rw = new_rows[r]
        dst_segs.append(rd)
        w_segs.append(rw)
        pos = hi
    dst_segs.append(dst[pos:m_old])
    w_segs.append(w64[pos:m_old])
    dst_new = np.concatenate(dst_segs)
    w64_new = np.concatenate(w_segs)

    num_edges = len(dst_new)
    m_pad = max(_round_up(num_edges, _LANE), _LANE)
    src_pad = np.zeros(m_pad, dtype=np.int32)
    dst_pad = np.zeros(m_pad, dtype=np.int32)
    wgt_pad = np.zeros(m_pad, dtype=np.float32)
    mask = np.zeros(m_pad, dtype=bool)
    src_pad[:num_edges] = np.repeat(
        np.arange(n_new, dtype=np.int32), deg)
    dst_pad[:num_edges] = dst_new
    wgt_pad[:num_edges] = w64_new.astype(np.float32)
    mask[:num_edges] = True

    # kdeg from the float64 per-edge values (pre-float32-cast), summed in
    # array order — np.add.at is sequential, matching build_graph exactly
    kdeg = np.zeros(n_new, dtype=np.float64)
    np.add.at(kdeg, src_pad[:num_edges], w64_new)

    import jax.numpy as jnp
    out = Graph(
        n=int(n_new), m_pad=int(m_pad), num_edges=int(num_edges),
        row_ptr=jnp.asarray(row_ptr),
        src=jnp.asarray(src_pad), dst=jnp.asarray(dst_pad),
        wgt=jnp.asarray(wgt_pad), edge_mask=jnp.asarray(mask),
        kdeg=jnp.asarray(kdeg, dtype=jnp.float32),
    )
    _set_fingerprint(out, row_ptr, dst_pad)
    return out


def affected_frontier(delta: GraphDelta, n: int) -> np.ndarray:
    """(n,) bool mask of vertices whose neighborhoods the delta changed.

    These are the endpoints of every inserted or deleted edge — the
    vertices GVE-LPA's pruning rule seeds *unprocessed* for incremental
    re-detection.  Pass as ``init_active`` together with warm-start
    labels: propagation then starts from the changed neighborhoods and
    wakes outward only as labels actually move.
    """
    out = np.zeros(n, dtype=bool)
    touched = delta.touched_vertices()
    out[touched[touched < n]] = True
    return out
