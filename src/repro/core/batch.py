"""Multi-graph batching: disjoint-union packing + batched LPA/split.

GSL-LPA's labels are vertex ids and label propagation never crosses a
missing edge, so k graphs packed as a *disjoint union* (concatenated CSR
arrays with per-graph vertex-id offsets and no inter-graph edges)
propagate independently inside one kernel launch — a single device
dispatch amortises per-launch overhead across the whole batch.

Exact per-graph parity with ``Engine.fit`` requires care in two places:

* **Local label coordinates.**  The tie-break hash and the parity
  classes are functions of raw label / vertex-id values, so a packed run
  over *global* ids would break ties differently from a standalone run.
  The batched kernels therefore keep every vertex's label in its graph's
  *local* id space (value in ``[0, n_i)``) while gathers still use global
  row indices; ``voffset`` (per-vertex owner offset) converts between the
  two where needed (the split shortcut's pointer jump).
* **Per-graph convergence.**  Each member graph must stop exactly where
  its standalone run would: the batched loops track a per-graph ``done``
  flag (frozen graphs stop producing candidates) and per-graph iteration
  counters, advancing the global loop until every member has converged.
  Early-converged members ride along as no-ops — their labels are at a
  sweep fixpoint, so the extra sweeps cannot change them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import _LANE, Graph, _round_up
from repro.core.lpa import _label_hash, lpa_move, neighbors_of
from repro.core.split import _min_label_sweep


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """k graphs packed into one disjoint-union super-graph.

    ``graph`` is a normal :class:`Graph` (member padding stripped, one
    shared padded tail), so every single-graph code path — bucketing,
    ``pad_graph``, ``to_padded_neighbors`` — applies unchanged.  The
    batch metadata stays host-side numpy.
    """
    graph: Graph             # packed super-graph (no inter-graph edges)
    sizes: np.ndarray        # (k,) int64 per-graph vertex counts
    offsets: np.ndarray      # (k + 1,) int64 vertex-id offset per graph
    edge_counts: np.ndarray  # (k,) int64 per-graph directed edge counts
    graph_id: np.ndarray     # (total_vertices,) int32 owner of each vertex

    @property
    def num_graphs(self) -> int:
        return len(self.sizes)

    @property
    def total_vertices(self) -> int:
        return int(self.offsets[-1])

    @property
    def total_edges(self) -> int:
        return int(self.edge_counts.sum())

    @classmethod
    def pack(cls, graphs) -> "GraphBatch":
        """Disjoint-union pack: offset vertex ids, concatenate CSR arrays.

        Member graphs' own edge padding is stripped; each member's edges
        are already sorted by (src, dst) and offsets are increasing, so
        the concatenation stays a valid CSR ordering.  Handles n=0 and
        edgeless members.
        """
        graphs = list(graphs)
        if not graphs:
            raise ValueError("GraphBatch.pack needs at least one graph")
        sizes = np.array([g.n for g in graphs], dtype=np.int64)
        offsets = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)])
        edge_counts = np.array([g.num_edges for g in graphs], dtype=np.int64)
        n_total = int(offsets[-1])
        m_total = int(edge_counts.sum())

        srcs, dsts, wgts, kdegs, degs = [], [], [], [], []
        for g, off in zip(graphs, offsets[:-1]):
            e = g.num_edges
            srcs.append(np.asarray(g.src)[:e].astype(np.int64) + off)
            dsts.append(np.asarray(g.dst)[:e].astype(np.int64) + off)
            wgts.append(np.asarray(g.wgt)[:e])
            kdegs.append(np.asarray(g.kdeg, dtype=np.float32))
            rp = np.asarray(g.row_ptr)
            degs.append((rp[1:] - rp[:-1]).astype(np.int64))

        m_pad = max(_round_up(m_total, _LANE), _LANE)
        src = np.zeros(m_pad, np.int32)
        dst = np.zeros(m_pad, np.int32)
        wgt = np.zeros(m_pad, np.float32)
        mask = np.zeros(m_pad, bool)
        src[:m_total] = np.concatenate(srcs)
        dst[:m_total] = np.concatenate(dsts)
        wgt[:m_total] = np.concatenate(wgts)
        mask[:m_total] = True
        row_ptr = np.concatenate(
            [np.zeros(1, np.int64),
             np.cumsum(np.concatenate(degs))]).astype(np.int32)
        graph_id = np.repeat(np.arange(len(graphs), dtype=np.int32), sizes)

        packed = Graph(
            n=n_total, m_pad=int(m_pad), num_edges=m_total,
            row_ptr=jnp.asarray(row_ptr),
            src=jnp.asarray(src), dst=jnp.asarray(dst),
            wgt=jnp.asarray(wgt), edge_mask=jnp.asarray(mask),
            kdeg=jnp.asarray(np.concatenate(kdegs) if kdegs
                             else np.zeros(0, np.float32)),
        )
        return cls(graph=packed, sizes=sizes, offsets=offsets,
                   edge_counts=edge_counts, graph_id=graph_id)

    def vertex_offsets(self) -> np.ndarray:
        """(total_vertices,) int32: each vertex's owning-graph offset."""
        return np.repeat(self.offsets[:-1], self.sizes).astype(np.int32)

    def pack_labels(self, member_labels) -> np.ndarray | None:
        """Concatenate per-member init labels into one packed vector.

        ``member_labels`` is a length-``num_graphs`` sequence; each entry
        is an (n_i,) vertex-id-valued array (*local* coordinates — which
        is exactly what a solo warm start uses, since a standalone
        graph's ids are its local ids) or None for a cold member (kept at
        singleton starts).  Returns a (total_vertices,) int32 vector, or
        None when every member is cold.
        """
        member_labels = list(member_labels)
        if len(member_labels) != self.num_graphs:
            raise ValueError(f"got {len(member_labels)} init-label entries "
                             f"for a batch of {self.num_graphs} graphs")
        if all(lab is None for lab in member_labels):
            return None
        out = np.empty(self.total_vertices, dtype=np.int32)
        for i, lab in enumerate(member_labels):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            if lab is None:
                out[lo:hi] = np.arange(hi - lo, dtype=np.int32)
            else:
                out[lo:hi] = np.asarray(lab, dtype=np.int32).reshape(-1)
        return out

    def pack_active(self, member_active) -> np.ndarray | None:
        """Concatenate per-member init active masks (None -> all-active).

        Packed counterpart of the GVE-LPA unprocessed flags: a member's
        mask marks the vertices seeded unprocessed (its delta's affected
        frontier); cold members start fully active.  Returns a
        (total_vertices,) bool vector, or None when every member is
        fully active.
        """
        member_active = list(member_active)
        if len(member_active) != self.num_graphs:
            raise ValueError(f"got {len(member_active)} init-active entries "
                             f"for a batch of {self.num_graphs} graphs")
        if all(act is None for act in member_active):
            return None
        out = np.empty(self.total_vertices, dtype=bool)
        for i, act in enumerate(member_active):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            out[lo:hi] = True if act is None \
                else np.asarray(act, dtype=bool).reshape(-1)
        return out

    def unpack(self, labels, compact: bool = True) -> list[np.ndarray]:
        """Slice a packed (>= total_vertices,) label vector per graph.

        ``labels`` is expected in local coordinates (what the batched
        kernels produce); with ``compact=True`` each slice is densely
        relabeled to ``[0, K_i)`` — identical rank order to the engine's
        single-graph compaction.
        """
        labels = np.asarray(labels).reshape(-1)
        if len(labels) < self.total_vertices:
            raise ValueError(f"labels has {len(labels)} entries; batch has "
                             f"{self.total_vertices} vertices")
        out = []
        for i in range(self.num_graphs):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            lab = labels[lo:hi].astype(np.int32)
            if compact:
                lab = np.unique(lab, return_inverse=True)[1].astype(np.int32)
            out.append(lab)
        return out


def warm_state_rows(rows: int, voffset, labels0=None, active0=None,
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Extend packed (total_vertices,) warm-start state to ``rows`` rows.

    Bucket-padding rows keep their local singleton ids (``row -
    voffset``, the batched kernels' cold start) and are seeded inactive
    when an explicit active mask is present.  With both inputs None this
    reproduces the cold defaults exactly: local-id labels, all-active.
    """
    voff = np.asarray(voffset).astype(np.int64)
    local = (np.arange(rows, dtype=np.int64) - voff).astype(np.int32)
    if labels0 is None:
        lab = local
    else:
        lab = local.copy()
        lab[: len(labels0)] = np.asarray(labels0, dtype=np.int32)
    if active0 is None:
        act = np.ones(rows, dtype=bool)
    else:
        act = np.zeros(rows, dtype=bool)
        act[: len(active0)] = np.asarray(active0, dtype=bool)
    return lab, act


def lpa_run_batched(graph: Graph, sizes: jnp.ndarray, graph_id: jnp.ndarray,
                    voffset: jnp.ndarray, labels0: jnp.ndarray,
                    active0: jnp.ndarray, *, tau: float, max_iterations: int,
                    profile: bool = False):
    """Batched propagation over a packed graph (traced; jit by the caller).

    graph: packed + bucket-padded super-graph.
    sizes: (k + 1,) traced per-slot real vertex counts (0 for empty slots
      and the padding slot), so one executable serves every batch in the
      bucket.
    graph_id / voffset: (graph.n,) owner slot + owner offset per vertex.
    labels0 / active0: (graph.n,) initial labels (*local* coordinates —
      cold start passes the local ids themselves) and unprocessed-seed
      mask (cold start passes all-True).  Traced, so cold and warm
      dispatches share one compiled executable.

    Returns (labels, iterations): labels in *local* coordinates, plus the
    per-slot iteration counts — each slot stops exactly where its
    standalone ``lpa_run`` would (same threshold arithmetic as the
    traced-``n_real`` path, same hash seeds, same parity classes).

    ``profile``: additionally carry a ``(2 * max_iterations, 2, k1)``
    int32 buffer with per-slot [candidate count, changed count] rows per
    sub-sweep (the batched counterpart of ``lpa_run``'s profile buffer;
    writes never feed back, so labels/iterations stay bit-identical).
    Returns ``(labels, iterations, buffer)``.
    """
    n = graph.n
    k1 = sizes.shape[0]
    vid = jnp.arange(n, dtype=jnp.int32)
    local = vid - voffset
    parity = (_label_hash(local, jnp.int32(-1)) & 1).astype(bool)
    thr = (jnp.float32(tau) * sizes.astype(jnp.float32)).astype(jnp.int32)
    done0 = sizes <= thr

    def cond(s):
        _labels, _active, it, done, _iters = s[:5]
        return jnp.any(~done) & (it < max_iterations)

    def body(s):
        labels, active, it, done, iters = s[:5]
        buf = s[5] if profile else None
        running = ~done[graph_id]
        dn = jnp.zeros((k1,), jnp.int32)
        for sweep, klass in enumerate((~parity, parity)):
            cand = active & klass & running
            labels, changed, _ = lpa_move(graph, labels, cand,
                                          2 * it + sweep)
            active = (active & ~cand) | neighbors_of(graph, changed)
            sc = jax.ops.segment_sum(changed.astype(jnp.int32),
                                     graph_id, num_segments=k1)
            dn = dn + sc
            if profile:
                buf = buf.at[2 * it + sweep].set(jnp.stack(
                    [jax.ops.segment_sum(cand.astype(jnp.int32), graph_id,
                                         num_segments=k1), sc]))
        iters = iters + jnp.where(done, 0, 1)
        nxt = (labels, active, it + jnp.int32(1), done | (dn <= thr), iters)
        return nxt + (buf,) if profile else nxt

    state = (labels0.astype(jnp.int32), active0.astype(bool), jnp.int32(0),
             done0, jnp.zeros((k1,), jnp.int32))
    if profile:
        state = state + (jnp.full((2 * max_iterations, 2, k1), -1,
                                  jnp.int32),)
        labels, _, _, _, iters, buf = jax.lax.while_loop(cond, body, state)
        return labels, iters, buf
    labels, _, _, _, iters = jax.lax.while_loop(cond, body, state)
    return labels, iters


def split_lp_batched(graph: Graph, sizes: jnp.ndarray, graph_id: jnp.ndarray,
                     voffset: jnp.ndarray, comm: jnp.ndarray, *,
                     prune: bool = False, shortcut: bool = False,
                     profile_rows: int = 0):
    """Batched Split-Last over a packed graph (local-label coordinates).

    Min-label sweeps are idempotent at a member's fixpoint, so converged
    members simply stop changing while the loop drains the rest; per-slot
    iteration counts record the sweep at which each member's standalone
    ``split_lp`` would have exited.

    ``profile_rows`` (0 = off): carry a ``(profile_rows, 2, k1)`` int32
    per-slot [active count, changed count] buffer per sweep (rows past
    the cap overwrite the last; writes never feed back).  Returns
    ``(labels, iterations, buffer)``.
    """
    n = graph.n
    k1 = sizes.shape[0]
    local = jnp.arange(n, dtype=jnp.int32) - voffset
    done0 = sizes == 0

    def cond(s):
        _labels, _active, done, _iters = s[:4]
        return jnp.any(~done)

    def body(s):
        labels, active, done, iters = s[:4]
        buf = s[4] if profile_rows else None
        new, nxt_active, changed, _ = _min_label_sweep(
            graph, comm, labels, active, prune, shortcut, voffset=voffset)
        dn = jax.ops.segment_sum(changed.astype(jnp.int32), graph_id,
                                 num_segments=k1)
        if profile_rows:
            row = jnp.minimum(iters.max(), profile_rows - 1)
            buf = buf.at[row].set(jnp.stack(
                [jax.ops.segment_sum(active.astype(jnp.int32), graph_id,
                                     num_segments=k1), dn]))
        iters = iters + jnp.where(done, 0, 1)
        nxt = (new, nxt_active, done | (dn == 0), iters)
        return nxt + (buf,) if profile_rows else nxt

    state = (local, jnp.ones(n, dtype=bool), done0,
             jnp.zeros((k1,), jnp.int32))
    if profile_rows:
        state = state + (jnp.full((profile_rows, 2, k1), -1, jnp.int32),)
        labels, _, _, iters, buf = jax.lax.while_loop(cond, body, state)
        return labels, iters, buf
    labels, _, _, iters = jax.lax.while_loop(cond, body, state)
    return labels, iters
