"""Distributed GSL-LPA: vertex-partitioned label propagation via shard_map.

Layout (DESIGN.md §6): vertices are 1-D partitioned across *all* mesh axes
(pod x data x model flattened); each device owns an equal slice of the
padded neighbor tiles (perfect static load balance).  The global label
vector is replicated; each sub-sweep computes new labels for the local
slice and refreshes the replica with one tiled all-gather — the only
collective in the inner loop (n * 4 bytes per sweep).

Faithful-baseline vs beyond-paper knobs:
  * ``exchange_every=1``  — all-gather after every sub-sweep: bit-identical
    to the single-device semi-synchronous engine (tests enforce equality).
  * ``exchange_every=k>1`` — run k local sub-sweeps on stale remote labels
    between exchanges.  LPA is a chaotic relaxation and tolerates staleness;
    this divides the collective term by k (§Perf hillclimb lever; quality
    measured in ``benchmarks/bench_stale_exchange.py``).
  * the changed mask is never exchanged — it is recovered locally by
    diffing label replicas (§Perf cell-1 iteration 1, -20% wire bytes).

The loop itself is host-driven (one jitted step per iteration) so that the
(labels, active, iteration) state can be checkpointed between iterations —
the fault-tolerance story for multi-hour billion-edge runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core.graph import Graph, to_padded_neighbors
from repro.core.lpa import _label_hash
from repro.kernels import ops


@partial(jax.tree_util.register_dataclass,
         data_fields=("nbr", "nw", "nmask"),
         meta_fields=("n", "n_pad", "d_max"))
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Padded neighbor tiles, row-sharded over the full device grid."""
    n: int        # real vertex count
    n_pad: int    # padded: multiple of (#devices * 8)
    d_max: int
    nbr: jnp.ndarray    # (n_pad, d_max) int32  — sharded on axis 0
    nw: jnp.ndarray     # (n_pad, d_max) float32
    nmask: jnp.ndarray  # (n_pad, d_max) bool


def _all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def shard_graph(graph: Graph, mesh: Mesh, d_max: int | None = None,
                n_rows: int | None = None) -> ShardedGraph:
    """Host-side build + placement of the sharded tiles.

    ``n_rows``: minimum padded row count — the engine's shape-bucketed
    path passes the vertex bucket here so that every graph in a bucket
    shards to identical tile shapes (one compile per bucket).
    """
    n_dev = int(np.prod(mesh.devices.shape))
    nbr, nw, nmask = to_padded_neighbors(graph, d_max)
    rows = max(nbr.shape[0], n_rows or 0)
    n_pad = ((rows + n_dev * 8 - 1) // (n_dev * 8)) * (n_dev * 8)
    extra = n_pad - nbr.shape[0]
    if extra:
        pad_ids = np.arange(nbr.shape[0], n_pad, dtype=np.int32)
        nbr = np.concatenate(
            [nbr, np.repeat(pad_ids[:, None], nbr.shape[1], 1)], 0)
        nw = np.concatenate([nw, np.zeros((extra, nw.shape[1]), np.float32)], 0)
        nmask = np.concatenate(
            [nmask, np.zeros((extra, nmask.shape[1]), bool)], 0)
    spec = NamedSharding(mesh, P(_all_axes(mesh), None))
    return ShardedGraph(
        n=graph.n, n_pad=n_pad, d_max=nbr.shape[1],
        nbr=jax.device_put(jnp.asarray(nbr), spec),
        nw=jax.device_put(jnp.asarray(nw), spec),
        nmask=jax.device_put(jnp.asarray(nmask), spec),
    )


def graph_input_specs(n_pad: int, d_max: int):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    return dict(
        nbr=jax.ShapeDtypeStruct((n_pad, d_max), jnp.int32),
        nw=jax.ShapeDtypeStruct((n_pad, d_max), jnp.float32),
        nmask=jax.ShapeDtypeStruct((n_pad, d_max), jnp.bool_),
        labels=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        active=jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        iteration=jax.ShapeDtypeStruct((), jnp.int32),
        n_real=jax.ShapeDtypeStruct((), jnp.int32),
    )


def make_lpa_step(mesh: Mesh, n_pad: int, d_max: int,
                  exchange_every: int = 1, mode: str = "auto",
                  trace_hook=None):
    """Build the jitted distributed LPA iteration.

    One call runs ``exchange_every`` semi-synchronous iterations (2 parity
    sub-sweeps each).  With ``exchange_every=1`` every sub-sweep ends in a
    label all-gather — bit-identical to the single-device engine.  With
    k > 1 only the final sub-sweep all-gathers; earlier sub-sweeps patch the
    device-local slice of the replica (remote labels go stale — the
    beyond-paper collective-term lever).

    Step signature: (nbr, nw, nmask, labels, active, iteration, n_real)
                 -> (labels', active', delta_n)
    ``labels`` replicated (n_pad,); ``active`` row-sharded (n_pad,);
    tiles row-sharded (n_pad, d_max).  ``n_real`` is the unpadded vertex
    count as a traced scalar, so one compiled step serves every graph that
    pads to the same (n_pad, d_max) — the engine's shape-bucket contract.

    ``trace_hook``, when given, is called (with no args) each time the step
    is actually traced — the engine's compile-observability hook.
    """
    axes = _all_axes(mesh)
    n_dev = int(np.prod(mesh.devices.shape))
    n_loc = n_pad // n_dev
    assert n_pad % n_dev == 0
    num_sweeps = 2 * exchange_every

    def step(nbr, nw, nmask, labels, active, iteration, n_real):
        if trace_hook is not None:
            trace_hook()
        row0 = jax.lax.axis_index(axes) * n_loc
        local_ids = row0 + jnp.arange(n_loc, dtype=jnp.int32)
        real_loc = local_ids < n_real
        parity_loc = (_label_hash(local_ids, jnp.int32(-1)) & 1).astype(bool)
        dn_total = jnp.int32(0)

        for s in range(num_sweeps):
            klass = parity_loc if (s % 2) else ~parity_loc
            cand = active & klass & real_loc
            seed = jnp.asarray(num_sweeps * iteration + s, jnp.int32)

            cur = labels[local_ids]
            best_lab, best_w, cur_w = ops.label_argmax(
                labels[nbr], nw, nmask, cur, seed, mode=mode)
            adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))
            new_local = jnp.where(adopt, best_lab, cur)
            changed_local = new_local != cur

            labels_prev = labels
            if s == num_sweeps - 1 or exchange_every == 1:
                # coherent exchange: ONE label all-gather per sub-sweep.
                # (beyond-paper: the changed mask is never exchanged — it is
                # recovered locally as new-replica != old-replica, saving a
                # pred[n] all-gather per sweep, ~20% of collective bytes)
                labels = jax.lax.all_gather(new_local, axes, tiled=True)
            else:
                # stale sub-sweep: patch local slice only (no collective)
                labels = jax.lax.dynamic_update_slice(labels, new_local,
                                                      (row0,))
            changed = labels != labels_prev
            dn_total = dn_total + jax.lax.psum(
                jnp.sum(changed_local.astype(jnp.int32)), axes)
            # pruning: local rows sleep if processed, wake on changed neighbor
            wake = jnp.any(changed[nbr] & nmask, axis=1)
            active = (active & ~cand) | (wake & real_loc)
        return labels, active, dn_total

    in_specs = (P(axes, None), P(axes, None), P(axes, None),  # tiles
                P(), P(axes), P(), P())
    out_specs = (P(), P(axes), P())
    sharded = shard_map(step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)

    tile_sharding = NamedSharding(mesh, P(axes, None))
    vec_sharding = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    return jax.jit(sharded,
                   in_shardings=(tile_sharding, tile_sharding, tile_sharding,
                                 rep, vec_sharding, rep, rep),
                   out_shardings=(rep, vec_sharding, rep))


def make_split_step(mesh: Mesh, n_pad: int, d_max: int,
                    mode: str = "auto", trace_hook=None):
    """Distributed SL-LP sweep: (tiles..., comm, labels) -> (labels', dn)."""
    axes = _all_axes(mesh)
    n_dev = int(np.prod(mesh.devices.shape))
    n_loc = n_pad // n_dev

    def step(nbr, nw, nmask, comm, labels):
        del nw
        if trace_hook is not None:
            trace_hook()
        row0 = jax.lax.axis_index(axes) * n_loc
        local_ids = row0 + jnp.arange(n_loc, dtype=jnp.int32)
        new_local = ops.min_label(labels[nbr], comm[nbr], nmask,
                                  labels[local_ids], comm[local_ids],
                                  mode=mode)
        changed = new_local != labels[local_ids]
        labels = jax.lax.all_gather(new_local, axes, tiled=True)
        dn = jax.lax.psum(jnp.sum(changed.astype(jnp.int32)), axes)
        return labels, dn

    in_specs = (P(axes, None), P(axes, None), P(axes, None), P(), P())
    out_specs = (P(), P())
    sharded = shard_map(step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)
    tile = NamedSharding(mesh, P(axes, None))
    rep = NamedSharding(mesh, P())
    return jax.jit(sharded, in_shardings=(tile, tile, tile, rep, rep),
                   out_shardings=(rep, rep))


def distributed_gsl_lpa(graph: Graph, mesh: Mesh, tau: float = 0.05,
                        max_iterations: int = 20, exchange_every: int = 1,
                        mode: str = "auto", checkpoint_cb=None):
    """Host-driven distributed GSL-LPA (propagation + SL-LP split).

    ``checkpoint_cb(phase, iteration, labels)`` is invoked after every
    iteration — the FT hook (state is the complete restart point).
    """
    sg = shard_graph(graph, mesh)
    step = make_lpa_step(mesh, sg.n_pad, sg.d_max,
                         exchange_every=exchange_every, mode=mode)
    rep = NamedSharding(mesh, P())
    vec = NamedSharding(mesh, P(_all_axes(mesh)))
    labels = jax.device_put(jnp.arange(sg.n_pad, dtype=jnp.int32), rep)
    active = jax.device_put(
        jnp.arange(sg.n_pad, dtype=jnp.int32) < sg.n, vec)
    it = 0
    while it < max_iterations:
        labels, active, dn = step(sg.nbr, sg.nw, sg.nmask, labels, active,
                                  jnp.int32(it), jnp.int32(sg.n))
        it += 1
        if checkpoint_cb is not None:
            checkpoint_cb("lpa", it, labels)
        # lint: host-sync-ok — documented convergence sync: one scalar
        if int(dn) <= tau * sg.n:
            break

    split = make_split_step(mesh, sg.n_pad, sg.d_max, mode=mode)
    comm = labels
    labels2 = jax.device_put(jnp.arange(sg.n_pad, dtype=jnp.int32), rep)
    sit = 0
    while True:
        labels2, dn = split(sg.nbr, sg.nw, sg.nmask, comm, labels2)
        sit += 1
        if checkpoint_cb is not None:
            checkpoint_cb("split", sit, labels2)
        # lint: host-sync-ok — split fixed-point test, one scalar per round
        if int(dn) == 0:
            break
    return np.asarray(labels2[: sg.n]), it, sit
