"""The paper's primary contribution: GSL-LPA community detection in JAX."""
from repro.core.batch import GraphBatch  # noqa: F401
from repro.core.delta import (  # noqa: F401
    GraphDelta,
    affected_frontier,
    apply_delta,
    apply_delta_patch,
    undirected_edges,
)
from repro.core.graph import Graph, build_graph, graph_fingerprint  # noqa: F401
from repro.core.gsl import GslResult, gsl_lpa, gve_lpa  # noqa: F401
from repro.core.lpa import LpaState, lpa_move, lpa_run  # noqa: F401
from repro.core.modularity import modularity  # noqa: F401
from repro.core.detect import (  # noqa: F401
    disconnected_communities,
    disconnected_communities_host,
    disconnected_fraction,
)
from repro.core.split import (  # noqa: F401
    compact_labels,
    num_communities,
    split_bfs_host,
    split_lp,
    split_lpp,
)
