"""Split-Last (SL): separate internally-disconnected communities.

Implements the paper's three techniques (Section 4):

* ``split_lp``   — Algorithm 1, minimum-label Label Propagation (SL-LP).
* ``split_lpp``  — Algorithm 1 with pruning (SL-LPP).
* ``split_bfs_host`` — Algorithm 2, per-community BFS.  BFS worklists are
  inherently sequential per component; this is the paper's preferred *CPU*
  technique and is kept as the host execution path / test oracle.  On TPU the
  production path is LP/LPP (see DESIGN.md §2 — the CPU ranking flips).

Beyond-paper optimization: ``shortcut=True`` adds Shiloach-Vishkin pointer
shortcutting (``L <- min(L, L[L])`` after each neighbor-min sweep).  Labels
always point at a vertex in the same community and component, so adopting the
label's label is sound; it collapses convergence from O(component diameter)
to O(log diameter) sweeps.  Disabled by default for paper-faithful runs.
"""
from __future__ import annotations

from collections import deque
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, to_numpy_adj


class SplitState(NamedTuple):
    labels: jnp.ndarray     # (n,) int32 minimum-label per (community, component)
    active: jnp.ndarray     # (n,) bool  pruning flags (LPP only; all-True for LP)
    iterations: jnp.ndarray  # () int32
    delta_n: jnp.ndarray    # () int32


def _min_label_sweep(graph: Graph, comm: jnp.ndarray, labels: jnp.ndarray,
                     active: jnp.ndarray, prune: bool, shortcut: bool,
                     voffset: jnp.ndarray | None = None,
                     label_bound: jnp.ndarray | int | None = None):
    """One sweep of Algorithm 1's loop body (lines 8-21), vectorised.

    ``voffset``: per-vertex owner offsets when labels are in per-graph
    *local* coordinates (the batched path) — the shortcut's pointer jump
    must gather at the label's global row, ``label + voffset``.

    ``label_bound``: exclusive upper bound on real label values, used as
    the no-same-community-neighbor sentinel.  Defaults to ``graph.n``;
    the out-of-core partition path sweeps compact local row spaces whose
    labels are *global* vertex ids and passes the full graph's vertex
    count (traced — one executable serves every partition).
    """
    n = graph.n
    bound = n if label_bound is None else label_bound
    same = graph.edge_mask & (comm[graph.src] == comm[graph.dst])
    # min over same-community neighbors; sentinel `bound` elsewhere
    cand = jnp.where(same, labels[graph.dst], bound).astype(jnp.int32)
    nbr_min = jax.ops.segment_min(cand, graph.src, num_segments=n)
    new = jnp.minimum(labels, nbr_min.astype(labels.dtype))
    if prune:
        new = jnp.where(active, new, labels)
    if shortcut:  # pointer jump (beyond-paper)
        new = jnp.minimum(new, new[new if voffset is None else new + voffset])
    changed = new != labels
    delta_n = jnp.sum(changed.astype(jnp.int32))
    if prune:
        # reactivate same-community neighbors of changed vertices (line 20-21)
        nxt_active = jax.ops.segment_max(
            (changed[graph.dst] & same).astype(jnp.int32), graph.src,
            num_segments=n) > 0
    else:
        nxt_active = active
    return new, nxt_active, changed, delta_n


@partial(jax.jit, static_argnames=("prune", "shortcut", "profile_rows"))
def split_lp(graph: Graph, comm: jnp.ndarray, prune: bool = False,
             shortcut: bool = False, profile_rows: int = 0,
             n_real: jnp.ndarray | None = None):
    """Algorithm 1: SL-LP (``prune=False``) / SL-LPP (``prune=True``).

    Returns labels where each vertex carries the minimum vertex id reachable
    within (its community x its connected component) — i.e. one unique label
    per component per community, which is exactly the split partition.

    ``profile_rows`` (static, 0 = off): carry a ``(profile_rows, 3)``
    int32 buffer writing [active count, changed count, sweep index] per
    sweep (rows past the cap overwrite the last — the caller flags
    truncation from the iteration count).  Buffer writes never feed back,
    so profiled runs stay bit-identical; returns ``(SplitState, buffer)``.
    ``n_real`` (traced, optional) masks bucket-padding vertices out of
    the recorded active counts — it does not affect the sweep itself.
    """
    n = graph.n
    comm = comm.astype(jnp.int32)
    state = SplitState(labels=jnp.arange(n, dtype=jnp.int32),
                       active=jnp.ones(n, dtype=bool),
                       iterations=jnp.int32(0), delta_n=jnp.int32(n))
    real = (jnp.ones(n, dtype=bool) if n_real is None
            else jnp.arange(n, dtype=jnp.int32) < n_real)

    def cond(carry):
        s = carry[0] if profile_rows else carry
        return s.delta_n > 0

    def body(carry):
        s, buf = carry if profile_rows else (carry, None)
        new, nxt_active, _, dn = _min_label_sweep(
            graph, comm, s.labels, s.active, prune, shortcut)
        if profile_rows:
            row = jnp.minimum(s.iterations, profile_rows - 1)
            buf = buf.at[row].set(jnp.stack(
                [jnp.sum((s.active & real).astype(jnp.int32)), dn,
                 s.iterations]))
        nxt = SplitState(new, nxt_active, s.iterations + 1, dn)
        return (nxt, buf) if profile_rows else nxt

    if profile_rows:
        buf0 = jnp.full((profile_rows, 3), -1, jnp.int32)
        return jax.lax.while_loop(cond, body, (state, buf0))
    return jax.lax.while_loop(cond, body, state)


def split_lpp(graph: Graph, comm: jnp.ndarray, shortcut: bool = False):
    return split_lp(graph, comm, prune=True, shortcut=shortcut)


@partial(jax.jit, static_argnames=("prune",))
def min_label_sweep(graph: Graph, comm: jnp.ndarray, labels: jnp.ndarray,
                    active: jnp.ndarray, label_bound: jnp.ndarray,
                    prune: bool = False) -> jnp.ndarray:
    """Partition-local split sweep: one Algorithm-1 step over a CSR slice.

    The out-of-core driver (:mod:`repro.partition.ooc`) runs the §3.3
    split phase one partition at a time: ``graph`` is a compact local
    subgraph (partition rows followed by halo rows), ``comm`` / ``labels``
    carry *global* community ids and split labels gathered for those rows,
    and ``label_bound`` is the full graph's vertex count.  Because the
    sweep is synchronous (new labels are a pure function of the pre-sweep
    snapshot), sweeping partitions sequentially against a shared snapshot
    and double-buffering the results is bit-identical to the in-core
    :func:`split_lp` sweep — the cross-partition label unification is the
    outer fixed-point loop over these sweeps.  The pointer-shortcut jump
    needs the full label array, so it is *not* applied here; the driver
    applies it globally after assembling the sweep (same ordering as the
    in-core sweep body).  Returns the new labels (pre-shortcut).
    """
    new, _, _, _ = _min_label_sweep(graph, comm, labels, active,
                                    prune=prune, shortcut=False,
                                    label_bound=label_bound)
    return new


@jax.jit
def min_label_wake(graph: Graph, comm: jnp.ndarray,
                   changed: jnp.ndarray) -> jnp.ndarray:
    """Pruning reactivation for a partition-local split sweep.

    A vertex re-enters the SL-LPP worklist exactly when one of its
    same-community neighbors changed label in the previous sweep
    (Algorithm 1 lines 20-21).  ``changed`` holds the previous sweep's
    global changed flags gathered to this slice's local rows; only the
    slice's own edges are needed because the reactivation rule reads each
    vertex's *own* neighborhood.
    """
    same = graph.edge_mask & (comm[graph.src] == comm[graph.dst])
    return jax.ops.segment_max(
        (changed[graph.dst] & same).astype(jnp.int32), graph.src,
        num_segments=graph.n) > 0


def split_bfs_host(graph: Graph, comm: np.ndarray) -> np.ndarray:
    """Algorithm 2: per-community BFS splitting (host / oracle path).

    Sequential-per-component frontier BFS with the paper's semantics: each
    still-unvisited vertex seeds a BFS restricted to its community; all
    reached vertices adopt the seed's id as their new community label.
    """
    adj = to_numpy_adj(graph)
    comm = np.asarray(comm)
    n = graph.n
    out = np.arange(n, dtype=np.int32)
    visited = np.zeros(n, dtype=bool)
    for i in range(n):
        if visited[i]:
            continue
        visited[i] = True
        q = deque([i])
        while q:
            u = q.popleft()
            out[u] = i
            for v, _w in adj[u]:
                if not visited[v] and comm[v] == comm[i]:
                    visited[v] = True
                    q.append(v)
    return out


def compact_labels(labels: jnp.ndarray) -> jnp.ndarray:
    """Relabel communities to a dense [0, K) range (jit-able, any values)."""
    sort_lab = jnp.sort(labels)
    is_new = jnp.concatenate([jnp.ones((1,), bool),
                              sort_lab[1:] != sort_lab[:-1]])
    rank_at_pos = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    idx = jnp.searchsorted(sort_lab, labels, side="left")
    return rank_at_pos[idx].astype(jnp.int32)


def num_communities(labels: jnp.ndarray) -> jnp.ndarray:
    sort_lab = jnp.sort(labels)
    is_new = jnp.concatenate([jnp.ones((1,), bool), sort_lab[1:] != sort_lab[:-1]])
    return jnp.sum(is_new.astype(jnp.int32))
