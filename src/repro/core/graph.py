"""Graph representation for the GSL-LPA engine.

A ``Graph`` is an immutable pytree holding a padded CSR / edge-list hybrid:
edges are stored *directed both ways* (undirected graph semantics, as in the
paper) and sorted by source vertex, so the ``src`` array is the CSR expansion
of ``row_ptr``.  Padding slots (up to ``m_pad``, a multiple of 128 for TPU
alignment) carry ``src = dst = 0``, ``wgt = 0`` and ``edge_mask = False``.

Host-side construction is numpy; the resulting arrays are device arrays.
Static metadata (``n``, ``m_pad``, ``num_edges``) lives in pytree aux data so
jitted functions specialise on shape, never on content.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_LANE = 128  # TPU lane alignment for padded edge arrays.


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@partial(jax.tree_util.register_dataclass,
         data_fields=("row_ptr", "src", "dst", "wgt", "edge_mask", "kdeg"),
         meta_fields=("n", "m_pad", "num_edges"))
@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded undirected graph (both edge directions materialised)."""
    # --- static metadata ---
    n: int          # number of vertices
    m_pad: int      # padded directed edge count (multiple of 128)
    num_edges: int  # actual directed edge count (2x undirected)
    # --- arrays ---
    row_ptr: jnp.ndarray   # (n + 1,) int32, CSR offsets into src/dst/wgt
    src: jnp.ndarray       # (m_pad,) int32, edge sources (sorted)
    dst: jnp.ndarray       # (m_pad,) int32, edge destinations
    wgt: jnp.ndarray       # (m_pad,) float32, edge weights (0 on padding)
    edge_mask: jnp.ndarray  # (m_pad,) bool, True for real edges
    kdeg: jnp.ndarray      # (n,) float32, weighted degree K_i

    @property
    def total_weight(self) -> jnp.ndarray:
        """Sum of directed edge weights == 2m in the paper's notation."""
        return jnp.sum(self.wgt)

    def degrees(self) -> jnp.ndarray:
        return self.row_ptr[1:] - self.row_ptr[:-1]


def build_graph(edges: np.ndarray, weights: np.ndarray | None = None,
                n: int | None = None, symmetrize: bool = True) -> Graph:
    """Build a :class:`Graph` from an undirected edge list.

    Args:
      edges: (E, 2) int array of endpoints.  Self loops are dropped
        (``scanCommunities`` excludes i == j).  Duplicate edges are merged
        with their weights summed.
      weights: (E,) float array; defaults to unit weights (paper default).
      n: vertex count; defaults to ``edges.max() + 1``.
      symmetrize: materialise both directions (paper: undirected).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if weights is None:
        weights = np.ones(len(edges), dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64).reshape(-1)
    if n is None:
        n = int(edges.max()) + 1 if len(edges) else 1

    keep = edges[:, 0] != edges[:, 1]
    edges, weights = edges[keep], weights[keep]
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
        weights = np.concatenate([weights, weights], axis=0)

    # Merge duplicates: sort by (src, dst), sum weights over runs.
    key = edges[:, 0] * n + edges[:, 1]
    order = np.argsort(key, kind="stable")
    key, edges, weights = key[order], edges[order], weights[order]
    uniq, inv = np.unique(key, return_inverse=True)
    wsum = np.zeros(len(uniq), dtype=np.float64)
    np.add.at(wsum, inv, weights)
    usrc = (uniq // n).astype(np.int32)
    udst = (uniq % n).astype(np.int32)

    num_edges = len(uniq)
    m_pad = max(_round_up(num_edges, _LANE), _LANE)
    src = np.zeros(m_pad, dtype=np.int32)
    dst = np.zeros(m_pad, dtype=np.int32)
    wgt = np.zeros(m_pad, dtype=np.float32)
    mask = np.zeros(m_pad, dtype=bool)
    src[:num_edges], dst[:num_edges] = usrc, udst
    wgt[:num_edges] = wsum.astype(np.float32)
    mask[:num_edges] = True

    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(row_ptr[1:], usrc, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)

    kdeg = np.zeros(n, dtype=np.float64)
    np.add.at(kdeg, usrc, wsum)

    graph = Graph(
        n=int(n), m_pad=int(m_pad), num_edges=int(num_edges),
        row_ptr=jnp.asarray(row_ptr),
        src=jnp.asarray(src), dst=jnp.asarray(dst), wgt=jnp.asarray(wgt),
        edge_mask=jnp.asarray(mask), kdeg=jnp.asarray(kdeg, dtype=jnp.float32),
    )
    # Fingerprint eagerly while the CSR is still host memory: every later
    # graph_fingerprint() (warm-cache lookups, StreamSession bookkeeping)
    # is then a dict read instead of a device->host copy + CRC.
    _set_fingerprint(graph, row_ptr, dst)
    return graph


def _set_fingerprint(graph: Graph, row_ptr: np.ndarray,
                     dst: np.ndarray) -> None:
    """Attach the structural fingerprint from host-side CSR arrays."""
    import zlib
    fp = (graph.n, graph.num_edges,
          zlib.crc32(np.ascontiguousarray(row_ptr).tobytes()),
          zlib.crc32(np.ascontiguousarray(dst).tobytes()))
    object.__setattr__(graph, "_fingerprint", fp)


def graph_fingerprint(graph: Graph) -> tuple:
    """Cheap structural identity: (n, m, crc of offsets, crc of dst).

    Used by the engine's ``warm_start="auto"`` keying — two graphs that
    merely share a vertex count must not warm-start off each other.
    Weights are deliberately excluded: a re-weighted graph keeps the same
    structure and its old labels remain a sound starting point.

    The result is memoized on the instance (frozen dataclass, hence the
    ``object.__setattr__``): re-fitting the same Graph object — the
    warm-start serving pattern — pays the O(m) device-to-host copy and
    CRC only once.
    """
    fp = getattr(graph, "_fingerprint", None)
    if fp is None:
        import zlib
        fp = (graph.n, graph.num_edges,
              zlib.crc32(np.asarray(graph.row_ptr).tobytes()),
              zlib.crc32(np.asarray(graph.dst).tobytes()))
        object.__setattr__(graph, "_fingerprint", fp)
    return fp


def to_numpy_adj(graph: Graph) -> list[list[tuple[int, float]]]:
    """Host adjacency list (for the BFS oracle / host split path)."""
    src = np.asarray(graph.src)[: graph.num_edges]
    dst = np.asarray(graph.dst)[: graph.num_edges]
    wgt = np.asarray(graph.wgt)[: graph.num_edges]
    adj: list[list[tuple[int, float]]] = [[] for _ in range(graph.n)]
    for s, d, w in zip(src.tolist(), dst.tolist(), wgt.tolist()):
        adj[s].append((d, w))
    return adj


def to_padded_neighbors(graph: Graph, d_max: int | None = None,
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense padded neighbor matrices for the Pallas tile path.

    Returns (nbr, nw, nmask) with shapes (n_pad, d_max): neighbor vertex ids,
    weights, and validity.  ``n_pad`` rounds n up to 8 (sublane), ``d_max``
    rounds the max degree up to 128 (lane).  Pad neighbor ids point at the row
    vertex itself with weight 0 (self edges are excluded by construction, so a
    0-weight self slot can never win the argmax).
    """
    row_ptr = np.asarray(graph.row_ptr)
    dst = np.asarray(graph.dst)[: graph.num_edges]
    wgt = np.asarray(graph.wgt)[: graph.num_edges]
    deg = row_ptr[1:] - row_ptr[:-1]
    if d_max is None:
        d_max = max(int(deg.max()) if len(deg) else 1, 1)
    d_max = _round_up(d_max, _LANE)
    n_pad = _round_up(graph.n, 8)

    nbr = np.repeat(np.arange(n_pad, dtype=np.int32)[:, None], d_max, axis=1)
    nw = np.zeros((n_pad, d_max), dtype=np.float32)
    nmask = np.zeros((n_pad, d_max), dtype=bool)
    for i in range(graph.n):
        lo, hi = int(row_ptr[i]), int(row_ptr[i + 1])
        k = min(hi - lo, d_max)
        nbr[i, :k] = dst[lo:lo + k]
        nw[i, :k] = wgt[lo:lo + k]
        nmask[i, :k] = True
    return nbr, nw, nmask
