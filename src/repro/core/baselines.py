"""Reimplementations of the LPA baselines the paper compares against.

The paper benchmarks FLPA (Traag & Subelj queue-based LPA), igraph LPA
(sequential synchronous-ish LPA), and NetworKit PLP (parallel LPA with an
update threshold).  Linking the original C/C++ packages is out of scope in
this offline container, so each is reimplemented *algorithmically* on the
host (numpy) with the defining feature preserved:

* ``flpa_host``      — FIFO queue of vertices whose neighborhood changed;
                       only those are rescanned (FLPA's defining trick).
* ``igraph_lpa_host``— sequential asynchronous LPA in random vertex order,
                       iterated until a full quiet pass (igraph semantics).
* ``networkit_plp``  — synchronous parallel LPA sweeps with an update
                       threshold (theta = n / 1e5, NetworKit's default) —
                       expressed with the same vectorised JAX sweep as
                       GVE-LPA but *without* pruning, mirroring PLP.

All baselines share tie-break semantics with the main implementation
(max weight, then smallest label; keep current on ties) so quality
differences reflect algorithm structure, not arbitrary tie choices.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, to_numpy_adj
from repro.core.lpa import lpa_move


def _best_label(adj_i, labels, cur) -> int:
    acc: dict[int, float] = {}
    for j, w in adj_i:
        c = int(labels[j])
        acc[c] = acc.get(c, 0.0) + w
    if not acc:
        return cur
    best_w = max(acc.values())
    cands = sorted(c for c, w in acc.items() if w >= best_w)
    if acc.get(cur, -1.0) >= best_w:
        return cur
    return cands[0]


def flpa_host(graph: Graph, max_passes: int = 100) -> np.ndarray:
    """Fast Label Propagation (Traag & Subelj 2023): queue-driven updates."""
    adj = to_numpy_adj(graph)
    n = graph.n
    labels = np.arange(n, dtype=np.int64)
    inq = np.ones(n, dtype=bool)
    q = deque(range(n))
    steps = 0
    limit = max_passes * n
    while q and steps < limit:
        i = q.popleft()
        inq[i] = False
        steps += 1
        c = _best_label(adj[i], labels, int(labels[i]))
        if c != labels[i]:
            labels[i] = c
            for j, _w in adj[i]:
                if labels[j] != c and not inq[j]:
                    inq[j] = True
                    q.append(j)
    return labels.astype(np.int32)


def igraph_lpa_host(graph: Graph, seed: int = 0, max_passes: int = 50,
                    ) -> np.ndarray:
    """Sequential asynchronous LPA in shuffled order (igraph-style)."""
    adj = to_numpy_adj(graph)
    rng = np.random.default_rng(seed)
    n = graph.n
    labels = np.arange(n, dtype=np.int64)
    for _ in range(max_passes):
        order = rng.permutation(n)
        changed = 0
        for i in order:
            c = _best_label(adj[i], labels, int(labels[i]))
            if c != labels[i]:
                labels[i] = c
                changed += 1
        if changed == 0:
            break
    return labels.astype(np.int32)


def networkit_plp(graph: Graph, theta: float | None = None,
                  max_iterations: int = 100) -> np.ndarray:
    """NetworKit-style PLP: synchronous parallel sweeps, threshold stop."""
    n = graph.n
    if theta is None:
        theta = max(n / 1e5, 1.0)
    labels = jnp.arange(n, dtype=jnp.int32)
    active = jnp.ones(n, dtype=bool)
    for it in range(max_iterations):
        labels, _changed, dn = lpa_move(graph, labels, active, it)
        labels = jax.block_until_ready(labels)
        if int(dn) <= theta:
            break
    return np.asarray(labels)
