"""Finding internally-disconnected communities (paper Appendix A.1, Alg. 4).

The paper's Algorithm 4 BFS-walks each community from one representative and
flags the community if fewer vertices are reached than its size.  The
TPU-native equivalent: run the (deterministic) min-label component pass of
``split_lp`` and count *distinct component roots per community* with a
sort + segment reduction — a community is disconnected iff it has >= 2 roots.
Both formulations are deterministic and agree exactly (tests enforce this
against a host BFS oracle).
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, to_numpy_adj
from repro.core.split import split_lp


@jax.jit
def disconnected_communities(graph: Graph, comm: jnp.ndarray):
    """Returns (flags, n_disconnected, n_communities).

    ``flags`` is an (n,) bool array indexed by community label value:
    ``flags[c]`` is True iff community ``c`` is non-empty and internally
    disconnected.
    """
    n = graph.n
    comm = comm.astype(jnp.int32)
    roots = split_lp(graph, comm).labels  # one root per (community, component)

    # Count distinct (community, root) pairs per community.
    c_s, r_s = jax.lax.sort((comm, roots), num_keys=2)
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), c_s[:-1]])
    prev_r = jnp.concatenate([jnp.full((1,), -1, jnp.int32), r_s[:-1]])
    new_pair = (c_s != prev_c) | (r_s != prev_r)
    pair_count = jax.ops.segment_sum(new_pair.astype(jnp.int32), c_s,
                                     num_segments=n)
    flags = pair_count > 1
    n_communities = jnp.sum((pair_count > 0).astype(jnp.int32))
    n_disconnected = jnp.sum(flags.astype(jnp.int32))
    return flags, n_disconnected, n_communities


def disconnected_fraction(graph: Graph, comm: jnp.ndarray) -> jnp.ndarray:
    _, bad, total = disconnected_communities(graph, comm)
    return bad.astype(jnp.float32) / jnp.maximum(total, 1).astype(jnp.float32)


def disconnected_communities_host(graph: Graph, comm: np.ndarray) -> dict:
    """Host BFS oracle mirroring Algorithm 4 literally (per-community BFS
    from one representative; flag if reached < community size)."""
    adj = to_numpy_adj(graph)
    comm = np.asarray(comm)
    n = graph.n
    sizes: dict[int, int] = {}
    rep: dict[int, int] = {}
    for i in range(n):
        c = int(comm[i])
        sizes[c] = sizes.get(c, 0) + 1
        rep.setdefault(c, i)
    flags: dict[int, bool] = {}
    for c, seed in rep.items():
        visited = {seed}
        q = deque([seed])
        while q:
            u = q.popleft()
            for v, _w in adj[u]:
                if v not in visited and comm[v] == c:
                    visited.add(v)
                    q.append(v)
        flags[c] = len(visited) < sizes[c]
    return flags
