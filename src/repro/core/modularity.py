"""Modularity (paper Eq. 1), computed with segment reductions.

With both edge directions stored, let S = sum of directed weights = 2m,
in_c = directed weight inside community c, K_c = sum of weighted degrees in
community c.  Then  Q = sum_c [ in_c / S - (K_c / S)^2 ].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import Graph


@jax.jit
def modularity(graph: Graph, comm: jnp.ndarray) -> jnp.ndarray:
    n = graph.n
    comm = comm.astype(jnp.int32)
    s = graph.total_weight  # = 2m
    within = graph.edge_mask & (comm[graph.src] == comm[graph.dst])
    in_c = jax.ops.segment_sum(jnp.where(within, graph.wgt, 0.0),
                               comm[graph.src], num_segments=n)
    k_c = jax.ops.segment_sum(graph.kdeg, comm, num_segments=n)
    s = jnp.maximum(s, 1e-30)   # empty graph: Q := 0, not NaN
    return jnp.sum(in_c / s - (k_c / s) ** 2)
