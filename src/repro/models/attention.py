"""GQA attention: chunked (flash-style) training path + KV-cache serving.

Training/prefill use an online-softmax scan over KV chunks so the (S, S)
score matrix is never materialised — peak activation is O(S * chunk) per
head instead of O(S^2), which is what lets 32k prefill fit HBM.  The causal
rectangle is still computed in full (masked); the strict lower-triangle
saving needs a Pallas flash kernel and is tracked as a §Perf item.

Decode is a single-token query against a (B, S_max, K, hd) cache with
``dynamic_update_slice`` in-place-able updates (XLA donates the buffer).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, beinsum
from repro.models.layers import apply_rope, rope_frequencies

NEG_INF = -1e30


class KVCache(NamedTuple):
    """KV cache; optionally int8-quantised (k/v int8 + per-(token, head)
    bf16 scales — halves serving HBM; §Perf serving lever)."""
    k: jnp.ndarray       # (B, S_max, K, hd)  bf16 or int8
    v: jnp.ndarray       # (B, S_max, K, hd)
    length: jnp.ndarray  # () int32 — tokens currently in cache
    k_scale: jnp.ndarray | None = None   # (B, S_max, K, 1) bf16 (int8 mode)
    v_scale: jnp.ndarray | None = None


def quantize_kv(x: jnp.ndarray):
    """Symmetric per-(token, head) int8: (B, S, K, hd) -> (q8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def attention_specs(d: int, n_heads: int, n_kv: int, head_dim: int,
                    qkv_bias: bool = False) -> dict:
    s = {
        "wq": ParamSpec((d, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d), ("heads", "head_dim", "embed")),
    }
    if qkv_bias:
        s["bq"] = ParamSpec((n_heads, head_dim), ("heads", "head_dim"),
                            init="zeros")
        s["bk"] = ParamSpec((n_kv, head_dim), ("kv_heads", "head_dim"),
                            init="zeros")
        s["bv"] = ParamSpec((n_kv, head_dim), ("kv_heads", "head_dim"),
                            init="zeros")
    return s


def mask_padded_heads(params: dict, real_h: int | None,
                      real_k: int | None) -> dict:
    """Zero-mask TP-padding heads (configs/base.py ``n_heads_padded``).

    With zero wq/wk/wv/wo slices the padded heads produce zero output and
    receive zero gradients — the model is exactly the logical architecture.
    """
    p = dict(params)
    h = p["wq"].shape[1]
    if real_h is not None and real_h < h:
        mh = (jnp.arange(h) < real_h).astype(p["wq"].dtype)
        p["wq"] = p["wq"] * mh[None, :, None]
        p["wo"] = p["wo"] * mh[:, None, None]
        if "bq" in p:
            p["bq"] = p["bq"] * mh[:, None]
    k = p["wk"].shape[1]
    if real_k is not None and real_k < k:
        mk = (jnp.arange(k) < real_k).astype(p["wk"].dtype)
        p["wk"] = p["wk"] * mk[None, :, None]
        p["wv"] = p["wv"] * mk[None, :, None]
        if "bk" in p:
            p["bk"] = p["bk"] * mk[:, None]
            p["bv"] = p["bv"] * mk[:, None]
    return p


def _project_qkv(params, x, positions, rope_theta):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,K,hd), RoPE applied."""
    q = beinsum("bsd,dhk->bshk", x, params["wq"])
    k = beinsum("bsd,dhk->bshk", x, params["wk"])
    v = beinsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if rope_theta is not None:
        cos, sin = rope_frequencies(q.shape[-1], positions, rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    return q, k, v


def chunked_attention(q, k, v, q_positions, kv_positions, *, causal: bool,
                      chunk: int = 512, window: int | None = None,
                      kv_valid_len=None, k_scale=None, v_scale=None):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, hd); k/v: (B, Skv, K, hd) with H = K * G.
    Returns (B, Sq, H, hd).  Masks: causal (q_pos >= kv_pos), optional
    sliding window, optional kv_valid_len (ragged cache).  With
    k_scale/v_scale (int8 cache), chunks are dequantised in-loop — the
    (B, S, K, hd) fp tensors never materialise.
    """
    b, sq, h, hd = q.shape
    skv, kk = k.shape[1], k.shape[2]
    g = h // kk
    assert h % kk == 0
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, sq, kk, g, hd).astype(jnp.float32) * scale

    n_chunks = skv // chunk if skv % chunk == 0 else -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=2**30)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kk, hd)
    vc = v.reshape(b, n_chunks, chunk, kk, hd)
    pc = kv_positions.reshape(n_chunks, chunk)
    quant = k_scale is not None
    if quant:
        ksc = k_scale.reshape(b, n_chunks, chunk, kk, 1)
        vsc = v_scale.reshape(b, n_chunks, chunk, kk, 1)

    def body(carry, xs):
        m, l, acc = carry
        if quant:
            k_i, v_i, p_i, ks_i, vs_i = xs
            k_i = k_i.astype(jnp.float32) * ks_i.astype(jnp.float32)
            v_i = v_i.astype(jnp.float32) * vs_i.astype(jnp.float32)
        else:
            k_i, v_i, p_i = xs      # (B, chunk, K, hd), ..., (chunk,)
        logits = jnp.einsum("bqkgh,bckh->bqkgc", qg,
                            k_i.astype(jnp.float32))   # (B,Sq,K,G,chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_positions[:, None] >= p_i[None, :]
        if window is not None:
            mask &= q_positions[:, None] - p_i[None, :] < window
        if kv_valid_len is not None:
            mask &= (p_i < kv_valid_len)[None, :]
        mask &= (p_i < 2**30)[None, :]                 # chunk padding
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p, v_i.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kk, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kk, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, kk, g, hd), jnp.float32)
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc)
    if quant:
        xs = xs + (jnp.moveaxis(ksc, 1, 0), jnp.moveaxis(vsc, 1, 0))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_train(params, x, positions, *, n_heads, n_kv, head_dim,
                    rope_theta=10000.0, causal=True, chunk=512,
                    window=None):
    """Full-sequence attention (training / encoder)."""
    q, k, v = _project_qkv(params, x, positions, rope_theta)
    out = chunked_attention(q, k, v, positions, positions, causal=causal,
                            chunk=chunk, window=window)
    return beinsum("bshk,hkd->bsd", out, params["wo"])


def attention_prefill(params, x, positions, s_max, *, rope_theta=10000.0,
                      chunk=512, window=None, quantize: bool = False):
    """Causal prefill: returns (output, populated KVCache of size s_max)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, rope_theta)
    out = chunked_attention(q, k, v, positions, positions, causal=True,
                            chunk=chunk, window=window)
    # pad (not DUS-into-zeros): keeps the cache init data-dependent so XLA
    # constant folding can never materialise an s_max-sized literal
    grow = ((0, 0), (0, s_max - s), (0, 0), (0, 0))
    if quantize:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache = KVCache(k=jnp.pad(kq, grow), v=jnp.pad(vq, grow),
                        length=jnp.int32(s),
                        k_scale=jnp.pad(ks, grow), v_scale=jnp.pad(vs, grow))
    else:
        cache = KVCache(k=jnp.pad(k, grow), v=jnp.pad(v, grow),
                        length=jnp.int32(s))
    return beinsum("bshk,hkd->bsd", out, params["wo"]), cache


def attention_decode(params, x, cache: KVCache, *, rope_theta=10000.0,
                     window=None):
    """One-token decode against the (optionally int8) cache.  x: (B, 1, d)."""
    from repro.parallel.api import shard_hint
    pos = cache.length[None]                                # (1,)
    q, k, v = _project_qkv(params, x, pos, rope_theta)
    quant = cache.k_scale is not None
    ks = vs = None
    if quant:
        k, ks = quantize_kv(k)
        v, vs = quantize_kv(v)
        ks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks,
                                                 cache.length, 1)
        vs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs,
                                                 cache.length, 1)
        ks = shard_hint(ks, "batch", "seq_kv", "kv_heads", None)
        vs = shard_hint(vs, "batch", "seq_kv", "kv_heads", None)
    kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k, cache.length, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v, cache.length, 1)
    # pin the cache layout: without this GSPMD reshards the cache onto the
    # query's kv-head split inside attention and then all-gathers the WHOLE
    # cache (in f32, via a fused upcast) to honor the output sharding —
    # 2 x 25.8 GB/step on the yi-9b decode_32k cell (§Perf iteration 1)
    kc = shard_hint(kc, "batch", "seq_kv", "kv_heads", "head_dim")
    vc = shard_hint(vc, "batch", "seq_kv", "kv_heads", "head_dim")
    s_max = kc.shape[1]
    kv_pos = jnp.arange(s_max, dtype=jnp.int32)
    out = chunked_attention(
        q, kc, vc, pos, kv_pos, causal=True,
        chunk=min(2048, s_max), window=window,
        kv_valid_len=cache.length + 1, k_scale=ks, v_scale=vs)
    y = beinsum("bshk,hkd->bsd", out, params["wo"])
    return y, KVCache(k=kc, v=vc, length=cache.length + 1,
                      k_scale=ks, v_scale=vs)


# ------------------------------------------------------ cross-attention ----
def cross_attention_specs(d: int, n_heads: int, n_kv: int, head_dim: int):
    return attention_specs(d, n_heads, n_kv, head_dim)


def cross_attention(params, x, memory_k, memory_v, memory_valid_len=None):
    """Decoder->encoder attention; memory_k/v: (B, Sm, K, hd) precomputed."""
    q = beinsum("bsd,dhk->bshk", x, params["wq"])
    sm = memory_k.shape[1]
    out = chunked_attention(
        q, memory_k, memory_v,
        jnp.zeros((x.shape[1],), jnp.int32),
        jnp.arange(sm, dtype=jnp.int32), causal=False,
        chunk=min(2048, sm), kv_valid_len=memory_valid_len)
    return beinsum("bshk,hkd->bsd", out, params["wo"])


def project_memory(params, memory):
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    k = beinsum("bsd,dhk->bshk", memory, params["wk"])
    v = beinsum("bsd,dhk->bshk", memory, params["wv"])
    return k, v
