"""Parameter plumbing for the LM substrate (flax-free, eval_shape-friendly).

Every module exposes ``specs(cfg) -> pytree[ParamSpec]``; parameters are
materialised from specs (``init_from_specs``) or abstracted for the dry-run
(``abstract_from_specs`` — pure ShapeDtypeStructs, no allocation).  Each
ParamSpec carries *logical* sharding axes ('embed', 'heads', 'ff', 'vocab',
'expert', ...) which ``repro.parallel.rules`` maps onto the physical mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]            # logical axis name (or None) per dim
    init: str = "normal"             # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_from_specs(specs, key: jax.Array):
    """Materialise parameters (deterministic per-leaf fold_in of the path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    params = []
    for i, s in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, s.dtype)
        else:
            v = (jax.random.normal(k, s.shape, jnp.float32) * s.scale
                 ).astype(s.dtype)
        params.append(v)
    return jax.tree.unflatten(treedef, params)


def abstract_from_specs(specs):
    """ShapeDtypeStruct tree for .lower() — never touches a device."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=_is_spec)


def logical_axes(specs):
    """Pytree of logical-axis tuples mirroring the param tree."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def stack_specs(specs, n: int, axis_name=None):
    """Prepend a stacking dimension (scan-over-layers) to every spec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes,
                            s.init, s.scale, s.dtype),
        specs, is_leaf=_is_spec)


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def beinsum(expr: str, *ops):
    """einsum with bf16 partial sums when every operand is bf16.

    TP-sharded contractions lower to partial dots + all-reduce of the
    *accumulator* dtype; XLA's default f32 accumulation makes every
    activation/gradient all-reduce 2x larger on the wire.  bf16 partial
    sums at TP boundaries are the standard trade (used for the §Perf
    collective-term iteration; the logits/router paths keep f32 — see the
    call sites).
    """
    if all(getattr(o, "dtype", None) == jnp.bfloat16 for o in ops):
        return jnp.einsum(expr, *ops,
                          preferred_element_type=jnp.bfloat16)
    return jnp.einsum(expr, *ops)
