"""Mixture-of-Experts with sort-based capacity dispatch (MegaBlocks-style).

The naive one-hot dispatch tensor (T, E, C) is infeasible at Arctic scale
(1M tokens x 128 experts); instead token->expert assignments are sorted by
expert id, positions within each expert computed with a segment trick, and
tokens scattered into a dense (E, C, d) buffer (unique slots -> efficient
XLA scatter).  Expert FFNs are one batched einsum over the expert axis;
tokens overflowing an expert's capacity are dropped (standard top-k MoE
semantics) and their combine weight zeroed.

Supports: top-k softmax routing with renormalisation, padded expert count
(e.g. Qwen2-MoE's 60 routed experts padded to 64 for TP divisibility —
padded experts are masked to -inf in the router), shared experts
(Qwen2-MoE) and a parallel dense residual branch (Arctic) at the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, beinsum
from repro.parallel.api import shard_hint


def moe_specs(d: int, ff: int, n_experts_padded: int) -> dict:
    e = n_experts_padded
    return {
        "router": ParamSpec((d, e), ("embed", None), scale=0.02,
                            dtype=jnp.float32),
        "gate": ParamSpec((e, d, ff), ("expert", "embed", "ff")),
        "up": ParamSpec((e, d, ff), ("expert", "embed", "ff")),
        "down": ParamSpec((e, ff, d), ("expert", "ff", "embed")),
    }


def _data_shards() -> int:
    """Data-parallel shard count from the active MeshRules (1 when unset)."""
    from repro.parallel.api import active_rules
    rules = active_rules()
    if rules is None:
        return 1
    ax = rules.mapping.get("batch")
    if not ax:
        return 1
    n = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        n *= rules.mesh.shape[a]
    return int(n)


def moe_apply(params, x, *, n_experts: int, n_experts_padded: int,
              top_k: int, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d).  Static shapes throughout.

    Dispatch is *hierarchical / shard-local* (§Perf iteration on the MoE
    cells): every data shard sorts only its own tokens and scatters them
    into its private capacity slice of the (E, dp, C_loc, d) buffer.  All
    scatter/gather index math is batched over the shard axis, so GSPMD
    partitions it locally — the naive global scatter instead lowers to a
    full (E, C, d) buffer all-reduce over the data axis (~2.5 GB/device per
    MoE layer on Jamba train_4k).  Cross-shard traffic only remains where
    it is information-theoretically required: moving expert outputs back to
    the token's shard (combine).
    """
    b, s, d = x.shape
    t = b * s
    e = n_experts_padded
    dp = _data_shards()
    if t % dp:
        dp = 1
    t_loc = t // dp
    ll = t_loc * top_k                                     # entries per shard
    xt = x.reshape(t, d)

    # ---- routing (fp32) ----
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    if n_experts < e:  # mask padded experts
        pad_mask = jnp.arange(e) >= n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- shard-local sort-based dispatch ----
    cap = int(max(8, -(-t_loc * top_k * capacity_factor // e)))
    flat_e = expert_idx.reshape(dp, ll).astype(jnp.int32)
    order = jnp.argsort(flat_e, axis=1, stable=True)       # (dp, L)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # first index of each expert's run within the shard row
    run_start = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(sorted_e)
    pos = jnp.arange(ll, dtype=jnp.int32)[None, :] - run_start
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, e * cap)  # (dp, L)

    token_in_row = order // top_k                          # (dp, L)
    x_rows = xt.reshape(dp, t_loc, d)
    gathered = jnp.take_along_axis(x_rows, token_in_row[..., None], axis=1)

    buf0 = jnp.zeros((dp, e * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bb, ii, uu: bb.at[ii].set(
        uu, mode="drop", unique_indices=True))(buf0, slot, gathered)
    buf = buf[:, :-1].reshape(dp, e, cap, d)
    # reshard shard-major -> expert-major (the "all-to-all" boundary)
    buf = jnp.swapaxes(buf, 0, 1)                          # (E, dp, cap, d)
    buf = shard_hint(buf, "expert", "batch", None, "embed")

    # ---- expert FFNs (SwiGLU), one batched einsum over experts ----
    g = beinsum("escd,edf->escf", buf, params["gate"])
    u = beinsum("escd,edf->escf", buf, params["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = beinsum("escf,efd->escd", h, params["down"])
    out_buf = shard_hint(out_buf, "expert", "batch", None, "embed")

    # ---- combine (back to shard-major, gather per shard row) ----
    out_rows = jnp.swapaxes(out_buf, 0, 1).reshape(dp, e * cap, d)
    out_rows = shard_hint(out_rows, "batch", None, "embed")
    picked = jnp.take_along_axis(
        out_rows, jnp.minimum(slot, e * cap - 1)[..., None], axis=1)
    picked = jnp.where(keep[..., None], picked, 0.0)
    unsorted = jax.vmap(lambda z, ii, uu: z.at[ii].set(
        uu, unique_indices=True))(
        jnp.zeros((dp, ll, d), x.dtype), order, picked)
    y = jnp.einsum("tkd,tk->td", unsorted.reshape(t, top_k, d),
                   gates.astype(x.dtype))
    return y.reshape(b, s, d)


# ------------------------------------------------- shared experts (Qwen) ---
def shared_expert_specs(d: int, ff_shared: int) -> dict:
    return {
        "gate": ParamSpec((d, ff_shared), ("embed", "ff")),
        "up": ParamSpec((d, ff_shared), ("embed", "ff")),
        "down": ParamSpec((ff_shared, d), ("ff", "embed")),
        "gate_proj": ParamSpec((d, 1), ("embed", None), dtype=jnp.float32),
    }


def shared_expert_apply(params, x):
    g = beinsum("bsd,df->bsf", x, params["gate"])
    u = beinsum("bsd,df->bsf", x, params["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = beinsum("bsf,fd->bsd", h, params["down"])
    gate = jax.nn.sigmoid(
        jnp.einsum("bsd,do->bso", x.astype(jnp.float32),
                   params["gate_proj"]))
    return y * gate.astype(x.dtype)
