"""Model assembler: decoder-only / hybrid / RWKV / enc-dec, scan-over-layers.

Layers are stacked into *scan groups* (``cfg.group_size`` layers per group,
chosen as the period of the layer pattern — 1 for homogeneous stacks, 8 for
Jamba's attn:mamba 1:7 interleave).  jax.lax.scan over the group stack keeps
the HLO a single group body regardless of depth — essential for 512-device
compile times — and jax.checkpoint around the group body implements the
activation-remat policy.

Decode state is a per-group-stacked cache pytree scanned alongside params.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv as rk
from repro.models.common import ParamSpec, stack_specs
from repro.parallel.api import shard_hint

NEG = -1e30


# ================================================================= specs ====
def _norm_specs(cfg):
    return (L.rmsnorm_specs(cfg.d_model) if cfg.norm == "rms"
            else L.layernorm_specs(cfg.d_model))


def _norm(cfg, params, x):
    return (L.rms_norm(params, x) if cfg.norm == "rms"
            else L.layer_norm(params, x))


def _layer_specs(cfg: ArchConfig, mix: str, mlp: str, cross: bool = False):
    s: dict[str, Any] = {}
    if mix == "attn":
        s["norm1"] = _norm_specs(cfg)
        s["attn"] = attn.attention_specs(cfg.d_model, cfg.n_heads_padded,
                                         cfg.n_kv_padded, cfg.head_dim,
                                         cfg.qkv_bias)
    elif mix == "mamba":
        s["norm1"] = _norm_specs(cfg)
        s["mamba"] = mb.mamba_specs(cfg.d_model, cfg.d_inner, cfg.d_state,
                                    cfg.d_conv, cfg.dt_rank)
    elif mix == "rwkv":
        s["norm1"] = L.layernorm_specs(cfg.d_model)
        s["time"] = rk.rwkv_time_specs(cfg.d_model, cfg.n_heads, cfg.lora_r)
    if cross:
        s["norm_x"] = _norm_specs(cfg)
        s["cross"] = attn.cross_attention_specs(
            cfg.d_model, cfg.n_heads_padded, cfg.n_kv_padded, cfg.head_dim)
    s["norm2"] = (_norm_specs(cfg) if mlp != "rwkv_ffn"
                  else L.layernorm_specs(cfg.d_model))
    if mlp == "dense":
        s["mlp"] = (L.swiglu_specs(cfg.d_model, cfg.d_ff)
                    if cfg.norm == "rms"
                    else L.gelu_mlp_specs(cfg.d_model, cfg.d_ff))
    elif mlp == "moe":
        s["moe"] = moe_mod.moe_specs(cfg.d_model, cfg.moe_ff or cfg.d_ff,
                                     cfg.moe_experts_padded)
        if cfg.shared_expert_ff:
            s["shared"] = moe_mod.shared_expert_specs(cfg.d_model,
                                                      cfg.shared_expert_ff)
        if cfg.dense_residual:
            s["dense2"] = L.swiglu_specs(cfg.d_model, cfg.d_ff)
    elif mlp == "rwkv_ffn":
        s["chan"] = rk.rwkv_channel_specs(cfg.d_model, cfg.d_ff)
    return s


def group_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    return {str(pos): _layer_specs(cfg, mix, mlp, cross)
            for pos, (mix, mlp) in enumerate(cfg.group_kinds())}


def model_specs(cfg: ArchConfig) -> dict:
    s: dict[str, Any] = {
        "embed": L.embedding_specs(cfg.vocab_padded, cfg.d_model),
        "groups": stack_specs(group_specs(cfg, cross=(cfg.kind == "encdec")),
                              cfg.n_groups, axis_name="layers"),
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = {"table": ParamSpec(
            (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), scale=0.02)}
    if cfg.kind == "encdec":
        enc_pattern = {"0": _layer_specs(cfg, "attn", "dense")}
        s["enc_groups"] = stack_specs(enc_pattern, cfg.enc_layers,
                                      axis_name="layers")
        s["enc_norm"] = _norm_specs(cfg)
    return s


# ============================================================ layer apply ===
def _apply_mlp(cfg, mlp, params, x):
    h = _norm(cfg, params["norm2"], x)
    if mlp == "dense":
        y = (L.swiglu(params["mlp"], h) if cfg.norm == "rms"
             else L.gelu_mlp(params["mlp"], h))
    elif mlp == "moe":
        y = moe_mod.moe_apply(
            params["moe"], h, n_experts=cfg.moe_experts,
            n_experts_padded=cfg.moe_experts_padded, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor)
        if "shared" in params:
            y = y + moe_mod.shared_expert_apply(params["shared"], h)
        if "dense2" in params:
            y = y + L.swiglu(params["dense2"], h)
    else:
        raise ValueError(mlp)
    return x + y


def _apply_layer_train(cfg, kinds, params, x, positions, memory=None):
    mix, mlp = kinds
    if mix == "attn":
        h = _norm(cfg, params["norm1"], x)
        ap = attn.mask_padded_heads(params["attn"], cfg.n_heads, cfg.n_kv)
        x = x + attn.attention_train(
            ap, h, positions, n_heads=cfg.n_heads_padded,
            n_kv=cfg.n_kv_padded, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            causal=(memory is None or cfg.kind != "encdec_encoder"),
            chunk=cfg.attn_chunk, window=cfg.window)
    elif mix == "mamba":
        h = _norm(cfg, params["norm1"], x)
        x = x + mb.mamba_train(params["mamba"], h, d_state=cfg.d_state,
                               dt_rank=cfg.dt_rank, chunk=cfg.mamba_chunk)
    elif mix == "rwkv":
        h = L.layer_norm(params["norm1"], x)
        y, _ = rk.rwkv_time_mix(params["time"], h, n_heads=cfg.n_heads)
        x = x + y
    if memory is not None and "cross" in params:
        h = _norm(cfg, params["norm_x"], x)
        cp = attn.mask_padded_heads(params["cross"], cfg.n_heads, cfg.n_kv)
        mk, mv = attn.project_memory(cp, memory)
        x = x + attn.cross_attention(cp, h, mk, mv)
    if mlp == "rwkv_ffn":
        h = L.layer_norm(params["norm2"], x)
        y, _ = rk.rwkv_channel_mix(params["chan"], h)
        return x + y
    return _apply_mlp(cfg, mlp, params, x)


# ============================================================== forward =====
def _scan_groups(cfg, groups_params, x, body):
    """scan(body) over the stacked groups with the remat policy applied."""
    if cfg.remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    x, ys = jax.lax.scan(body, x, groups_params,
                         unroll=min(cfg.scan_unroll, cfg.n_groups))
    return x, ys


def forward_train(cfg: ArchConfig, params, batch) -> jnp.ndarray:
    """Token logits for the training step (decoder-only / hybrid / rwkv)."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    prefix = None
    if cfg.family == "vlm" and "vision_embeds" in batch:
        prefix = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    x = shard_hint(x, "batch", None, "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    memory = None
    if cfg.kind == "encdec":
        memory = encode(cfg, params, batch["frames"])
    pattern = cfg.group_kinds()

    def body(xc, gp):
        for pos, kinds in enumerate(pattern):
            xc = _apply_layer_train(cfg, kinds, gp[str(pos)], xc, positions,
                                    memory)
        xc = shard_hint(xc, "batch", None, "embed")
        return xc, None

    x, _ = _scan_groups(cfg, params["groups"], x, body)
    x = _norm(cfg, params["final_norm"], x)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    logits = shard_hint(logits, "batch", None, "vocab")
    return logits


def encode(cfg: ArchConfig, params, frames) -> jnp.ndarray:
    """Encoder stack over precomputed frame embeddings (audio stub)."""
    x = frames.astype(jnp.bfloat16)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(xc, gp):
        p = gp["0"]
        h = _norm(cfg, p["norm1"], xc)
        ap = attn.mask_padded_heads(p["attn"], cfg.n_heads, cfg.n_kv)
        xc = xc + attn.attention_train(
            ap, h, positions, n_heads=cfg.n_heads_padded,
            n_kv=cfg.n_kv_padded, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, causal=False,
            chunk=cfg.attn_chunk)
        xc = _apply_mlp(cfg, "dense", p, xc)
        return xc, None

    x, _ = _scan_groups(cfg, params["enc_groups"], x, body)
    return _norm(cfg, params["enc_norm"], x)


def loss_fn(cfg: ArchConfig, params, batch) -> jnp.ndarray:
    """Mean next-token cross-entropy (padded-vocab ids masked out)."""
    logits = forward_train(cfg, params, batch).astype(jnp.float32)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        logits = logits[:, cfg.frontend_len:]
    targets = batch["targets"]
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    logits = jnp.where(vmask[None, None, :], logits, NEG)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# =============================================================== serving ====
def _abstractify(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def init_decode_caches(cfg: ArchConfig, batch: int, s_max: int,
                       abstract: bool = False):
    """Stacked (per scan group) decode caches for every layer position.

    Shapes are built symbolically first; ``abstract=True`` returns pure
    ShapeDtypeStructs WITHOUT allocating — a 32k x b128 cache tree is
    ~100 GB, which must never exist on the host during a dry-run.
    """
    g = cfg.n_groups

    def one(kinds):
        mix, _mlp = kinds
        sds = jax.ShapeDtypeStruct
        if mix == "attn":
            if cfg.kv_cache_dtype == "int8":
                return attn.KVCache(
                    k=sds((g, batch, s_max, cfg.n_kv_padded, cfg.head_dim),
                          jnp.int8),
                    v=sds((g, batch, s_max, cfg.n_kv_padded, cfg.head_dim),
                          jnp.int8),
                    length=sds((g,), jnp.int32),
                    k_scale=sds((g, batch, s_max, cfg.n_kv_padded, 1),
                                jnp.bfloat16),
                    v_scale=sds((g, batch, s_max, cfg.n_kv_padded, 1),
                                jnp.bfloat16))
            return attn.KVCache(
                k=sds((g, batch, s_max, cfg.n_kv_padded, cfg.head_dim),
                      jnp.bfloat16),
                v=sds((g, batch, s_max, cfg.n_kv_padded, cfg.head_dim),
                      jnp.bfloat16),
                length=sds((g,), jnp.int32))
        if mix == "mamba":
            return mb.MambaState(
                h=sds((g, batch, cfg.d_inner, cfg.d_state), jnp.float32),
                conv=sds((g, batch, cfg.d_conv - 1, cfg.d_inner),
                         jnp.bfloat16))
        if mix == "rwkv":
            hd = cfg.d_model // cfg.n_heads
            return rk.RwkvState(
                wkv=sds((g, batch, cfg.n_heads, hd, hd), jnp.float32),
                shift_t=sds((g, batch, cfg.d_model), jnp.bfloat16),
                shift_c=sds((g, batch, cfg.d_model), jnp.bfloat16))
        return ()

    pattern = cfg.group_kinds()
    stacked = {str(pos): one(k) for pos, k in enumerate(pattern)}
    if cfg.kind == "encdec":
        sds = jax.ShapeDtypeStruct
        stacked = {
            "self": stacked,
            "memory_k": sds((g, batch, cfg.cross_memory_len,
                             cfg.n_kv_padded, cfg.head_dim), jnp.bfloat16),
            "memory_v": sds((g, batch, cfg.cross_memory_len,
                             cfg.n_kv_padded, cfg.head_dim), jnp.bfloat16),
        }
    if abstract:
        return stacked
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), stacked,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _decode_mix(cfg, kinds, params, x, cache):
    mix, _ = kinds
    if mix == "attn":
        h = _norm(cfg, params["norm1"], x)
        ap = attn.mask_padded_heads(params["attn"], cfg.n_heads, cfg.n_kv)
        y, cache = attn.attention_decode(ap, h, cache,
                                         rope_theta=cfg.rope_theta,
                                         window=cfg.window)
        return x + y, cache
    if mix == "mamba":
        h = _norm(cfg, params["norm1"], x)
        y, cache = mb.mamba_decode(params["mamba"], h, cache,
                                   d_state=cfg.d_state, dt_rank=cfg.dt_rank)
        return x + y, cache
    if mix == "rwkv":
        h = L.layer_norm(params["norm1"], x)
        y, (wkv, last_t) = rk.rwkv_time_mix(
            params["time"], h, state=cache, n_heads=cfg.n_heads)
        return x + y, cache._replace(wkv=wkv, shift_t=last_t[:, 0]
                                     if last_t.ndim == 3 else last_t)
    return x, cache


def decode_step(cfg: ArchConfig, params, caches, batch):
    """One-token decode: batch['tokens'] (B, 1) -> (logits, new caches)."""
    x = L.embed(params["embed"], batch["tokens"])
    x = shard_hint(x, "batch", None, "embed")
    pattern = cfg.group_kinds()
    is_encdec = cfg.kind == "encdec"

    def body(xc, xs):
        gp, gc = xs
        self_gc = gc["self"] if is_encdec else gc
        new_gc = {}
        for pos, kinds in enumerate(pattern):
            p, c = gp[str(pos)], self_gc[str(pos)]
            xc, new_c = _decode_mix(cfg, kinds, p, xc, c)
            if is_encdec and "cross" in p:
                h = _norm(cfg, p["norm_x"], xc)
                xc = xc + attn.cross_attention(p["cross"], h,
                                               gc["memory_k"],
                                               gc["memory_v"])
            _, mlp = kinds
            if mlp == "rwkv_ffn":
                h = L.layer_norm(p["norm2"], xc)
                y, last_c = rk.rwkv_channel_mix(p["chan"], h, c.shift_c)
                xc = xc + y
                new_c = new_c._replace(shift_c=last_c)
            else:
                xc = _apply_mlp(cfg, mlp, p, xc)
            new_gc[str(pos)] = new_c
        if is_encdec:
            new_gc = {"self": new_gc, "memory_k": gc["memory_k"],
                      "memory_v": gc["memory_v"]}
        return xc, new_gc

    x, new_caches = jax.lax.scan(body, x, (params["groups"], caches),
                                 unroll=min(cfg.scan_unroll, cfg.n_groups))
    x = _norm(cfg, params["final_norm"], x)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    return jnp.where(vmask[None, None, :], logits, NEG), new_caches


def prefill(cfg: ArchConfig, params, batch, s_max: int):
    """Populate decode caches from a prompt; returns (last logits, caches)."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x],
                            axis=1)
    x = shard_hint(x, "batch", None, "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    pattern = cfg.group_kinds()
    is_encdec = cfg.kind == "encdec"
    memory = encode(cfg, params, batch["frames"]) if is_encdec else None

    def body(xc, gp):
        new_gc = {}
        for pos, kinds in enumerate(pattern):
            p = gp[str(pos)]
            mix, mlp = kinds
            if mix == "attn":
                h = _norm(cfg, p["norm1"], xc)
                ap = attn.mask_padded_heads(p["attn"], cfg.n_heads, cfg.n_kv)
                y, c = attn.attention_prefill(
                    ap, h, positions, s_max, rope_theta=cfg.rope_theta,
                    chunk=cfg.attn_chunk, window=cfg.window,
                    quantize=(cfg.kv_cache_dtype == "int8"))
                xc = xc + y
            elif mix == "mamba":
                h = _norm(cfg, p["norm1"], xc)
                y, c = mb.mamba_prefill(p["mamba"], h, d_state=cfg.d_state,
                                        dt_rank=cfg.dt_rank,
                                        chunk=cfg.mamba_chunk)
                xc = xc + y
            elif mix == "rwkv":
                h = L.layer_norm(p["norm1"], xc)
                y, (wkv, last_t) = rk.rwkv_time_mix(p["time"], h,
                                                    n_heads=cfg.n_heads)
                xc = xc + y
                c = rk.RwkvState(wkv=wkv, shift_t=last_t,
                                 shift_c=jnp.zeros_like(last_t))
            if is_encdec and "cross" in p:
                h = _norm(cfg, p["norm_x"], xc)
                mk, mv = attn.project_memory(p["cross"], memory)
                xc = xc + attn.cross_attention(p["cross"], h, mk, mv)
            if mlp == "rwkv_ffn":
                h = L.layer_norm(p["norm2"], xc)
                y, last_c = rk.rwkv_channel_mix(p["chan"], h)
                xc = xc + y
                c = c._replace(shift_c=last_c)
            else:
                xc = _apply_mlp(cfg, mlp, p, xc)
            new_gc[str(pos)] = c
        if is_encdec:
            p0 = gp["0"]
            mk, mv = attn.project_memory(p0["cross"], memory)
            new_gc = {"self": new_gc, "memory_k": mk, "memory_v": mv}
        xc = shard_hint(xc, "batch", None, "embed")
        return xc, new_gc

    x, caches = _scan_groups(cfg, params["groups"], x, body)
    x = _norm(cfg, params["final_norm"], x)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], table)
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    return jnp.where(vmask[None, :], logits, NEG), caches
