"""RWKV6 "Finch" block: attention-free time mix with data-dependent decay.

The defining Finch feature — per-channel, per-token decay
``w_t = exp(-exp(w0 + tanh(x W_a) W_b))`` — is kept; token-shift mixing uses
the static (v5-style) interpolation coefficients.  The WKV recurrence over
per-head (hd x hd) state runs as a lax.scan over time (state fp32); a
chunked Pallas WKV kernel is the known real-hardware optimisation and is
tracked as a §Perf item, but the recurrence itself is O(S) compute either
way.  Decode is the O(1) state update — why this arch runs ``long_500k``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, beinsum


class RwkvState(NamedTuple):
    wkv: jnp.ndarray     # (B, H, hd, hd) fp32
    shift_t: jnp.ndarray  # (B, d) last token input (time mix)
    shift_c: jnp.ndarray  # (B, d) last token input (channel mix)


def rwkv_time_specs(d: int, n_heads: int, lora_r: int = 64) -> dict:
    hd = d // n_heads
    return {
        "mu_r": ParamSpec((d,), ("embed",), scale=0.5),
        "mu_k": ParamSpec((d,), ("embed",), scale=0.5),
        "mu_v": ParamSpec((d,), ("embed",), scale=0.5),
        "mu_g": ParamSpec((d,), ("embed",), scale=0.5),
        "mu_w": ParamSpec((d,), ("embed",), scale=0.5),
        "wr": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wo": ParamSpec((n_heads, hd, d), ("heads", "head_dim", "embed")),
        # data-dependent decay (the Finch contribution)
        "w0": ParamSpec((d,), ("embed",), init="zeros"),
        "w_a": ParamSpec((d, lora_r), ("embed", None)),
        "w_b": ParamSpec((lora_r, d), (None, "embed")),
        "bonus_u": ParamSpec((n_heads, hd), ("heads", "head_dim"),
                             scale=0.5),
        "ln_scale": ParamSpec((d,), ("embed",), init="ones"),
    }


def rwkv_channel_specs(d: int, ff: int) -> dict:
    return {
        "mu_k": ParamSpec((d,), ("embed",), scale=0.5),
        "mu_r": ParamSpec((d,), ("embed",), scale=0.5),
        "wk": ParamSpec((d, ff), ("embed", "ff")),
        "wr": ParamSpec((d, d), ("embed", None)),
        "wv": ParamSpec((ff, d), ("ff", "embed")),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None]
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _decay(params, xw):
    """w_t in (0,1): exp(-exp(w0 + tanh(xw W_a) W_b))."""
    lora = jnp.einsum("bsr,rd->bsd",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr",
                                          xw.astype(jnp.float32),
                                          params["w_a"].astype(jnp.float32))),
                      params["w_b"].astype(jnp.float32))
    return jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + lora))


def rwkv_time_mix(params, x, state: RwkvState | None = None,
                  n_heads: int = 32):
    """x: (B, S, d).  Returns (out, new_state_parts) — train when S>1."""
    b, s, d = x.shape
    hd = d // n_heads
    last = None if state is None else state.shift_t
    xs = _shift(x, last)
    xr = _mix(x, xs, params["mu_r"])
    xk = _mix(x, xs, params["mu_k"])
    xv = _mix(x, xs, params["mu_v"])
    xg = _mix(x, xs, params["mu_g"])
    xw = _mix(x, xs, params["mu_w"])

    r = beinsum("bsd,dhk->bshk", xr, params["wr"]).astype(jnp.float32)
    k = beinsum("bsd,dhk->bshk", xk, params["wk"]).astype(jnp.float32)
    v = beinsum("bsd,dhk->bshk", xv, params["wv"]).astype(jnp.float32)
    g = beinsum("bsd,dhk->bshk", xg, params["wg"])
    w = _decay(params, xw).reshape(b, s, n_heads, hd)      # (B,S,H,hd)
    u = params["bonus_u"].astype(jnp.float32)              # (H, hd)

    def step(wkv, inp):
        r_t, k_t, v_t, w_t = inp          # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]         # (B,H,hd,hd)
        out_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                           wkv + u[None, :, :, None] * kv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, out_t

    wkv0 = (jnp.zeros((b, n_heads, hd, hd), jnp.float32)
            if state is None else state.wkv)
    tm = lambda a: jnp.moveaxis(a, 1, 0)                   # scan over time
    wkv, outs = jax.lax.scan(step, wkv0, (tm(r), tm(k), tm(v), tm(w)))
    out = jnp.moveaxis(outs, 0, 1)                         # (B,S,H,hd)

    # group norm per head + gate
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, s, d) * params["ln_scale"].astype(jnp.float32)
    out = out.reshape(b, s, n_heads, hd)
    out = (out * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    y = beinsum("bshk,hkd->bsd", out, params["wo"])
    return y, (wkv, x[:, -1])


def rwkv_channel_mix(params, x, last=None):
    xs = _shift(x, last)
    xk = _mix(x, xs, params["mu_k"])
    xr = _mix(x, xs, params["mu_r"])
    k = beinsum("bsd,df->bsf", xk, params["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, params["wr"]).astype(jnp.float32))
    return (r.astype(x.dtype) * beinsum("bsf,fd->bsd", k, params["wv"]),
            x[:, -1])
