"""LM substrate: composable model definitions (pure functions + specs)."""
