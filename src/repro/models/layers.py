"""Shared neural layers: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, beinsum


# ---------------------------------------------------------------- norms ----
def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rms_norm(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def layer_norm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----
def rope_frequencies(head_dim: int, positions: jnp.ndarray,
                     theta: float = 10000.0):
    """(..., S) positions -> (..., S, head_dim/2) cos/sin tables."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    inv_freq = 1.0 / (theta ** exponent)                   # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLPs ----
def swiglu_specs(d: int, ff: int) -> dict:
    return {"gate": ParamSpec((d, ff), ("embed", "ff")),
            "up": ParamSpec((d, ff), ("embed", "ff")),
            "down": ParamSpec((ff, d), ("ff", "embed"))}


def swiglu(params, x):
    g = beinsum("bsd,df->bsf", x, params["gate"])
    u = beinsum("bsd,df->bsf", x, params["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return beinsum("bsf,fd->bsd", h, params["down"])


def gelu_mlp_specs(d: int, ff: int, bias: bool = True) -> dict:
    s = {"up": ParamSpec((d, ff), ("embed", "ff")),
         "down": ParamSpec((ff, d), ("ff", "embed"))}
    if bias:
        s["up_b"] = ParamSpec((ff,), ("ff",), init="zeros")
        s["down_b"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


def gelu_mlp(params, x):
    h = beinsum("bsd,df->bsf", x, params["up"])
    if "up_b" in params:
        h = h + params["up_b"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = beinsum("bsf,fd->bsd", h, params["down"])
    if "down_b" in params:
        y = y + params["down_b"]
    return y


# ----------------------------------------------------------- embeddings ----
def embedding_specs(vocab_padded: int, d: int) -> dict:
    return {"table": ParamSpec((vocab_padded, d), ("vocab", "embed"),
                               scale=1.0)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Logits over the (padded) vocab; callers mask padded ids in the loss."""
    return jnp.einsum("bsd,vd->bsv", x, params["table"])
