"""Mamba (selective SSM) block — the Jamba hybrid's sequence mixer.

Training uses a chunked associative scan: the (B, chunk, d_inner, d_state)
decay/increment intermediates exist only per chunk (VMEM-friendly, sharded
on d_inner over 'model'), with the hidden state carried across chunks.
Decode is the O(1) recurrence h' = exp(dt*A) h + dt*B x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, beinsum


class MambaState(NamedTuple):
    h: jnp.ndarray       # (B, d_inner, d_state) fp32 SSM state
    conv: jnp.ndarray    # (B, d_conv - 1, d_inner) causal-conv tail


def mamba_specs(d: int, d_inner: int, d_state: int, d_conv: int,
                dt_rank: int) -> dict:
    return {
        "in_proj": ParamSpec((d, 2 * d_inner), ("embed", "ff")),
        "conv_w": ParamSpec((d_conv, d_inner), (None, "ff"), scale=0.1),
        "conv_b": ParamSpec((d_inner,), ("ff",), init="zeros"),
        "x_proj": ParamSpec((d_inner, dt_rank + 2 * d_state), ("ff", None)),
        "dt_proj": ParamSpec((dt_rank, d_inner), (None, "ff")),
        "dt_bias": ParamSpec((d_inner,), ("ff",), init="zeros"),
        "a_log": ParamSpec((d_inner, d_state), ("ff", None), init="ones"),
        "d_skip": ParamSpec((d_inner,), ("ff",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ff", "embed")),
    }


def _causal_conv(params, x, tail=None):
    """Depthwise causal conv1d via shift-adds.  x: (B, S, d_inner)."""
    d_conv = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(params["conv_w"][j] * xp[:, j:j + x.shape[1]]
            for j in range(d_conv))
    new_tail = xp[:, -(d_conv - 1):] if d_conv > 1 else tail
    return y + params["conv_b"], new_tail


def _ssm_inputs(params, x_conv, d_state, dt_rank):
    """Project conv output to (dt, B, C) selective-scan inputs."""
    proj = jnp.einsum("bsi,io->bso", x_conv, params["x_proj"])
    dt_r, b_mat, c_mat = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))
    return dt, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32)


def mamba_train(params, x, *, d_state: int, dt_rank: int, chunk: int = 64,
                return_state: bool = False):
    """x: (B, S, d) -> (B, S, d).  S must be a multiple of ``chunk``."""
    b, s, _ = x.shape
    xz = beinsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_tail = _causal_conv(params, x_in)
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
    dt, b_mat, c_mat = _ssm_inputs(params, x_conv, d_state, dt_rank)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))      # (di, ds)
    xf = x_conv.astype(jnp.float32)
    d_inner = xf.shape[-1]
    # pad S to a chunk multiple with dt=0 steps (decay=1, inc=0: state inert)
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        dt, b_mat, c_mat, xf = (jnp.pad(v, pad)
                                for v in (dt, b_mat, c_mat, xf))
    n_chunks = s_pad // chunk

    def chunk_body(h, inputs):
        dt_c, b_c, c_c, x_c = inputs      # (B, ck, ...) slices
        decay = jnp.exp(dt_c[..., None] * a)               # (B,ck,di,ds)
        inc = (dt_c * x_c)[..., None] * b_c[:, :, None, :]  # (B,ck,di,ds)

        def combine(p, q):
            return (p[0] * q[0], q[0] * p[1] + q[1])

        dcum, hs = jax.lax.associative_scan(combine, (decay, inc), axis=1)
        hs = hs + dcum * h[:, None]                        # fold carry in
        y_c = jnp.einsum("bcis,bcs->bci", hs, c_c)
        return hs[:, -1], y_c

    reshape = lambda v: v.reshape(b, n_chunks, chunk, *v.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0,
                               (reshape(dt), reshape(b_mat), reshape(c_mat),
                                reshape(xf)))
    y = ys.swapaxes(0, 1).reshape(b, s_pad, d_inner)[:, :s]
    xf = xf[:, :s]
    y = y + xf * params["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = beinsum("bsi,id->bsd", y.astype(x.dtype), params["out_proj"])
    if return_state:
        return out, MambaState(h=h_final,
                               conv=conv_tail.astype(jnp.bfloat16))
    return out


def mamba_prefill(params, x, *, d_state: int, dt_rank: int, chunk: int = 64):
    """Prefill: full-sequence output + state for subsequent decode."""
    return mamba_train(params, x, d_state=d_state, dt_rank=dt_rank,
                       chunk=chunk, return_state=True)


def mamba_init_state(params, batch: int) -> MambaState:
    d_inner = params["dt_bias"].shape[0]
    d_state = params["a_log"].shape[1]
    d_conv = params["conv_w"].shape[0]
    return MambaState(
        h=jnp.zeros((batch, d_inner, d_state), jnp.float32),
        conv=jnp.zeros((batch, d_conv - 1, d_inner), jnp.bfloat16))


def mamba_decode(params, x, state: MambaState, *, d_state: int,
                 dt_rank: int):
    """One-token step.  x: (B, 1, d) -> (B, 1, d) + new state."""
    xz = beinsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, new_tail = _causal_conv(params, x_in.astype(state.conv.dtype),
                                    tail=state.conv)
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
    dt, b_mat, c_mat = _ssm_inputs(params, x_conv, d_state, dt_rank)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xf = x_conv.astype(jnp.float32)[:, 0]                  # (B, di)
    dt0, b0, c0 = dt[:, 0], b_mat[:, 0], c_mat[:, 0]
    decay = jnp.exp(dt0[..., None] * a)                    # (B, di, ds)
    h = decay * state.h + (dt0 * xf)[..., None] * b0[:, None, :]
    y = jnp.einsum("bis,bs->bi", h, c0)
    y = y + xf * params["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32)[:, 0])
    out = beinsum("bi,id->bd", y.astype(x.dtype), params["out_proj"])
    return out[:, None], MambaState(h=h, conv=new_tail)
