from repro.ft.preemption import PreemptionHandler  # noqa: F401
from repro.ft.straggler import StragglerMonitor  # noqa: F401
