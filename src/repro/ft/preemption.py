"""Preemption handling: SIGTERM -> checkpoint at the next step boundary.

Cloud TPU/TRN preemptions deliver a grace-period signal; the train loop
polls ``should_stop`` once per step and exits through a final checkpoint.
``install()`` is idempotent and chains any pre-existing handler.
"""
from __future__ import annotations

import signal
import threading


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._signals = signals
        self._prev = {}
        self._installed = False

    def install(self) -> "PreemptionHandler":
        if self._installed:
            return self
        for sig in self._signals:
            self._prev[sig] = signal.getsignal(sig)
            signal.signal(sig, self._on_signal)
        self._installed = True
        return self

    def _on_signal(self, signum, frame):
        self._flag.set()
        prev = self._prev.get(signum)
        if callable(prev):
            prev(signum, frame)

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()

    def request_stop(self) -> None:  # test hook / manual drain
        self._flag.set()

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._installed = False
