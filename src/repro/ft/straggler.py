"""Straggler detection: per-step wall-time ring buffer + outlier policy.

At pod scale a slow host (thermal throttling, failing HBM, network flap)
shows up as a step-time outlier on *every* host (SPMD lockstep).  The
monitor keeps a rolling median and flags steps exceeding ``threshold x
median``; the launcher policy (see ft/POLICY.md) is: after ``patience``
consecutive flags, checkpoint + re-dispatch excluding the slow host.  In
this container the detection + restart path is exercised by tests with
injected delays.
"""
from __future__ import annotations

import statistics
import time
from collections import deque
from typing import Callable


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.5,
                 patience: int = 3,
                 on_straggler: Callable[[int, float, float], None] | None
                 = None):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self._times: deque[float] = deque(maxlen=window)
        self._consecutive = 0
        self._t0: float | None = None
        self.flagged_steps: list[int] = []
        self.tripped = False

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, step: int, duration: float | None = None) -> bool:
        """Record a step; returns True if the re-dispatch policy tripped."""
        if duration is None:
            assert self._t0 is not None, "step_start() not called"
            duration = time.perf_counter() - self._t0
        median = (statistics.median(self._times) if len(self._times) >= 8
                  else None)
        self._times.append(duration)
        if median is not None and duration > self.threshold * median:
            self.flagged_steps.append(step)
            self._consecutive += 1
            if self.on_straggler:
                self.on_straggler(step, duration, median)
            if self._consecutive >= self.patience:
                self.tripped = True
        else:
            self._consecutive = 0
        return self.tripped
