"""Execution-strategy backends for the unified Engine.

Importing this package registers the three built-in strategies:
``segment`` (CSR sort+segment-reduce), ``tile`` (padded-neighbor /
Pallas kernels), and ``sharded`` (multi-device shard_map).
"""
from repro.engine.backends import segment, sharded, tile  # noqa: F401
