"""Tile backend: single-device padded-neighbor path over the Pallas ops.

The propagation loop mirrors ``core.lpa.lpa_run`` sweep-for-sweep (same
parity classes, same per-sweep hash seeds, same adopt rule) but computes
each sweep with ``kernels.ops.label_argmax`` over dense (rows, d_max)
neighbor tiles — the compiled-kernel path on TPU, the jnp oracle
elsewhere.  For integer-valued edge weights the per-community sums are
exact in float32, so the final labels are bit-identical to the segment
backend (the parity suite asserts this); the split phase uses
``ops.min_label`` and matches ``split_lp`` exactly.

Both phases run as single jitted ``lax.while_loop`` executables per shape
bucket; the real vertex count is a traced scalar.

With ``EngineConfig.fuse_sweeps`` resolved on (``ops.resolve_fuse``), the
loop bodies switch to the *lazy-wake* form — the wake reduction for
sub-sweep ``k`` is applied at the start of sub-sweep ``k+1`` from the
carried changed mask, exactly the restructure the out-of-core driver
already uses — so each sub-sweep's wake + move (and the split's wake +
min-label) runs as one fused Pallas dispatch
(``kernels/fused_sweep.py``) with the neighbor tiles read once.  Labels
and iteration counts are bit-identical either way; the fused bodies get
their own TRACE_LOG tags so the trace-audit gate sees them as distinct
contracts.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import warm_state_rows
from repro.core.graph import Graph, _round_up, to_padded_neighbors
from repro.core.lpa import _label_hash
from repro.engine.bucketing import (
    BatchBucketKey,
    BucketKey,
    batch_index_arrays,
    pad_active,
    pad_labels,
)
from repro.engine.cache import TRACE_LOG
from repro.engine.config import EngineConfig
from repro.engine.registry import BackendRun, BatchBackendRun, register_backend
from repro.kernels import ops
from repro.obs.convergence import batch_profiles, solo_profile


def tile_rows(bucket_n: int) -> int:
    """Row count of the padded tiles for a vertex bucket (sublane-aligned)."""
    return _round_up(bucket_n, 8)


def pad_tile_rows(nbr: np.ndarray, nw: np.ndarray, nmask: np.ndarray,
                  rows: int):
    """Grow neighbor tiles to ``rows`` rows: self-pointing ids, zero weight,
    masked out — identical padding semantics to ``to_padded_neighbors``."""
    have = nbr.shape[0]
    if have == rows:
        return nbr, nw, nmask
    if have > rows:
        raise ValueError(f"tiles have {have} rows, bucket wants {rows}")
    extra = rows - have
    pad_ids = np.arange(have, rows, dtype=np.int32)
    nbr = np.concatenate(
        [nbr, np.repeat(pad_ids[:, None], nbr.shape[1], axis=1)], axis=0)
    nw = np.concatenate(
        [nw, np.zeros((extra, nw.shape[1]), np.float32)], axis=0)
    nmask = np.concatenate(
        [nmask, np.zeros((extra, nmask.shape[1]), bool)], axis=0)
    return nbr, nw, nmask


@register_backend("tile")
class TileBackend:
    name = "tile"
    supports_batch = True
    supports_partition = True
    supports_fused_partition = True

    def plan_key(self, config: EngineConfig) -> tuple:
        return ()

    def build(self, bucket: BucketKey, config: EngineConfig):
        rows = tile_rows(bucket.n)
        tau, max_iterations = config.tau, config.max_iterations
        mode = config.kernel_mode
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut
        fuse = ops.resolve_fuse(config.fuse_sweeps, config.kernel_mode)
        profile = config.profile != "off"
        split_rows = 2 * max_iterations if config.profile == "full" else 0

        ids = np.arange(rows, dtype=np.int32)

        def _propagate(nbr, nw, nmask, n_real, labels0, active0):
            TRACE_LOG.record("tile:propagate")
            vid = jnp.asarray(ids)
            parity = (_label_hash(vid, jnp.int32(-1)) & 1).astype(bool)
            real = vid < n_real
            threshold = (jnp.float32(tau)
                         * n_real.astype(jnp.float32)).astype(jnp.int32)

            def cond(s):
                _labels, _active, it, dn = s[:4]
                return (dn > threshold) & (it < max_iterations)

            def body(s):
                labels, active, it, _ = s[:4]
                buf = s[4] if profile else None
                dn = jnp.int32(0)
                for sweep in range(2):  # semi-synchronous parity sub-sweeps
                    klass = parity if sweep else ~parity
                    cand = active & klass
                    seed = 2 * it + sweep
                    best_lab, best_w, cur_w = ops.label_argmax(
                        labels[nbr], nw, nmask, labels,
                        jnp.asarray(seed, jnp.int32), mode=mode)
                    adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))
                    new = jnp.where(adopt, best_lab.astype(jnp.int32), labels)
                    changed = new != labels
                    wake = jnp.any(changed[nbr] & nmask, axis=1)
                    active = (active & ~cand) | (wake & real)
                    labels = new
                    sc = jnp.sum(changed.astype(jnp.int32))
                    dn = dn + sc
                    if profile:
                        buf = buf.at[seed].set(jnp.stack(
                            [jnp.sum(cand.astype(jnp.int32)), sc, seed]))
                nxt = (labels, active, it + jnp.int32(1), dn)
                return nxt + (buf,) if profile else nxt

            init = (labels0, active0 & real, jnp.int32(0), jnp.int32(rows))
            if profile:
                init = init + (jnp.full((2 * max_iterations, 3), -1,
                                        jnp.int32),)
                labels, _, it, _, buf = jax.lax.while_loop(cond, body, init)
                return labels, it, buf
            labels, _, it, _ = jax.lax.while_loop(cond, body, init)
            return labels, it

        def _propagate_fused(nbr, nw, nmask, n_real, labels0, active0):
            TRACE_LOG.record("tile:propagate_fused")
            vid = jnp.asarray(ids)
            parity = (_label_hash(vid, jnp.int32(-1)) & 1).astype(bool)
            real = vid < n_real
            threshold = (jnp.float32(tau)
                         * n_real.astype(jnp.float32)).astype(jnp.int32)

            def cond(s):
                _labels, _active, _chg, _candp, it, dn = s[:6]
                return (dn > threshold) & (it < max_iterations)

            def body(s):
                # Lazy wake: chg/candp carry the previous sub-sweep's
                # changed mask and candidate set into the fused kernel,
                # which applies the active refresh before picking this
                # sub-sweep's candidates — one dispatch per sub-sweep.
                labels, active, chg, candp, it, _ = s[:6]
                buf = s[6] if profile else None
                dn = jnp.int32(0)
                for sweep in range(2):  # semi-synchronous parity sub-sweeps
                    klass = parity if sweep else ~parity
                    seed = 2 * it + sweep
                    new, active = ops.fused_move(
                        labels[nbr], nw, nmask, chg[nbr], labels, active,
                        candp, klass, real, jnp.asarray(seed, jnp.int32),
                        mode=mode)
                    chg = new != labels
                    # candp is exactly this sub-sweep's candidate set
                    # (refreshed-active & klass) — same counts as the
                    # unfused body's `cand`.
                    candp = active & klass
                    labels = new
                    sc = jnp.sum(chg.astype(jnp.int32))
                    dn = dn + sc
                    if profile:
                        buf = buf.at[seed].set(jnp.stack(
                            [jnp.sum(candp.astype(jnp.int32)), sc, seed]))
                nxt = (labels, active, chg, candp, it + jnp.int32(1), dn)
                return nxt + (buf,) if profile else nxt

            zeros = jnp.zeros(rows, dtype=bool)
            init = (labels0, active0 & real, zeros, zeros, jnp.int32(0),
                    jnp.int32(rows))
            if profile:
                init = init + (jnp.full((2 * max_iterations, 3), -1,
                                        jnp.int32),)
                labels, _, _, _, it, _, buf = jax.lax.while_loop(cond, body,
                                                                 init)
                return labels, it, buf
            labels, _, _, _, it, _ = jax.lax.while_loop(cond, body, init)
            return labels, it

        def _split(nbr, nmask, comm, labels0, n_real):
            TRACE_LOG.record("tile:split")
            same = (comm[nbr] == comm[:, None]) & nmask
            real = jnp.asarray(ids) < n_real

            def cond(s):
                _labels, _active, _it, dn = s[:4]
                return dn > 0

            def body(s):
                labels, active, it, _ = s[:4]
                buf = s[4] if split_rows else None
                new = ops.min_label(labels[nbr], comm[nbr], nmask, labels,
                                    comm, mode=mode)
                if prune:
                    new = jnp.where(active, new, labels)
                if shortcut:
                    new = jnp.minimum(new, new[new])
                changed = new != labels
                dn = jnp.sum(changed.astype(jnp.int32))
                if split_rows:
                    row = jnp.minimum(it, split_rows - 1)
                    buf = buf.at[row].set(jnp.stack(
                        [jnp.sum((active & real).astype(jnp.int32)), dn,
                         it]))
                if prune:
                    active = jnp.any(changed[nbr] & same, axis=1)
                nxt = (new, active, it + jnp.int32(1), dn)
                return nxt + (buf,) if split_rows else nxt

            init = (labels0, jnp.ones(rows, dtype=bool), jnp.int32(0),
                    jnp.int32(rows))
            if split_rows:
                init = init + (jnp.full((split_rows, 3), -1, jnp.int32),)
                labels, _, it, _, buf = jax.lax.while_loop(cond, body, init)
                return labels, it, buf
            labels, _, it, _ = jax.lax.while_loop(cond, body, init)
            return labels, it

        def _split_fused(nbr, nmask, comm, labels0, n_real):
            TRACE_LOG.record("tile:split_fused")
            real = jnp.asarray(ids) < n_real

            def cond(s):
                _labels, _chg, _it, dn = s[:4]
                return dn > 0

            def body(s):
                # chg carries last iteration's changed mask (ones on the
                # first: rows with no same-community neighbor reduce to
                # their own label, so the result matches active0 = ones).
                labels, chg, it, _ = s[:4]
                buf = s[4] if split_rows else None
                new = ops.fused_split(labels[nbr], comm[nbr], nmask,
                                      chg[nbr], labels, comm, prune=prune,
                                      mode=mode)
                if shortcut:
                    new = jnp.minimum(new, new[new])
                changed = new != labels
                dn = jnp.sum(changed.astype(jnp.int32))
                if split_rows:
                    # the fused body never materialises the prune
                    # worklist; the wake source (last sweep's changed
                    # rows) is the closest observable frontier proxy
                    row = jnp.minimum(it, split_rows - 1)
                    buf = buf.at[row].set(jnp.stack(
                        [jnp.sum((chg & real).astype(jnp.int32)), dn, it]))
                nxt = (new, changed, it + jnp.int32(1), dn)
                return nxt + (buf,) if split_rows else nxt

            init = (labels0, jnp.ones(rows, dtype=bool), jnp.int32(0),
                    jnp.int32(rows))
            if split_rows:
                init = init + (jnp.full((split_rows, 3), -1, jnp.int32),)
                labels, _, it, _, buf = jax.lax.while_loop(cond, body, init)
                return labels, it, buf
            labels, _, it, _ = jax.lax.while_loop(cond, body, init)
            return labels, it

        return SimpleNamespace(
            rows=rows,
            propagate=jax.jit(_propagate_fused if fuse else _propagate),
            split=(jax.jit(_split_fused if fuse else _split)
                   if do_split else None),
            profile=profile, split_profile_rows=split_rows,
        )

    def prepare(self, graph: Graph, bucket: BucketKey,
                config: EngineConfig):
        nbr, nw, nmask = to_padded_neighbors(graph, d_max=bucket.d)
        nbr, nw, nmask = pad_tile_rows(nbr, nw, nmask, tile_rows(bucket.n))
        return (jnp.asarray(nbr), jnp.asarray(nw), jnp.asarray(nmask))

    def run(self, plan, inputs, n_real: int,
            init_labels: np.ndarray | None,
            init_active: np.ndarray | None = None) -> BackendRun:
        nbr, nw, nmask = inputs
        profiling = getattr(plan, "profile", False)
        labels0 = jnp.asarray(pad_labels(
            np.arange(n_real, dtype=np.int32) if init_labels is None
            else init_labels, n_real, plan.rows))
        active0 = jnp.asarray(pad_active(init_active, n_real, plan.rows))

        t0 = time.perf_counter()
        out = plan.propagate(nbr, nw, nmask, jnp.int32(n_real),
                             labels0, active0)
        (labels, it, pbuf) = out if profiling else (*out, None)
        labels = jax.block_until_ready(labels)
        lpa_iters = int(it)
        t1 = time.perf_counter()

        split_iters = 0
        sbuf = None
        if plan.split is not None:
            roots0 = jnp.arange(plan.rows, dtype=jnp.int32)
            out = plan.split(nbr, nmask, labels, roots0, jnp.int32(n_real))
            (labels, sit, sbuf) = out if plan.split_profile_rows \
                else (*out, None)
            labels = jax.block_until_ready(labels)
            split_iters = int(sit)
        t2 = time.perf_counter()

        # profile fetch: one host transfer, after the convergence sync
        profile = solo_profile(pbuf, lpa_iters, sbuf, split_iters,
                               plan.split_profile_rows,
                               int(n_real)) if profiling else None
        return BackendRun(labels=np.asarray(labels),
                          lpa_iterations=lpa_iters,
                          split_iterations=split_iters,
                          lpa_seconds=t1 - t0, split_seconds=t2 - t1,
                          profile=profile)

    # --- out-of-core partition sweeps (repro.partition.ooc driver) ---
    #
    # A partition's tiles hold only its *owned* rows (``shapes.rows``
    # high), but neighbor ids index the full local row space (owned +
    # halo), so the per-sweep ``labels_loc`` gather covers halo imports
    # for free.  Label values are global vertex ids — the argmax hash is
    # a function of the raw value, and the kernels' sentinel is INT32_MAX,
    # so no label_bound plumbing is needed on this path.  Tile width is
    # the in-core d bucket: per-row reductions run at identical widths,
    # keeping the float sums bit-identical to the in-core tile fit.

    def build_partition(self, config: EngineConfig):
        mode = config.kernel_mode
        prune = config.split == "lpp"
        fuse = ops.resolve_fuse(config.fuse_sweeps, config.kernel_mode)

        def _move(nbr, nw, nmask, labels, cand, seed):
            TRACE_LOG.record("tile:part_move")
            row_lab = labels[: nbr.shape[0]]
            best_lab, best_w, cur_w = ops.label_argmax(
                labels[nbr], nw, nmask, row_lab, seed, mode=mode)
            adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))
            return jnp.where(adopt, best_lab.astype(jnp.int32), row_lab)

        def _wake(nbr, nmask, changed):
            TRACE_LOG.record("tile:part_wake")
            return jnp.any(changed[nbr] & nmask, axis=1)

        def _split(nbr, nmask, comm, labels, active):
            TRACE_LOG.record("tile:part_split")
            rows = nbr.shape[0]
            new = ops.min_label(labels[nbr], comm[nbr], nmask,
                                labels[:rows], comm[:rows], mode=mode)
            if prune:
                new = jnp.where(active, new, labels[:rows])
            return new

        def _split_wake(nbr, nmask, comm, changed):
            TRACE_LOG.record("tile:part_split_wake")
            rows = nbr.shape[0]
            same = (comm[nbr] == comm[:rows, None]) & nmask
            return jnp.any(changed[nbr] & same, axis=1)

        def _fused_move(nbr, nw, nmask, labels, chg, active, candp, klass,
                        seed):
            TRACE_LOG.record("tile:part_fused_move")
            rows = nbr.shape[0]
            real = jnp.ones(rows, dtype=bool)  # padded rows: nmask/klass off
            return ops.fused_move(labels[nbr], nw, nmask, chg[nbr],
                                  labels[:rows], active, candp, klass, real,
                                  seed, mode=mode)

        def _fused_split(nbr, nmask, comm, labels, chg):
            TRACE_LOG.record("tile:part_fused_split")
            rows = nbr.shape[0]
            return ops.fused_split(labels[nbr], comm[nbr], nmask, chg[nbr],
                                   labels[:rows], comm[:rows], prune=prune,
                                   mode=mode)

        return SimpleNamespace(
            move=jax.jit(_move), wake=jax.jit(_wake),
            split=jax.jit(_split), split_wake=jax.jit(_split_wake),
            fused_move=jax.jit(_fused_move),
            fused_split=jax.jit(_fused_split),
            fuse=fuse,
        )

    def partition_caps(self, budget: int, d_bucket: int):
        """(max_edges, max_vertices) for a byte budget: the dense tiles
        cost ~9 B/cell at ``d_bucket`` cells per row, padded ≤ 2x."""
        half = max(budget // 2, 1)
        return max(half // 40, 1), max(half // (18 * max(d_bucket, 1)), 8)

    def partition_prepare_nbytes(self, shapes) -> int:
        return shapes.rows * shapes.d * 9

    def prepare_partition(self, resident, shapes, config: EngineConfig):
        """Dense (rows, d) neighbor tiles of one partition's owned rows.

        Same padding semantics as ``to_padded_neighbors`` (self-pointing
        ids, zero weight, masked out), built vectorized off the local
        window so residency setup is O(window), not a Python row loop.
        """
        rows, d = shapes.rows, shapes.d
        size = resident.size
        row_ptr = resident.row_ptr.astype(np.int64)
        deg = row_ptr[1:] - row_ptr[:-1]
        nbr = np.repeat(np.arange(rows, dtype=np.int32)[:, None], d, axis=1)
        nw = np.zeros((rows, d), np.float32)
        nmask = np.zeros((rows, d), bool)
        if size and len(resident.dst):
            ridx = np.repeat(np.arange(size), deg)
            cidx = np.arange(len(resident.dst)) - np.repeat(row_ptr[:-1], deg)
            nbr[ridx, cidx] = resident.dst
            nw[ridx, cidx] = resident.wgt
            nmask[ridx, cidx] = True
        return ((jnp.asarray(nbr), jnp.asarray(nw), jnp.asarray(nmask)),
                self.partition_prepare_nbytes(shapes))

    def partition_move(self, ops_ns, inputs, labels_loc, cand_owned,
                       seed, bound) -> np.ndarray:
        nbr, nw, nmask = inputs
        cand = np.zeros(nbr.shape[0], bool)
        cand[: len(cand_owned)] = cand_owned
        return np.asarray(ops_ns.move(nbr, nw, nmask,
                                      jnp.asarray(labels_loc),
                                      jnp.asarray(cand), jnp.int32(seed)))

    def partition_wake(self, ops_ns, inputs, changed_loc) -> np.ndarray:
        nbr, _nw, nmask = inputs
        return np.asarray(ops_ns.wake(nbr, nmask, jnp.asarray(changed_loc)))

    def partition_split(self, ops_ns, inputs, comm_loc, labels_loc,
                        active_owned, bound) -> np.ndarray:
        nbr, _nw, nmask = inputs
        active = np.zeros(nbr.shape[0], bool)
        active[: len(active_owned)] = active_owned
        return np.asarray(ops_ns.split(nbr, nmask, jnp.asarray(comm_loc),
                                       jnp.asarray(labels_loc),
                                       jnp.asarray(active)))

    def partition_split_wake(self, ops_ns, inputs, comm_loc,
                             changed_loc) -> np.ndarray:
        nbr, _nw, nmask = inputs
        return np.asarray(ops_ns.split_wake(nbr, nmask,
                                            jnp.asarray(comm_loc),
                                            jnp.asarray(changed_loc)))

    # Fused partition sweeps (fuse_sweeps on): the ooc driver's lazy-wake
    # loop already matches the fused kernel's contract, so wake + move
    # (and split-wake + min-label) collapse into one dispatch per
    # partition visit.  Owned-row state columns pad to the tile height.

    def partition_move_fused(self, ops_ns, inputs, labels_loc, changed_loc,
                             active_owned, cand_prev_owned, klass_owned,
                             seed, bound):
        nbr, nw, nmask = inputs
        rows = nbr.shape[0]

        def pad(col):
            out = np.zeros(rows, dtype=bool)
            out[: len(col)] = col
            return jnp.asarray(out)

        new, act = ops_ns.fused_move(
            nbr, nw, nmask, jnp.asarray(labels_loc),
            jnp.asarray(changed_loc), pad(active_owned),
            pad(cand_prev_owned), pad(klass_owned), jnp.int32(seed))
        return np.asarray(new), np.asarray(act)

    def partition_split_fused(self, ops_ns, inputs, comm_loc, labels_loc,
                              changed_loc, bound) -> np.ndarray:
        nbr, _nw, nmask = inputs
        return np.asarray(ops_ns.fused_split(nbr, nmask,
                                             jnp.asarray(comm_loc),
                                             jnp.asarray(labels_loc),
                                             jnp.asarray(changed_loc)))

    # --- batched dispatch: one tile launch over the packed super-graph.
    # Labels live in per-graph *local* coordinates (the argmax tie-break
    # hashes raw label values); nbr tiles hold global row ids, and the
    # per-slot done/iters state freezes each member exactly where its
    # standalone run would stop.

    def build_batch(self, bucket: BatchBucketKey, config: EngineConfig):
        rows = tile_rows(bucket.n)
        k1 = bucket.k + 1
        tau, max_iterations = config.tau, config.max_iterations
        mode = config.kernel_mode
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut
        fuse = ops.resolve_fuse(config.fuse_sweeps, config.kernel_mode)
        profile = config.profile != "off"
        split_rows = 2 * max_iterations if config.profile == "full" else 0

        ids = np.arange(rows, dtype=np.int32)

        def _propagate(nbr, nw, nmask, sizes, graph_id, voffset, n_total,
                       labels0, active0):
            TRACE_LOG.record("tile:batch_propagate")
            vid = jnp.asarray(ids)
            local = vid - voffset
            parity = (_label_hash(local, jnp.int32(-1)) & 1).astype(bool)
            real = vid < n_total
            thr = (jnp.float32(tau)
                   * sizes.astype(jnp.float32)).astype(jnp.int32)
            done0 = sizes <= thr

            def cond(s):
                _labels, _active, it, done, _iters = s[:5]
                return jnp.any(~done) & (it < max_iterations)

            def body(s):
                labels, active, it, done, iters = s[:5]
                buf = s[5] if profile else None
                running = ~done[graph_id]
                dn = jnp.zeros((k1,), jnp.int32)
                for sweep in range(2):  # semi-synchronous parity sub-sweeps
                    klass = parity if sweep else ~parity
                    cand = active & klass & running
                    seed = 2 * it + sweep
                    best_lab, best_w, cur_w = ops.label_argmax(
                        labels[nbr], nw, nmask, labels,
                        jnp.asarray(seed, jnp.int32), mode=mode)
                    adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))
                    new = jnp.where(adopt, best_lab.astype(jnp.int32), labels)
                    changed = new != labels
                    wake = jnp.any(changed[nbr] & nmask, axis=1)
                    active = (active & ~cand) | (wake & real)
                    labels = new
                    sc = jax.ops.segment_sum(changed.astype(jnp.int32),
                                             graph_id, num_segments=k1)
                    dn = dn + sc
                    if profile:
                        buf = buf.at[seed].set(jnp.stack(
                            [jax.ops.segment_sum(cand.astype(jnp.int32),
                                                 graph_id, num_segments=k1),
                             sc]))
                iters = iters + jnp.where(done, 0, 1)
                nxt = (labels, active, it + jnp.int32(1),
                       done | (dn <= thr), iters)
                return nxt + (buf,) if profile else nxt

            init = (labels0.astype(jnp.int32), active0 & real, jnp.int32(0),
                    done0, jnp.zeros((k1,), jnp.int32))
            if profile:
                init = init + (jnp.full((2 * max_iterations, 2, k1), -1,
                                        jnp.int32),)
                labels, _, _, _, iters, buf = jax.lax.while_loop(cond, body,
                                                                 init)
                return labels, iters, buf
            labels, _, _, _, iters = jax.lax.while_loop(cond, body, init)
            return labels, iters

        def _propagate_fused(nbr, nw, nmask, sizes, graph_id, voffset,
                             n_total, labels0, active0):
            TRACE_LOG.record("tile:batch_propagate_fused")
            vid = jnp.asarray(ids)
            local = vid - voffset
            parity = (_label_hash(local, jnp.int32(-1)) & 1).astype(bool)
            real = vid < n_total
            thr = (jnp.float32(tau)
                   * sizes.astype(jnp.float32)).astype(jnp.int32)
            done0 = sizes <= thr

            def cond(s):
                _labels, _active, _chg, _candp, it, done, _iters = s[:7]
                return jnp.any(~done) & (it < max_iterations)

            def body(s):
                # Lazy wake (see the solo fused body); done graphs keep
                # running=False folded into the candidate class column.
                labels, active, chg, candp, it, done, iters = s[:7]
                buf = s[7] if profile else None
                running = ~done[graph_id]
                dn = jnp.zeros((k1,), jnp.int32)
                for sweep in range(2):  # semi-synchronous parity sub-sweeps
                    klass = parity if sweep else ~parity
                    seed = 2 * it + sweep
                    new, active = ops.fused_move(
                        labels[nbr], nw, nmask, chg[nbr], labels, active,
                        candp, klass & running, real,
                        jnp.asarray(seed, jnp.int32), mode=mode)
                    chg = new != labels
                    candp = active & klass & running
                    labels = new
                    sc = jax.ops.segment_sum(chg.astype(jnp.int32),
                                             graph_id, num_segments=k1)
                    dn = dn + sc
                    if profile:
                        # candp is exactly this sub-sweep's candidate set
                        buf = buf.at[seed].set(jnp.stack(
                            [jax.ops.segment_sum(candp.astype(jnp.int32),
                                                 graph_id, num_segments=k1),
                             sc]))
                iters = iters + jnp.where(done, 0, 1)
                nxt = (labels, active, chg, candp, it + jnp.int32(1),
                       done | (dn <= thr), iters)
                return nxt + (buf,) if profile else nxt

            zeros = jnp.zeros(rows, dtype=bool)
            init = (labels0.astype(jnp.int32), active0 & real, zeros, zeros,
                    jnp.int32(0), done0, jnp.zeros((k1,), jnp.int32))
            if profile:
                init = init + (jnp.full((2 * max_iterations, 2, k1), -1,
                                        jnp.int32),)
                labels, _, _, _, _, _, iters, buf = jax.lax.while_loop(
                    cond, body, init)
                return labels, iters, buf
            labels, _, _, _, _, _, iters = jax.lax.while_loop(cond, body,
                                                              init)
            return labels, iters

        def _split(nbr, nmask, sizes, graph_id, voffset, comm):
            TRACE_LOG.record("tile:batch_split")
            vid = jnp.asarray(ids)
            local = vid - voffset
            same = (comm[nbr] == comm[:, None]) & nmask
            done0 = sizes == 0

            def cond(s):
                _labels, _active, done, _iters = s[:4]
                return jnp.any(~done)

            def body(s):
                labels, active, done, iters = s[:4]
                buf = s[4] if split_rows else None
                new = ops.min_label(labels[nbr], comm[nbr], nmask, labels,
                                    comm, mode=mode)
                if prune:
                    new = jnp.where(active, new, labels)
                if shortcut:
                    new = jnp.minimum(new, new[new + voffset])
                changed = new != labels
                dn = jax.ops.segment_sum(changed.astype(jnp.int32),
                                         graph_id, num_segments=k1)
                if split_rows:
                    # iters.max() is the global sweep index: a not-yet-done
                    # slot increments every sweep, so its count equals the
                    # body-execution count.  Rows past the cap overwrite
                    # the last row (flagged truncated at fetch time).
                    row = jnp.minimum(iters.max(), split_rows - 1)
                    buf = buf.at[row].set(jnp.stack(
                        [jax.ops.segment_sum(active.astype(jnp.int32),
                                             graph_id, num_segments=k1),
                         dn]))
                if prune:
                    active = jnp.any(changed[nbr] & same, axis=1)
                iters = iters + jnp.where(done, 0, 1)
                nxt = (new, active, done | (dn == 0), iters)
                return nxt + (buf,) if split_rows else nxt

            init = (local, jnp.ones(rows, dtype=bool), done0,
                    jnp.zeros((k1,), jnp.int32))
            if split_rows:
                init = init + (jnp.full((split_rows, 2, k1), -1,
                                        jnp.int32),)
                labels, _, _, iters, buf = jax.lax.while_loop(cond, body,
                                                              init)
                return labels, iters, buf
            labels, _, _, iters = jax.lax.while_loop(cond, body, init)
            return labels, iters

        def _split_fused(nbr, nmask, sizes, graph_id, voffset, comm):
            TRACE_LOG.record("tile:batch_split_fused")
            vid = jnp.asarray(ids)
            local = vid - voffset
            done0 = sizes == 0

            def cond(s):
                _labels, _chg, done, _iters = s[:4]
                return jnp.any(~done)

            def body(s):
                labels, chg, done, iters = s[:4]
                buf = s[4] if split_rows else None
                new = ops.fused_split(labels[nbr], comm[nbr], nmask,
                                      chg[nbr], labels, comm, prune=prune,
                                      mode=mode)
                if shortcut:
                    new = jnp.minimum(new, new[new + voffset])
                changed = new != labels
                dn = jax.ops.segment_sum(changed.astype(jnp.int32),
                                         graph_id, num_segments=k1)
                if split_rows:
                    # Fused bodies fold the prune worklist into the kernel,
                    # so last sweep's changed set stands in as the frontier.
                    row = jnp.minimum(iters.max(), split_rows - 1)
                    buf = buf.at[row].set(jnp.stack(
                        [jax.ops.segment_sum(chg.astype(jnp.int32),
                                             graph_id, num_segments=k1),
                         dn]))
                iters = iters + jnp.where(done, 0, 1)
                nxt = (new, changed, done | (dn == 0), iters)
                return nxt + (buf,) if split_rows else nxt

            init = (local, jnp.ones(rows, dtype=bool), done0,
                    jnp.zeros((k1,), jnp.int32))
            if split_rows:
                init = init + (jnp.full((split_rows, 2, k1), -1,
                                        jnp.int32),)
                labels, _, _, iters, buf = jax.lax.while_loop(cond, body,
                                                              init)
                return labels, iters, buf
            labels, _, _, iters = jax.lax.while_loop(cond, body, init)
            return labels, iters

        return SimpleNamespace(
            rows=rows,
            propagate=jax.jit(_propagate_fused if fuse else _propagate),
            split=(jax.jit(_split_fused if fuse else _split)
                   if do_split else None),
            profile=profile,
            split_profile_rows=split_rows if do_split else 0,
        )

    def prepare_batch(self, batch, bucket: BatchBucketKey,
                      config: EngineConfig):
        rows = tile_rows(bucket.n)
        nbr, nw, nmask = to_padded_neighbors(batch.graph, d_max=bucket.d)
        nbr, nw, nmask = pad_tile_rows(nbr, nw, nmask, rows)
        sizes, graph_id, voffset = batch_index_arrays(batch, bucket.k, rows)
        return (jnp.asarray(nbr), jnp.asarray(nw), jnp.asarray(nmask),
                jnp.asarray(sizes), jnp.asarray(graph_id),
                jnp.asarray(voffset), jnp.int32(batch.total_vertices))

    def run_batch(self, plan, inputs,
                  init_labels: np.ndarray | None = None,
                  init_active: np.ndarray | None = None) -> BatchBackendRun:
        nbr, nw, nmask, sizes, graph_id, voffset, n_total = inputs
        k1 = sizes.shape[0]
        labels0, active0 = warm_state_rows(plan.rows, voffset,
                                           init_labels, init_active)
        profiling = getattr(plan, "profile", False)

        t0 = time.perf_counter()
        out = plan.propagate(nbr, nw, nmask, sizes, graph_id,
                             voffset, n_total,
                             jnp.asarray(labels0),
                             jnp.asarray(active0))
        (labels, iters, pbuf) = out if profiling else (*out, None)
        labels = jax.block_until_ready(labels)
        t1 = time.perf_counter()

        split_iters = np.zeros(k1, np.int32)
        sbuf = None
        if plan.split is not None:
            out = plan.split(nbr, nmask, sizes, graph_id, voffset, labels)
            (labels, siters, sbuf) = (out if plan.split_profile_rows
                                      else (*out, None))
            labels = jax.block_until_ready(labels)
            split_iters = np.asarray(siters)
        t2 = time.perf_counter()

        profiles = None
        if profiling:
            profiles = batch_profiles(pbuf, np.asarray(iters), sbuf,
                                      split_iters,
                                      plan.split_profile_rows,
                                      np.asarray(sizes))

        return BatchBackendRun(labels=np.asarray(labels),
                               lpa_iterations=np.asarray(iters),
                               split_iterations=split_iters,
                               lpa_seconds=t1 - t0, split_seconds=t2 - t1,
                               profile=profiles)
