"""Sharded backend: multi-device shard_map execution behind the Engine.

Reuses ``core.distributed``'s step builders but keeps them in the
engine's compile cache: the jitted LPA/split steps are built once per
(shape bucket, mesh, exchange_every) and the host-driven loop replays
them for every graph in the bucket — the real vertex count rides along
as a traced scalar.  With ``exchange_every=1`` (and one device) the
result is bit-identical to the segment and tile backends; with more
devices it matches the single-device engine exactly (enforced by
``tests/test_distributed.py``).

Requesting ``split="lpp"`` is rejected: the distributed split step has no
pruning variant (the all-gather already dominates; see DESIGN.md §6).
"""
from __future__ import annotations

import time
from functools import lru_cache
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import (
    make_lpa_step,
    make_split_step,
    shard_graph,
)
from repro.core.graph import Graph
from repro.engine.backends.tile import tile_rows
from repro.engine.bucketing import BucketKey, pad_active, pad_labels
from repro.engine.cache import TRACE_LOG
from repro.engine.config import EngineConfig
from repro.engine.registry import BackendRun, register_backend


@lru_cache(maxsize=1)
def _default_mesh():
    from repro.launch.mesh import make_flat_mesh
    return make_flat_mesh()


def _resolve_mesh(config: EngineConfig):
    return config.mesh if config.mesh is not None else _default_mesh()


def _shard_rows(bucket_n: int, n_dev: int) -> int:
    per = n_dev * 8
    return ((tile_rows(bucket_n) + per - 1) // per) * per


@register_backend("sharded")
class ShardedBackend:
    name = "sharded"
    # No batched dispatch yet: the shard_map steps gather labels across
    # devices each exchange, and a packed multi-graph layout would need
    # per-shard graph_id bookkeeping (ROADMAP open item).  Engine.fit_many
    # falls back to sequential fits for this backend.
    supports_batch = False

    def plan_key(self, config: EngineConfig) -> tuple:
        # the Mesh itself (hashable: device ids + axis names) — two meshes
        # with equal shape but different devices must not share a plan
        return (_resolve_mesh(config),)

    def build(self, bucket: BucketKey, config: EngineConfig):
        if config.split == "lpp":
            raise ValueError("sharded backend supports split in "
                             "('none', 'lp', 'bfs_host'); use 'lp'")
        mesh = _resolve_mesh(config)
        n_dev = int(np.prod(tuple(mesh.shape.values())))
        rows = _shard_rows(bucket.n, n_dev)
        step = make_lpa_step(
            mesh, rows, bucket.d, exchange_every=config.exchange_every,
            mode=config.kernel_mode,
            trace_hook=lambda: TRACE_LOG.record("sharded:propagate"))
        split = None
        if config.split == "lp":
            split = make_split_step(
                mesh, rows, bucket.d, mode=config.kernel_mode,
                trace_hook=lambda: TRACE_LOG.record("sharded:split"))
        return SimpleNamespace(mesh=mesh, rows=rows, step=step, split=split,
                               tau=config.tau,
                               max_iterations=config.max_iterations)

    def prepare(self, graph: Graph, bucket: BucketKey,
                config: EngineConfig):
        mesh = _resolve_mesh(config)
        n_dev = int(np.prod(tuple(mesh.shape.values())))
        sg = shard_graph(graph, mesh, d_max=bucket.d,
                         n_rows=_shard_rows(bucket.n, n_dev))
        return sg

    def run(self, plan, inputs, n_real: int,
            init_labels: np.ndarray | None,
            init_active: np.ndarray | None = None) -> BackendRun:
        sg = inputs
        mesh = plan.mesh
        axes = tuple(mesh.axis_names)
        rep = NamedSharding(mesh, P())
        vec = NamedSharding(mesh, P(axes))
        labels = jax.device_put(jnp.asarray(pad_labels(
            np.arange(n_real, dtype=np.int32) if init_labels is None
            else init_labels, n_real, plan.rows)), rep)
        active = jax.device_put(
            (jnp.arange(plan.rows, dtype=jnp.int32) < n_real)
            & jnp.asarray(pad_active(init_active, n_real, plan.rows)), vec)
        threshold = int(np.float32(plan.tau) * np.float32(n_real))
        nr = jnp.int32(n_real)

        t0 = time.perf_counter()
        it = 0
        while it < plan.max_iterations:
            labels, active, dn = plan.step(sg.nbr, sg.nw, sg.nmask, labels,
                                           active, jnp.int32(it), nr)
            it += 1
            # host-driven convergence loop by design: one scalar readback
            # lint: host-sync-ok — per exchange round (README "sharded")
            if int(dn) <= threshold:
                break
        labels = jax.block_until_ready(labels)
        t1 = time.perf_counter()

        sit = 0
        if plan.split is not None:
            comm = labels
            labels = jax.device_put(
                jnp.arange(plan.rows, dtype=jnp.int32), rep)
            while True:
                labels, dn = plan.split(sg.nbr, sg.nw, sg.nmask, comm, labels)
                sit += 1
                # lint: host-sync-ok — split fixed-point, one scalar/round
                if int(dn) == 0:
                    break
            labels = jax.block_until_ready(labels)
        t2 = time.perf_counter()

        return BackendRun(labels=np.asarray(labels), lpa_iterations=it,
                          split_iterations=sit,
                          lpa_seconds=t1 - t0, split_seconds=t2 - t1)
