"""Segment backend: the CSR edge-list sort + segment-reduce path.

Wraps ``core.lpa.lpa_run`` (propagation) and ``core.split.split_lp``
(Split-Last) behind the Backend protocol.  The plan's jitted wrappers
close over the algorithm statics and record into ``TRACE_LOG`` at trace
time, so same-bucket graphs demonstrably reuse one executable.

In ``bucketing="exact"`` mode the convergence threshold is baked in
statically (``tau * n`` with Python float semantics) — bit-identical to
the legacy ``gsl_lpa`` path, which is what the compatibility wrappers
rely on.  In ``pow2`` mode the threshold is computed from the traced
real vertex count so one executable serves the whole bucket.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import lpa_run_batched, split_lp_batched, warm_state_rows
from repro.core.graph import Graph
from repro.core.lpa import lpa_run
from repro.core.split import split_lp
from repro.engine.bucketing import (
    BatchBucketKey,
    BucketKey,
    batch_index_arrays,
    pad_active,
    pad_graph,
    pad_labels,
)
from repro.engine.cache import TRACE_LOG
from repro.engine.config import EngineConfig
from repro.engine.registry import BackendRun, BatchBackendRun, register_backend


@register_backend("segment")
class SegmentBackend:
    name = "segment"
    supports_batch = True

    def plan_key(self, config: EngineConfig) -> tuple:
        return ()

    def build(self, bucket: BucketKey, config: EngineConfig):
        exact = config.bucketing == "exact"
        tau, max_iterations = config.tau, config.max_iterations
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut

        def _propagate(graph, n_real, labels0, active0):
            TRACE_LOG.record("segment:propagate")
            return lpa_run(graph, tau=tau, max_iterations=max_iterations,
                           init_labels=labels0,
                           n_real=None if exact else n_real,
                           init_active=active0)

        def _split(graph, labels):
            TRACE_LOG.record("segment:split")
            return split_lp(graph, labels, prune=prune, shortcut=shortcut)

        return SimpleNamespace(
            propagate=jax.jit(_propagate),
            split=jax.jit(_split) if do_split else None,
        )

    def prepare(self, graph: Graph, bucket: BucketKey,
                config: EngineConfig) -> Graph:
        return pad_graph(graph, bucket)

    def run(self, plan, inputs: Graph, n_real: int,
            init_labels: np.ndarray | None,
            init_active: np.ndarray | None = None) -> BackendRun:
        g = inputs
        labels0 = jnp.asarray(pad_labels(
            np.arange(n_real, dtype=np.int32) if init_labels is None
            else init_labels, n_real, g.n))
        active0 = jnp.asarray(pad_active(init_active, n_real, g.n))

        t0 = time.perf_counter()
        state = plan.propagate(g, jnp.int32(n_real), labels0, active0)
        labels = jax.block_until_ready(state.labels)
        lpa_iters = int(state.iteration)
        t1 = time.perf_counter()

        split_iters = 0
        if plan.split is not None:
            st = plan.split(g, labels)
            labels = jax.block_until_ready(st.labels)
            split_iters = int(st.iterations)
        t2 = time.perf_counter()

        return BackendRun(labels=np.asarray(labels),
                          lpa_iterations=lpa_iters,
                          split_iterations=split_iters,
                          lpa_seconds=t1 - t0, split_seconds=t2 - t1)

    # --- batched dispatch (GraphBatch disjoint-union packing) ---

    def build_batch(self, bucket: BatchBucketKey, config: EngineConfig):
        tau, max_iterations = config.tau, config.max_iterations
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut

        def _propagate(graph, sizes, graph_id, voffset, labels0, active0):
            TRACE_LOG.record("segment:batch_propagate")
            return lpa_run_batched(graph, sizes, graph_id, voffset,
                                   labels0, active0,
                                   tau=tau, max_iterations=max_iterations)

        def _split(graph, sizes, graph_id, voffset, comm):
            TRACE_LOG.record("segment:batch_split")
            return split_lp_batched(graph, sizes, graph_id, voffset, comm,
                                    prune=prune, shortcut=shortcut)

        return SimpleNamespace(
            propagate=jax.jit(_propagate),
            split=jax.jit(_split) if do_split else None,
        )

    def prepare_batch(self, batch, bucket: BatchBucketKey,
                      config: EngineConfig):
        g = pad_graph(batch.graph, BucketKey(bucket.n, bucket.m, bucket.d))
        sizes, graph_id, voffset = batch_index_arrays(batch, bucket.k,
                                                      bucket.n)
        return (g, jnp.asarray(sizes), jnp.asarray(graph_id),
                jnp.asarray(voffset))

    def run_batch(self, plan, inputs,
                  init_labels: np.ndarray | None = None,
                  init_active: np.ndarray | None = None) -> BatchBackendRun:
        g, sizes, graph_id, voffset = inputs
        k1 = sizes.shape[0]
        labels0, active0 = warm_state_rows(g.n, voffset,
                                           init_labels, init_active)

        t0 = time.perf_counter()
        labels, iters = plan.propagate(g, sizes, graph_id, voffset,
                                       jnp.asarray(labels0),
                                       jnp.asarray(active0))
        labels = jax.block_until_ready(labels)
        t1 = time.perf_counter()

        split_iters = np.zeros(k1, np.int32)
        if plan.split is not None:
            labels, siters = plan.split(g, sizes, graph_id, voffset, labels)
            labels = jax.block_until_ready(labels)
            split_iters = np.asarray(siters)
        t2 = time.perf_counter()

        return BatchBackendRun(labels=np.asarray(labels),
                               lpa_iterations=np.asarray(iters),
                               split_iterations=split_iters,
                               lpa_seconds=t1 - t0, split_seconds=t2 - t1)
