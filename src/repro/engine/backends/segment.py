"""Segment backend: the CSR edge-list sort + segment-reduce path.

Wraps ``core.lpa.lpa_run`` (propagation) and ``core.split.split_lp``
(Split-Last) behind the Backend protocol.  The plan's jitted wrappers
close over the algorithm statics and record into ``TRACE_LOG`` at trace
time, so same-bucket graphs demonstrably reuse one executable.

In ``bucketing="exact"`` mode the convergence threshold is baked in
statically (``tau * n`` with Python float semantics) — bit-identical to
the legacy ``gsl_lpa`` path, which is what the compatibility wrappers
rely on.  In ``pow2`` mode the threshold is computed from the traced
real vertex count so one executable serves the whole bucket.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import lpa_run_batched, split_lp_batched, warm_state_rows
from repro.core.graph import Graph
from repro.core.lpa import lpa_move, lpa_run, neighbors_of
from repro.core.split import min_label_sweep, min_label_wake, split_lp
from repro.engine.bucketing import (
    BatchBucketKey,
    BucketKey,
    batch_index_arrays,
    pad_active,
    pad_graph,
    pad_labels,
)
from repro.engine.cache import TRACE_LOG
from repro.engine.config import EngineConfig
from repro.engine.registry import BackendRun, BatchBackendRun, register_backend
from repro.obs.convergence import batch_profiles, solo_profile


@register_backend("segment")
class SegmentBackend:
    name = "segment"
    supports_batch = True
    supports_partition = True
    supports_fused_partition = True

    def plan_key(self, config: EngineConfig) -> tuple:
        return ()

    def build(self, bucket: BucketKey, config: EngineConfig):
        exact = config.bucketing == "exact"
        tau, max_iterations = config.tau, config.max_iterations
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut
        profile = config.profile != "off"
        split_rows = 2 * max_iterations if config.profile == "full" else 0

        def _propagate(graph, n_real, labels0, active0):
            TRACE_LOG.record("segment:propagate")
            return lpa_run(graph, tau=tau, max_iterations=max_iterations,
                           init_labels=labels0,
                           n_real=None if exact else n_real,
                           init_active=active0, profile=profile)

        def _split(graph, labels, n_real):
            TRACE_LOG.record("segment:split")
            return split_lp(graph, labels, prune=prune, shortcut=shortcut,
                            profile_rows=split_rows, n_real=n_real)

        return SimpleNamespace(
            propagate=jax.jit(_propagate),
            split=jax.jit(_split) if do_split else None,
            profile=profile, split_profile_rows=split_rows,
            max_iterations=max_iterations,
        )

    def prepare(self, graph: Graph, bucket: BucketKey,
                config: EngineConfig) -> Graph:
        return pad_graph(graph, bucket)

    def run(self, plan, inputs: Graph, n_real: int,
            init_labels: np.ndarray | None,
            init_active: np.ndarray | None = None) -> BackendRun:
        g = inputs
        labels0 = jnp.asarray(pad_labels(
            np.arange(n_real, dtype=np.int32) if init_labels is None
            else init_labels, n_real, g.n))
        active0 = jnp.asarray(pad_active(init_active, n_real, g.n))

        profiling = getattr(plan, "profile", False)
        t0 = time.perf_counter()
        out = plan.propagate(g, jnp.int32(n_real), labels0, active0)
        state, pbuf = out if profiling else (out, None)
        labels = jax.block_until_ready(state.labels)
        lpa_iters = int(state.iteration)
        t1 = time.perf_counter()

        split_iters = 0
        sbuf = None
        if plan.split is not None:
            out = plan.split(g, labels, jnp.int32(n_real))
            st, sbuf = out if plan.split_profile_rows else (out, None)
            labels = jax.block_until_ready(st.labels)
            split_iters = int(st.iterations)
        t2 = time.perf_counter()

        # profile fetch: one host transfer, after the convergence sync
        profile = solo_profile(pbuf, lpa_iters, sbuf, split_iters,
                               plan.split_profile_rows,
                               int(n_real)) if profiling else None
        return BackendRun(labels=np.asarray(labels),
                          lpa_iterations=lpa_iters,
                          split_iterations=split_iters,
                          lpa_seconds=t1 - t0, split_seconds=t2 - t1,
                          profile=profile)

    # --- batched dispatch (GraphBatch disjoint-union packing) ---

    def build_batch(self, bucket: BatchBucketKey, config: EngineConfig):
        tau, max_iterations = config.tau, config.max_iterations
        do_split = config.split in ("lp", "lpp")
        prune = config.split == "lpp"
        shortcut = config.shortcut
        profile = config.profile != "off"
        split_rows = 2 * max_iterations if config.profile == "full" else 0

        def _propagate(graph, sizes, graph_id, voffset, labels0, active0):
            TRACE_LOG.record("segment:batch_propagate")
            return lpa_run_batched(graph, sizes, graph_id, voffset,
                                   labels0, active0,
                                   tau=tau, max_iterations=max_iterations,
                                   profile=profile)

        def _split(graph, sizes, graph_id, voffset, comm):
            TRACE_LOG.record("segment:batch_split")
            return split_lp_batched(graph, sizes, graph_id, voffset, comm,
                                    prune=prune, shortcut=shortcut,
                                    profile_rows=split_rows)

        return SimpleNamespace(
            propagate=jax.jit(_propagate),
            split=jax.jit(_split) if do_split else None,
            profile=profile, split_profile_rows=split_rows,
        )

    def prepare_batch(self, batch, bucket: BatchBucketKey,
                      config: EngineConfig):
        g = pad_graph(batch.graph, BucketKey(bucket.n, bucket.m, bucket.d))
        sizes, graph_id, voffset = batch_index_arrays(batch, bucket.k,
                                                      bucket.n)
        return (g, jnp.asarray(sizes), jnp.asarray(graph_id),
                jnp.asarray(voffset))

    # --- out-of-core partition sweeps (repro.partition.ooc driver) ---
    #
    # One partition's edge window runs as a compact local Graph: rows
    # [0, size) are the owned vertex range, rows [size, n_local) the
    # halo imports (no out-edges, so they can never adopt).  Label
    # *values* stay global vertex ids — the tie-break hash is a function
    # of the raw value — so every sweep takes the full graph's vertex
    # count as a traced ``label_bound`` sentinel; local row counts and
    # edge windows are padded to one uniform per-run shape, so all
    # partitions share a single jitted executable per stage.

    def build_partition(self, config: EngineConfig):
        prune = config.split == "lpp"
        # Unlike the tile backend (where fusion means a real Pallas kernel
        # body, so 'auto' only fuses when one executes), the segment fused
        # sweeps are jnp compositions — one XLA executable instead of two
        # full edge passes per partition visit — and profit on every
        # backend, so 'auto' fuses here.
        fuse = config.fuse_sweeps != "off"

        def _move(graph, labels, cand, seed, bound):
            TRACE_LOG.record("segment:part_move")
            new, _, _ = lpa_move(graph, labels, cand, seed,
                                 label_bound=bound)
            return new

        def _wake(graph, changed):
            TRACE_LOG.record("segment:part_wake")
            return neighbors_of(graph, changed)

        def _split(graph, comm, labels, active, bound):
            TRACE_LOG.record("segment:part_split")
            return min_label_sweep(graph, comm, labels, active, bound,
                                   prune=prune)

        def _split_wake(graph, comm, changed):
            TRACE_LOG.record("segment:part_split_wake")
            return min_label_wake(graph, comm, changed)

        def _fused_move(graph, labels, chg, active, candp, klass, seed,
                        bound):
            TRACE_LOG.record("segment:part_fused_move")
            wake = neighbors_of(graph, chg)
            act = (active & ~candp) | wake
            new, _, _ = lpa_move(graph, labels, act & klass, seed,
                                 label_bound=bound)
            return new, act

        def _fused_split(graph, comm, labels, chg, bound):
            TRACE_LOG.record("segment:part_fused_split")
            if prune:
                sact = min_label_wake(graph, comm, chg)
            else:
                # no-prune split sweeps every row every iteration; rows
                # without a same-community neighbor reduce to their own
                # label, so the all-ones active is the identity on them
                sact = jnp.ones(graph.n, dtype=bool)
            return min_label_sweep(graph, comm, labels, sact, bound,
                                   prune=prune)

        return SimpleNamespace(
            move=jax.jit(_move), wake=jax.jit(_wake),
            split=jax.jit(_split), split_wake=jax.jit(_split_wake),
            fused_move=jax.jit(_fused_move),
            fused_split=jax.jit(_fused_split), fuse=fuse,
        )

    def partition_caps(self, budget: int, d_bucket: int):
        """(max_edges, max_vertices) per partition for a byte budget.

        One resident partition costs ~12 B/edge of locally-remapped
        window plus ~13 B/edge × pow2 padding of device CSR and ~24
        B/row of vertex-indexed locals; halving the budget leaves the
        LRU headroom for per-sweep transient gathers.
        """
        half = max(budget // 2, 1)
        return max(half // 64, 1), max(half // 48, 8)

    def partition_prepare_nbytes(self, shapes) -> int:
        return shapes.m * 13 + (shapes.n_loc + 1) * 4 + shapes.n_loc * 4

    def prepare_partition(self, resident, shapes, config: EngineConfig):
        """Pad a resident slice to the run's uniform local-Graph shape."""
        n_loc, m = shapes.n_loc, shapes.m
        m_w = len(resident.src)
        src = np.zeros(m, np.int32)
        dst = np.zeros(m, np.int32)
        wgt = np.zeros(m, np.float32)
        mask = np.zeros(m, bool)
        src[:m_w] = resident.src
        dst[:m_w] = resident.dst
        wgt[:m_w] = resident.wgt
        mask[:m_w] = True
        row_ptr = np.full(n_loc + 1, m_w, np.int32)
        row_ptr[: resident.size + 1] = resident.row_ptr
        # num_edges is static pytree aux data: it must be the *uniform*
        # padded size, not the per-partition real count, or every distinct
        # window width retraces the sweep jits (validity flows through
        # edge_mask; the sweep kernels never read num_edges)
        g = Graph(n=n_loc, m_pad=m, num_edges=m,
                  row_ptr=jnp.asarray(row_ptr), src=jnp.asarray(src),
                  dst=jnp.asarray(dst), wgt=jnp.asarray(wgt),
                  edge_mask=jnp.asarray(mask),
                  kdeg=jnp.zeros(n_loc, jnp.float32))
        return g, self.partition_prepare_nbytes(shapes)

    def partition_move(self, ops_ns, inputs, labels_loc, cand_owned,
                       seed, bound) -> np.ndarray:
        g = inputs
        cand = np.zeros(g.n, bool)
        cand[: len(cand_owned)] = cand_owned
        return np.asarray(ops_ns.move(g, jnp.asarray(labels_loc),
                                      jnp.asarray(cand),
                                      jnp.int32(seed), bound))

    def partition_wake(self, ops_ns, inputs, changed_loc) -> np.ndarray:
        return np.asarray(ops_ns.wake(inputs, jnp.asarray(changed_loc)))

    def partition_split(self, ops_ns, inputs, comm_loc, labels_loc,
                        active_owned, bound) -> np.ndarray:
        g = inputs
        active = np.zeros(g.n, bool)
        active[: len(active_owned)] = active_owned
        return np.asarray(ops_ns.split(g, jnp.asarray(comm_loc),
                                       jnp.asarray(labels_loc),
                                       jnp.asarray(active), bound))

    def partition_split_wake(self, ops_ns, inputs, comm_loc,
                             changed_loc) -> np.ndarray:
        return np.asarray(ops_ns.split_wake(inputs, jnp.asarray(comm_loc),
                                            jnp.asarray(changed_loc)))

    # Fused partition sweeps (fuse_sweeps != "off"): the ooc driver's
    # lazy-wake loop lets wake + active refresh + move (and split-wake +
    # min-label) run as one XLA executable per partition visit — one pass
    # over the window's edge arrays instead of two, and no host
    # round-trip of the intermediate wake mask.

    def partition_move_fused(self, ops_ns, inputs, labels_loc, changed_loc,
                             active_owned, cand_prev_owned, klass_owned,
                             seed, bound):
        g = inputs

        def pad(col):
            out = np.zeros(g.n, dtype=bool)
            out[: len(col)] = col
            return jnp.asarray(out)

        new, act = ops_ns.fused_move(
            g, jnp.asarray(labels_loc), jnp.asarray(changed_loc),
            pad(active_owned), pad(cand_prev_owned), pad(klass_owned),
            jnp.int32(seed), bound)
        return np.asarray(new), np.asarray(act)

    def partition_split_fused(self, ops_ns, inputs, comm_loc, labels_loc,
                              changed_loc, bound) -> np.ndarray:
        return np.asarray(ops_ns.fused_split(inputs, jnp.asarray(comm_loc),
                                             jnp.asarray(labels_loc),
                                             jnp.asarray(changed_loc),
                                             bound))

    def run_batch(self, plan, inputs,
                  init_labels: np.ndarray | None = None,
                  init_active: np.ndarray | None = None) -> BatchBackendRun:
        g, sizes, graph_id, voffset = inputs
        k1 = sizes.shape[0]
        profiling = getattr(plan, "profile", False)
        labels0, active0 = warm_state_rows(g.n, voffset,
                                           init_labels, init_active)

        t0 = time.perf_counter()
        out = plan.propagate(g, sizes, graph_id, voffset,
                             jnp.asarray(labels0), jnp.asarray(active0))
        (labels, iters, pbuf) = out if profiling else (*out, None)
        labels = jax.block_until_ready(labels)
        t1 = time.perf_counter()

        split_iters = np.zeros(k1, np.int32)
        sbuf = None
        if plan.split is not None:
            out = plan.split(g, sizes, graph_id, voffset, labels)
            (labels, siters, sbuf) = out if plan.split_profile_rows \
                else (*out, None)
            labels = jax.block_until_ready(labels)
            split_iters = np.asarray(siters)
        t2 = time.perf_counter()

        profiles = batch_profiles(pbuf, np.asarray(iters), sbuf,
                                  split_iters, plan.split_profile_rows,
                                  np.asarray(sizes)) if profiling else None
        return BatchBackendRun(labels=np.asarray(labels),
                               lpa_iterations=np.asarray(iters),
                               split_iterations=split_iters,
                               lpa_seconds=t1 - t0, split_seconds=t2 - t1,
                               profile=profiles)
