"""Engine configuration and the unified detection result.

``EngineConfig`` is the single knob surface for every execution strategy
(backend) behind :class:`repro.engine.Engine`; ``DetectionResult`` is the
backend-independent return type of ``Engine.fit``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

BACKENDS = ("auto", "segment", "tile", "sharded")
SPLIT_METHODS = ("none", "lp", "lpp", "bfs_host")
BUCKETING = ("pow2", "exact")
WARM_START = ("off", "auto")
FUSE_SWEEPS = ("auto", "on", "off")
PROFILE = ("off", "convergence", "full")
QUALITY = ("off", "basic", "full")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Configuration for :class:`repro.engine.Engine`.

    backend: execution strategy — ``"segment"`` (CSR sort+segment-reduce),
      ``"tile"`` (padded-neighbor tiles / Pallas kernels), ``"sharded"``
      (multi-device shard_map), or ``"auto"`` (chosen per graph from size,
      max degree, and device count).
    tau / max_iterations / split / shortcut: the GSL-LPA algorithm knobs
      (paper Algorithm 3 + Section 4), identical semantics to ``gsl_lpa``.
    bucketing: ``"pow2"`` pads every graph up to power-of-two vertex/edge
      buckets so same-bucket graphs share one compiled executable;
      ``"exact"`` compiles per exact shape (bit-identical to the legacy
      ``gsl_lpa`` path — used by the compatibility wrappers).
    min_vertex_bucket / min_edge_bucket: floors for the pow2 buckets, so a
      stream of small graphs collapses into a single bucket.
    warm_start: ``"auto"`` reuses a previous result's labels as the
      initial assignment whenever a graph's structural fingerprint hits
      the engine's warm-start cache (incremental re-detection on
      evolving graphs; applies to ``fit`` and ``fit_many`` members
      alike); ``"off"`` always starts from singletons.  Explicit
      ``init_labels`` always wins.
    memory_budget: resident edge-byte cap for ``Engine.fit`` (bytes, or
      a string like ``"64MB"``).  A graph whose edge arrays exceed it is
      detected out-of-core: partitioned into contiguous CSR slices swept
      one-resident-at-a-time with halo-label exchange
      (:mod:`repro.partition`) — labels bit-identical to the in-core
      fit.  ``None`` (default) always fits in core.  Per-call override:
      ``fit(graph, memory_budget=...)``.
    patch_churn_threshold: streaming sessions route a delta through the
      in-place CSR splice patch when it touches fewer than this fraction
      of vertices, and through the full vectorized rebuild above it.
      Default from the measured crossover on this container's CPU
      (``bench_streaming_deltas.py --churn-sweep`` reports the sweep).
    warm_cache_size: bound on the per-engine warm-start cache (LRU over
      graph fingerprints) — keeps a long streaming session from growing
      one labels array per graph ever seen.
    compute_metrics: also report modularity and disconnected-community
      fraction on the result (extra device work; off on the hot path).
    exchange_every: sharded backend — label all-gather cadence (1 is
      bit-faithful to single device; >1 trades staleness for bandwidth).
    kernel_mode: tile/sharded kernel dispatch — ``"auto"`` | ``"pallas"``
      | ``"interpret"`` | ``"ref"`` (see kernels/ops.py).
    fuse_sweeps: tile backend — run each sub-sweep's wake + move (and the
      split's wake + min-label) as one fused Pallas dispatch instead of
      two, with the (TILE_B, D) neighbor tiles read once per sweep
      (kernels/fused_sweep.py).  ``"auto"`` fuses exactly when a real
      kernel body executes (kernel_mode pallas/interpret); the jnp oracle
      stays unfused as the parity reference.  ``"on"`` / ``"off"`` force
      it.  Out-of-core partition sweeps fuse on the segment backend too
      under ``"auto"`` (the fused jnp compositions profit on every
      backend); only ``"off"`` disables that.  Labels and iteration
      counts are bit-identical either way (the fused-parity suite
      asserts this).
    mesh: sharded backend — a ``jax.sharding.Mesh``; defaults to one flat
      axis over every visible device.
    profile: per-fit convergence profiling depth.  ``"convergence"``
      captures the propagation phase's per-sub-sweep frontier/changed
      curve; ``"full"`` adds the Split-Last phase.  Counts are recorded
      device-side into a preallocated buffer carried through the sweep
      loop and fetched once after convergence — labels and iteration
      counts stay bit-identical to ``"off"`` (the parity suite asserts
      it), and no host sync enters the hot loop.  The flag is a plan
      static (part of ``algo_key()``), so ``"off"`` keeps today's exact
      executables.  Results surface as ``DetectionResult.profile``.
    quality: per-fit result-quality telemetry depth (``repro.obs.quality``).
      ``"basic"`` reports modularity (one device segment-sum pass over the
      final labels), community count, a community-size summary, and label
      churn vs the warm-start assignment; ``"full"`` adds the
      disconnected-community fraction (reuses ``check_connected``'s cached
      pass — the paper's headline invariant, live).  All of it runs *after*
      convergence on the final labels, so — unlike ``profile`` — the knob is
      NOT part of ``algo_key()``: every quality mode shares the ``"off"``
      executables and labels/iteration counts are bit-identical by
      construction (the parity suite pins it).  Reports land on
      ``DetectionResult.quality`` and in the metrics registry under the
      engine scope's ``quality.*`` names.
    """
    backend: str = "auto"
    tau: float = 0.05
    max_iterations: int = 20
    split: str = "lp"
    shortcut: bool = False
    bucketing: str = "pow2"
    min_vertex_bucket: int = 256
    min_edge_bucket: int = 2048
    warm_start: str = "off"
    warm_cache_size: int = 64
    memory_budget: int | str | None = None
    # Measured: the splice patch ties the rebuild at ~20% churn on this
    # container's CPU (3.7x faster at 2%, 2x slower at 50%) — see
    # bench_streaming_deltas.py's churn sweep, which reports the live
    # crossover so other hardware can recalibrate.
    patch_churn_threshold: float = 0.20
    compute_metrics: bool = False
    exchange_every: int = 1
    kernel_mode: str = "auto"
    fuse_sweeps: str = "auto"
    mesh: Any = None
    profile: str = "off"
    quality: str = "off"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.split not in SPLIT_METHODS:
            raise ValueError(f"split must be one of {SPLIT_METHODS}, "
                             f"got {self.split!r}")
        if self.bucketing not in BUCKETING:
            raise ValueError(f"bucketing must be one of {BUCKETING}, "
                             f"got {self.bucketing!r}")
        if self.warm_start not in WARM_START:
            raise ValueError(f"warm_start must be one of {WARM_START}, "
                             f"got {self.warm_start!r}")
        if self.fuse_sweeps not in FUSE_SWEEPS:
            raise ValueError(f"fuse_sweeps must be one of {FUSE_SWEEPS}, "
                             f"got {self.fuse_sweeps!r}")
        if self.profile not in PROFILE:
            raise ValueError(f"profile must be one of {PROFILE}, "
                             f"got {self.profile!r}")
        if self.quality not in QUALITY:
            raise ValueError(f"quality must be one of {QUALITY}, "
                             f"got {self.quality!r}")
        if self.exchange_every < 1:
            raise ValueError("exchange_every must be >= 1")
        if self.warm_cache_size < 1:
            raise ValueError("warm_cache_size must be >= 1")
        if self.memory_budget is not None:
            from repro.partition.plan import parse_bytes
            budget = parse_bytes(self.memory_budget)
            if budget < 1:
                raise ValueError("memory_budget must be >= 1 byte")
            object.__setattr__(self, "memory_budget", budget)
        if not 0.0 <= self.patch_churn_threshold <= 1.0:
            raise ValueError("patch_churn_threshold must be in [0, 1]")

    def algo_key(self) -> tuple:
        """The hashable algorithm statics a compiled plan specialises on."""
        return (self.tau, self.max_iterations, self.split, self.shortcut,
                self.exchange_every, self.kernel_mode, self.fuse_sweeps,
                self.profile)


@dataclasses.dataclass
class DetectionResult:
    """Unified result of ``Engine.fit`` — identical shape for all backends."""
    labels: np.ndarray            # (n,) int32, compacted to dense [0, K)
    num_communities: int
    backend: str                  # backend that actually ran
    lpa_iterations: int
    split_iterations: int         # 0 for split in ("none", "bfs_host")
    timings: dict[str, float]     # phase -> seconds (propagation/split/...)
    bucket: tuple                 # (n, m, d) — or (k, n, m, d) when batched
    cache_hit: bool               # compiled plan came from the engine cache
    warm_started: bool            # fit started from caller/previous labels
    modularity: float | None = None
    disconnected_fraction: float | None = None
    # Batched dispatch provenance (``Engine.fit_many``): how many graphs
    # shared the launch and this graph's position in the pack.  Batch-
    # level stage timings appear as ``"prorated_*"`` keys — work-share
    # estimates, not measurements; the real per-stage spans are recorded
    # once at batch level (see ``repro.obs.trace``).
    batch_size: int = 1
    batch_index: int = 0
    # Out-of-core provenance: partition count of the fit (1 = in-core)
    # and the driver's observability counters (peak resident bytes, halo
    # exchange volume, partition loads) when it ran partitioned.
    partitions: int = 1
    ooc: dict | None = None
    # Per-fit convergence profile (``EngineConfig.profile != "off"``):
    # a :class:`repro.obs.ConvergenceProfile` with the per-sub-sweep
    # frontier/changed curves.  None when profiling is off.
    profile: Any = None
    # Per-fit quality report (``EngineConfig.quality != "off"``): a
    # :class:`repro.obs.QualityReport` — modularity, community sizes,
    # churn vs the warm-start assignment, disconnected fraction ("full").
    quality: Any = None
    # Fingerprint of the graph the cached ``disconnected_fraction``
    # was computed against (see ``check_connected``).
    _connected_fp: Any = dataclasses.field(
        default=None, repr=False, compare=False)

    def check_connected(self, graph) -> float:
        """Disconnected-community fraction, computed lazily and cached.

        Lets tests and serving assert the paper's headline invariant
        (``check_connected(graph) == 0.0`` after any split mode) without
        paying for full quality metrics on every fit
        (``compute_metrics=True`` also reports modularity).  ``graph``
        must be the graph this result was fitted on — the result itself
        only holds labels.

        The cache keys on the graph's structural fingerprint: repeated
        calls with the same graph (invariant suites, ``quality="full"``
        telemetry, serving health checks) pay the device pass once, and
        a call with a *different* graph recomputes instead of returning
        a stale fraction.
        """
        from repro.core.graph import graph_fingerprint
        fp = graph_fingerprint(graph)
        if self.disconnected_fraction is None or self._connected_fp != fp:
            import jax.numpy as jnp

            from repro.core.detect import disconnected_fraction
            self.disconnected_fraction = float(
                disconnected_fraction(graph, jnp.asarray(self.labels)))
            self._connected_fp = fp
        return self.disconnected_fraction

    @property
    def lpa_seconds(self) -> float:
        # Solo fits measure "propagation" directly; batched members carry
        # an explicitly-labeled work-share estimate instead.
        return (self.timings.get("propagation", 0.0)
                + self.timings.get("prorated_propagation", 0.0))

    @property
    def split_seconds(self) -> float:
        return (self.timings.get("split", 0.0)
                + self.timings.get("prorated_split", 0.0)
                + self.timings.get("compact", 0.0))

    @property
    def total_seconds(self) -> float:
        return sum(self.timings.values())
