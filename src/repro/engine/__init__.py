"""Unified GSL-LPA engine: pluggable backends behind one ``fit`` call.

Public surface:

  * :class:`Engine` / :class:`EngineConfig` / :class:`DetectionResult`
  * ``register_backend`` / ``backend_names`` — strategy extension points
  * ``GLOBAL_CACHE`` / ``TRACE_LOG`` — compile-cache observability
"""
from repro.engine.cache import (  # noqa: F401
    GLOBAL_CACHE,
    TRACE_LOG,
    CompileCache,
    TraceLog,
)
from repro.engine.config import DetectionResult, EngineConfig  # noqa: F401
from repro.engine.engine import Engine  # noqa: F401
from repro.engine.registry import (  # noqa: F401
    backend_names,
    choose_backend,
    choose_backend_batch,
    get_backend,
    register_backend,
)
