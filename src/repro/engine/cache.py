"""Shape-bucketed compile cache + trace-count instrumentation.

The cache maps (backend, bucket, algorithm statics, placement statics) to
a prepared *plan* — the backend's jitted executables specialised to the
bucket shapes.  A traffic stream of same-bucket graphs pays tracing and
XLA compilation exactly once.

``TRACE_LOG`` is the observability hook the acceptance tests assert on:
backends call ``TRACE_LOG.record(tag)`` inside their traced function
bodies, which Python only executes on an actual (re)trace — cache hits,
both in this cache and in jax's own jit cache, leave the counters
untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
from collections import Counter
from typing import Any, Callable, Hashable

# Workload attribution for the trace auditor: the engine (and the ooc
# driver) set the current (backend, bucket) around each backend dispatch,
# so a TRACE_LOG.record fired from inside a traced body lands in the
# right per-workload-context bin.  A ContextVar keeps nested/threaded
# engines from clobbering each other.
_TRACE_CONTEXT: contextvars.ContextVar[tuple | None] = \
    contextvars.ContextVar("repro_trace_context", default=None)


def current_trace_context() -> tuple | None:
    return _TRACE_CONTEXT.get()


@contextlib.contextmanager
def trace_context(backend: str, bucket):
    """Attribute any traces fired in the body to ``(backend, bucket)``."""
    token = _TRACE_CONTEXT.set((backend, tuple(bucket)
                                if isinstance(bucket, (list, tuple))
                                else bucket))
    try:
        yield
    finally:
        _TRACE_CONTEXT.reset(token)


class TraceLog:
    """Counts jit traces per backend stage (e.g. ``"segment:propagate"``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: Counter[str] = Counter()
        # (tag, trace-context) -> count; context None for unattributed
        self.context_counts: Counter[tuple] = Counter()

    def record(self, tag: str) -> None:
        ctx = _TRACE_CONTEXT.get()
        with self._lock:
            self.counts[tag] += 1
            self.context_counts[(tag, ctx)] += 1

    def total(self, prefix: str = "") -> int:
        with self._lock:
            return sum(v for k, v in self.counts.items()
                       if k.startswith(prefix))

    def snapshot(self) -> dict[str, int]:
        with self._lock:
            return dict(self.counts)

    def context_snapshot(self) -> dict[tuple, int]:
        with self._lock:
            return dict(self.context_counts)

    def reset(self) -> None:
        with self._lock:
            self.counts.clear()
            self.context_counts.clear()


TRACE_LOG = TraceLog()


class CompileCache:
    """Keyed store of backend plans with hit/miss accounting."""

    def __init__(self):
        self._lock = threading.Lock()
        self._plans: dict[Hashable, Any] = {}
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], Any]) -> tuple[Any, bool]:
        """Returns (plan, was_hit).  Builders run outside the lock is not
        needed here — plan building is cheap (tracing happens lazily on
        the first call of each jitted function)."""
        with self._lock:
            if key in self._plans:
                self.hits += 1
                return self._plans[key], True
            self.misses += 1
        plan = builder()
        with self._lock:
            self._plans.setdefault(key, plan)
            return self._plans[key], False

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"plans": len(self._plans), "hits": self.hits,
                    "misses": self.misses}


# Default process-wide cache: every Engine without an explicit cache shares
# it, so e.g. the `gsl_lpa` wrapper and a user's Engine reuse executables.
GLOBAL_CACHE = CompileCache()
