"""Backend strategy registry + auto-selection policy.

A backend is a stateless strategy object with four hooks:

  * ``plan_key(config)``  — extra hashable statics (placement: mesh shape,
    device count) the compiled plan depends on beyond the algorithm knobs;
  * ``build(bucket, config)`` — construct the plan: jitted executables
    specialised to the bucket shapes (cached by the engine);
  * ``prepare(graph, bucket, config)`` — per-graph host-side prep (padding
    to the bucket, tile construction, device placement);
  * ``run(plan, inputs, n_real, init_labels, init_active)`` — execute,
    returning a :class:`BackendRun`.  ``init_labels`` seeds propagation
    (warm start); ``init_active`` seeds the unprocessed flags (a delta's
    affected frontier) — both optional, None means cold/full.

Backends that set ``supports_batch = True`` additionally implement the
batched trio — ``build_batch`` / ``prepare_batch`` / ``run_batch`` —
executing a whole :class:`repro.core.batch.GraphBatch` in one dispatch
and returning a :class:`BatchBackendRun` with per-graph iteration
counts.  ``run_batch`` takes optional packed (total_vertices,) warm
labels / active seeds (local coordinates; see ``GraphBatch.pack_labels``)
and must treat them bit-identically to per-member solo warm runs.
``Engine.fit_many`` falls back to sequential ``fit`` calls for backends
without the flag (e.g. ``sharded``).

Registration is open: third-party strategies can ``register_backend`` and
be selected by name through ``EngineConfig.backend``.
"""
from __future__ import annotations

from typing import NamedTuple, Protocol

import jax
import numpy as np

from repro.core.graph import Graph
from repro.engine.bucketing import (
    BatchBucketKey,
    BucketKey,
    max_degree,
    next_pow2,
)
from repro.engine.config import EngineConfig


class BackendRun(NamedTuple):
    """Raw backend output (labels still padded + uncompacted)."""
    labels: np.ndarray        # (bucket rows,) int32 — engine slices [:n_real]
    lpa_iterations: int
    split_iterations: int
    lpa_seconds: float
    split_seconds: float
    # ConvergenceProfile when the plan was built with profiling on
    # (EngineConfig.profile != "off"); None otherwise.
    profile: object | None = None


class BatchBackendRun(NamedTuple):
    """Raw batched-backend output (local labels, per-slot iterations)."""
    labels: np.ndarray            # (bucket rows,) int32 local labels
    lpa_iterations: np.ndarray    # (k_bucket + 1,) int32 per slot
    split_iterations: np.ndarray  # (k_bucket + 1,) int32 per slot
    lpa_seconds: float
    split_seconds: float
    # per-slot list of ConvergenceProfile under profiling; None otherwise.
    profile: list | None = None


class Backend(Protocol):
    name: str
    supports_batch: bool

    def plan_key(self, config: EngineConfig) -> tuple: ...

    def build(self, bucket: BucketKey, config: EngineConfig): ...

    def prepare(self, graph: Graph, bucket: BucketKey,
                config: EngineConfig): ...

    def run(self, plan, inputs, n_real: int,
            init_labels: np.ndarray | None,
            init_active: np.ndarray | None = None) -> BackendRun: ...

    def build_batch(self, bucket: BatchBucketKey, config: EngineConfig): ...

    def prepare_batch(self, batch, bucket: BatchBucketKey,
                      config: EngineConfig): ...

    def run_batch(self, plan, inputs,
                  init_labels: np.ndarray | None = None,
                  init_active: np.ndarray | None = None,
                  ) -> BatchBackendRun: ...


_BACKENDS: dict[str, Backend] = {}


def register_backend(name: str):
    def deco(cls):
        _BACKENDS[name] = cls()
        return cls
    return deco


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


# Auto-selection: the tile path materialises (rows, d_max) dense neighbor
# tiles — a win on TPU for degree-bounded graphs, a memory loss on skewed
# ones.  Thresholds are deliberately simple and documented in README.md.
_TILE_MAX_DEGREE = 1024
_TILE_MAX_CELLS = 1 << 24  # ~150 MB of tiles at 9 B/cell


def choose_backend(graph: Graph, config: EngineConfig) -> str:
    """Pick a backend from graph shape + device topology."""
    if jax.device_count() > 1 or config.mesh is not None:
        return "sharded"
    d = next_pow2(max(max_degree(graph), 1))
    if jax.default_backend() == "tpu" and d <= _TILE_MAX_DEGREE \
            and graph.n * d <= _TILE_MAX_CELLS:
        return "tile"
    return "segment"


def choose_backend_batch(graphs, config: EngineConfig) -> str:
    """Pick a backend for a batched dispatch (packed-shape thresholds).

    Same policy as :func:`choose_backend` but against the disjoint-union
    shapes: the tile path materialises (total rows, max-member-degree)
    tiles, so the cell budget applies to the packed totals.
    """
    if jax.device_count() > 1 or config.mesh is not None:
        return "sharded"
    d = next_pow2(max(max(max_degree(g) for g in graphs), 1))
    n_total = sum(g.n for g in graphs)
    if jax.default_backend() == "tpu" and d <= _TILE_MAX_DEGREE \
            and n_total * d <= _TILE_MAX_CELLS:
        return "tile"
    return "segment"
