"""Shape bucketing: pad graphs to canonical shapes so jit caches hit.

Every distinct (vertex-count, edge-count, max-degree) shape triple would
otherwise force a fresh trace+compile — fatal for a service ingesting a
stream of graphs.  Bucketing rounds each dimension up to the next power of
two (with configurable floors), pads the graph with isolated vertices and
masked edges to the bucket shape, and keys the engine's compile cache on
the bucket.  Padded vertices have no edges, so they can never adopt or
donate a label; the only semantic coupling is the convergence threshold,
which the backends compute from the *real* vertex count passed as a traced
scalar (see ``lpa_run``'s ``n_real``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, _LANE, _round_up


class BucketKey(NamedTuple):
    """Canonical padded shapes — the compile-cache key's shape component."""
    n: int   # vertex bucket (>= real n)
    m: int   # directed-edge bucket (>= real m_pad; multiple of 128)
    d: int   # max-degree bucket (multiple of 128; tile/sharded backends)


class BatchBucketKey(NamedTuple):
    """Batched-dispatch bucket: graph-count + packed-total shapes.

    Mixed traffic reuses compiled batch plans as long as the *totals*
    land in the same bucket — the per-graph composition rides along as
    traced data (sizes / graph_id / voffset arrays).
    """
    k: int   # graph-count bucket (>= real batch size)
    n: int   # total-vertex bucket (>= packed n)
    m: int   # total-edge bucket (>= packed m_pad; multiple of 128)
    d: int   # max-degree bucket across members (multiple of 128)


def next_pow2(x: int, floor: int = 1) -> int:
    return max(int(floor), 1 << max(int(x) - 1, 0).bit_length())


def max_degree(graph: Graph) -> int:
    deg = np.asarray(graph.row_ptr[1:]) - np.asarray(graph.row_ptr[:-1])
    return int(deg.max()) if len(deg) else 1


def bucket_for(graph: Graph, *, bucketing: str = "pow2",
               min_vertex_bucket: int = 256,
               min_edge_bucket: int = 2048) -> BucketKey:
    d_real = max(max_degree(graph), 1)
    if bucketing == "exact":
        return BucketKey(n=graph.n, m=graph.m_pad,
                         d=_round_up(d_real, _LANE))
    return BucketKey(
        n=next_pow2(graph.n, min_vertex_bucket),
        m=next_pow2(graph.m_pad, min_edge_bucket),
        d=_round_up(next_pow2(d_real), _LANE),
    )


def batch_bucket_for(batch, *, bucketing: str = "pow2",
                     min_vertex_bucket: int = 256,
                     min_edge_bucket: int = 2048) -> BatchBucketKey:
    """Bucket a :class:`repro.core.batch.GraphBatch`'s packed shapes."""
    g = batch.graph
    d_real = max(max_degree(g), 1)
    if bucketing == "exact":
        return BatchBucketKey(k=batch.num_graphs, n=g.n, m=g.m_pad,
                              d=_round_up(d_real, _LANE))
    return BatchBucketKey(
        k=next_pow2(batch.num_graphs),
        n=next_pow2(g.n, min_vertex_bucket),
        m=next_pow2(g.m_pad, min_edge_bucket),
        d=_round_up(next_pow2(d_real), _LANE),
    )


def batch_index_arrays(batch, k_bucket: int, n_rows: int,
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-slot / per-vertex index arrays for the batched kernels.

    Returns (sizes, graph_id, voffset):
      sizes    (k_bucket + 1,) int32 — real vertex count per slot; empty
               slots and the final padding slot carry 0, so they are
               converged from the first iteration.
      graph_id (n_rows,) int32 — owning slot per row; padding rows map to
               the extra slot ``k_bucket``.
      voffset  (n_rows,) int32 — owning slot's vertex-id offset (padding
               rows use the packed vertex count, keeping local ids
               well-defined).
    """
    k1 = k_bucket + 1
    nt = batch.total_vertices
    sizes = np.zeros(k1, np.int32)
    sizes[:batch.num_graphs] = batch.sizes
    graph_id = np.full(n_rows, k_bucket, np.int32)
    graph_id[:nt] = batch.graph_id
    voffset = np.full(n_rows, nt, np.int32)
    voffset[:nt] = batch.vertex_offsets()
    return sizes, graph_id, voffset


def pad_graph(graph: Graph, bucket: BucketKey) -> Graph:
    """Pad a graph up to its bucket shape (no-op when already there).

    Vertices ``graph.n .. bucket.n`` are isolated; edge slots up to
    ``bucket.m`` are masked out.  The padded graph's static metadata is a
    pure function of the bucket, so every graph in a bucket produces the
    same jit cache key.  ``num_edges`` is deliberately set to the bucket
    edge count — host-side helpers (``to_numpy_adj`` etc.) must be given
    the *original* graph, never a bucketed one.
    """
    if graph.n == bucket.n and graph.m_pad == bucket.m:
        return graph
    if graph.n > bucket.n or graph.m_pad > bucket.m:
        raise ValueError(f"graph (n={graph.n}, m_pad={graph.m_pad}) exceeds "
                         f"bucket {bucket}")
    extra_m = bucket.m - graph.m_pad
    extra_n = bucket.n - graph.n

    def pad1(a, amount, value=0):
        return jnp.pad(a, (0, amount), constant_values=value)

    row_ptr = jnp.concatenate([
        graph.row_ptr,
        jnp.full((extra_n,), graph.row_ptr[-1], dtype=graph.row_ptr.dtype),
    ]) if extra_n else graph.row_ptr
    return Graph(
        n=bucket.n, m_pad=bucket.m, num_edges=bucket.m,
        row_ptr=row_ptr,
        src=pad1(graph.src, extra_m),
        dst=pad1(graph.dst, extra_m),
        wgt=pad1(graph.wgt, extra_m),
        edge_mask=pad1(graph.edge_mask, extra_m),
        kdeg=pad1(graph.kdeg, extra_n),
    )


def pad_active(active: np.ndarray | None, n_real: int,
               n_bucket: int) -> np.ndarray:
    """Pad an (n_real,) unprocessed-seed mask to the bucket.

    ``None`` (a full detection) seeds every row active — bit-identical
    to the pre-init_active behaviour, including the padded rows, which
    are edgeless and therefore inert either way.  An explicit mask (a
    delta's affected frontier) seeds padded rows asleep.
    """
    if active is None:
        return np.ones(n_bucket, dtype=bool)
    active = np.asarray(active, dtype=bool).reshape(-1)
    if len(active) != n_real:
        raise ValueError(f"init_active has {len(active)} entries for a "
                         f"graph with {n_real} vertices")
    if n_bucket == n_real:
        return active
    return np.concatenate([active, np.zeros(n_bucket - n_real, dtype=bool)])


def pad_labels(labels: np.ndarray, n_real: int, n_bucket: int) -> np.ndarray:
    """Pad an (n_real,) init-label vector to the bucket: padded vertices
    keep their own ids (singleton communities, the LPA invariant)."""
    labels = np.asarray(labels, dtype=np.int32).reshape(-1)
    if len(labels) != n_real:
        raise ValueError(f"init_labels has {len(labels)} entries for a "
                         f"graph with {n_real} vertices")
    if np.any(labels < 0) or np.any(labels >= n_real):
        raise ValueError("init_labels must be vertex-id-valued in [0, n)")
    if n_bucket == n_real:
        return labels
    return np.concatenate(
        [labels, np.arange(n_real, n_bucket, dtype=np.int32)])
