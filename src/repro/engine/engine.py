"""The unified Engine: one entry point for every GSL-LPA execution path.

    from repro.engine import Engine, EngineConfig

    eng = Engine(EngineConfig(backend="auto"))
    result = eng.fit(graph)                 # DetectionResult
    result = eng.fit(graph2)                # same bucket -> no recompile
    result = eng.fit(graph2, init_labels=result.labels)   # warm start
    results = eng.fit_many([g1, g2, g3])    # one batched dispatch
    results = eng.fit_many(posts, init_labels=prev_labels,
                           init_active=frontiers)   # batched warm re-detect

``fit`` is backend-agnostic: it buckets the graph, fetches (or builds) the
compiled plan from the shape-bucketed cache, runs the backend, applies the
host split when requested, compacts labels, and optionally attaches
quality metrics — returning the same :class:`DetectionResult` regardless
of execution strategy.

Warm starts: ``init_labels`` seeds propagation with an existing
assignment; ``init_active`` seeds the unprocessed flags (GVE-LPA pruning
rule — pass a delta's affected frontier so only changed neighborhoods
get re-processed).  With ``warm_start="auto"`` the engine keeps a
bounded LRU cache of ``graph_fingerprint -> last labels`` updated on
every fit (solo or batched member), so re-fitting a structurally
identical graph warm-starts automatically.  Batched warm re-detection is
bit-identical to solo warm ``fit`` on each member (pinned in
tests/test_stream.py).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

import repro.engine.backends  # noqa: F401  (registers built-in strategies)
from repro.core.batch import GraphBatch
from repro.core.graph import Graph, graph_fingerprint
from repro.core.split import split_bfs_host
from repro.engine.bucketing import batch_bucket_for, bucket_for
from repro.engine.cache import GLOBAL_CACHE, CompileCache, trace_context
from repro.engine.config import DetectionResult, EngineConfig
from repro.engine.registry import (
    choose_backend,
    choose_backend_batch,
    get_backend,
)
from repro.obs import REGISTRY, span


def _as_graph(graph) -> Graph:
    """Accept a Graph or a path to a graph file (mtx / SNAP edge list).

    Paths go through :func:`repro.io.load_graph` — first fit of a file
    parses + caches the CSR on disk, later fits (any process) mmap it
    back.  Imported lazily: the io layer is optional on the hot path.
    """
    if isinstance(graph, Graph):
        return graph
    if isinstance(graph, str) or hasattr(graph, "__fspath__"):
        from repro.io import load_graph
        return load_graph(graph)
    raise TypeError(f"fit expects a Graph or a graph-file path, got "
                    f"{type(graph).__name__}")


def _compact_host(labels: np.ndarray) -> tuple[np.ndarray, int]:
    """Dense [0, K) relabeling, host-side (same rank order as
    ``split.compact_labels``, but shape-polymorphic for free)."""
    uniq, inv = np.unique(np.asarray(labels), return_inverse=True)
    return inv.astype(np.int32), len(uniq)


def _check_init_labels(labels, n: int, name: str) -> np.ndarray:
    """Validate warm-start labels: (n,) vertex-id-valued.  The usual way
    to trip this is feeding *stale* labels from a pre-delta graph whose
    vertex count has since changed — reject loudly, never truncate."""
    labels = np.asarray(labels)
    if labels.shape != (n,):
        raise ValueError(
            f"{name} has shape {labels.shape} for a graph with {n} "
            f"vertices — stale warm-start labels from a different graph? "
            f"Re-detect cold or extend the labels to the new vertex set.")
    labels = labels.astype(np.int32)
    if n and (labels.min() < 0 or labels.max() >= n):
        raise ValueError(f"{name} must be vertex-id-valued in [0, {n})")
    return labels


def _check_init_active(active, n: int, name: str) -> np.ndarray:
    active = np.asarray(active).astype(bool)
    if active.shape != (n,):
        raise ValueError(f"{name} has shape {active.shape} for a graph "
                         f"with {n} vertices")
    return active


class _WarmCache:
    """Bounded LRU of ``graph_fingerprint -> last compacted labels``.

    Per-session state for ``warm_start="auto"``: every fit stores its
    result labels under the graph's structural fingerprint, and a later
    fit of a structurally identical graph starts from them.  The bound
    keeps a long streaming session from accumulating one labels array
    per graph ever served (tests pin the no-unbounded-growth property).

    Thread-safe: one Engine is shared by every session of the serving
    tier, so ``get``/``put`` race from the micro-batcher worker, client
    threads calling ``fit`` directly, and ``stats()`` pollers.  An
    ``OrderedDict`` mutated by ``move_to_end``/``popitem`` corrupts
    under that interleaving (the compile caches in ``engine/cache.py``
    always took a lock; this cache historically did not), so every
    access holds the lock.
    """

    def __init__(self, max_entries: int, scope=None):
        self.max_entries = int(max_entries)
        self._entries: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._lock = threading.Lock()
        # Registry handles (metrics write-through; ``stats()`` views stay
        # computed from the authoritative OrderedDict, not read back).
        self._m_hits = scope.counter("warm_hits") if scope else None
        self._m_misses = scope.counter("warm_misses") if scope else None
        self._m_evict = scope.counter("warm_evictions") if scope else None
        self._m_entries = scope.gauge("warm_entries") if scope else None

    def get(self, fp: tuple) -> np.ndarray | None:
        with self._lock:
            labels = self._entries.get(fp)
            if labels is not None:
                self._entries.move_to_end(fp)
        if self._m_hits is not None:
            (self._m_hits if labels is not None else self._m_misses).inc()
        return labels

    def put(self, fp: tuple, labels: np.ndarray) -> None:
        evicted = 0
        with self._lock:
            self._entries[fp] = labels
            self._entries.move_to_end(fp)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                evicted += 1
            count = len(self._entries)
        if self._m_entries is not None:
            self._m_entries.set(count)
            if evicted:
                self._m_evict.inc(evicted)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class Engine:
    """Pluggable-backend GSL-LPA engine with a shape-bucketed jit cache.

    ``cache=None`` shares the process-wide :data:`GLOBAL_CACHE`, so
    independent Engine instances (and the legacy ``gsl_lpa`` wrapper)
    reuse each other's compiled plans.  The warm-start cache, by
    contrast, is per-engine session state.
    """

    def __init__(self, config: EngineConfig | None = None,
                 cache: CompileCache | None = None):
        self.config = config if config is not None else EngineConfig()
        self.cache = cache if cache is not None else GLOBAL_CACHE
        self._obs = REGISTRY.scope("engine")
        self._warm = _WarmCache(self.config.warm_cache_size,
                                scope=self._obs)
        self._m_fits = self._obs.counter("fits")
        self._m_batch_fits = self._obs.counter("batch_fits")
        # Quality telemetry scope ("engine.quality.*") — claimed eagerly
        # so concurrent fits never race a lazy scope() call.
        self._q_obs = self._obs.scope("quality") \
            if self.config.quality != "off" else None

    # --- warm-start resolution ---

    def _auto_fp(self, graph: Graph) -> tuple | None:
        return graph_fingerprint(graph) \
            if self.config.warm_start == "auto" else None

    def _resolve_warm(self, n: int, init_labels, init_active,
                      fp: tuple | None, name: str):
        """Explicit init labels win; else consult the warm cache.

        A frontier seed only means anything *relative to* a previous
        assignment — restricting a cold singleton start to the frontier
        would freeze every other vertex at its own label and return
        garbage.  So when no warm labels resolve (explicit None plus a
        cache miss, e.g. after LRU eviction), ``init_active`` is dropped
        and the fit degrades to a full cold detection.
        """
        warm_started = init_labels is not None
        if init_labels is None and fp is not None:
            init_labels = self._warm.get(fp)
            warm_started = init_labels is not None
        if init_active is not None:  # validate even when about to drop it
            init_active = _check_init_active(init_active, n,
                                             name.replace("labels", "active"))
        if init_labels is not None:
            init_labels = _check_init_labels(init_labels, n, name)
        else:
            init_active = None
        return init_labels, init_active, warm_started

    # --- solo fit ---

    def fit(self, graph, init_labels=None, init_active=None, *,
            backend: str | None = None,
            memory_budget: int | str | None = None) -> DetectionResult:
        """Detect communities; returns a unified :class:`DetectionResult`.

        ``graph`` may be a :class:`Graph` or a path to a graph file
        (``.mtx`` / SNAP edge list): paths route through
        :func:`repro.io.load_graph`, so the parse is paid once per file
        content and later fits mmap the cached CSR.

        ``memory_budget`` (bytes, or ``"64MB"``-style; defaults to
        ``config.memory_budget``) auto-routes the fit: in-core when the
        graph's edge arrays fit the budget, otherwise out-of-core —
        partitioned CSR slices swept one-resident-at-a-time with
        halo-label exchange (:mod:`repro.partition`), labels
        bit-identical to the in-core path.  For paths the routing
        decision reads only the store entry's metadata, so a
        bigger-than-budget file is never materialized.

        ``init_labels``: optional (n,) vertex-id-valued initial assignment
        (warm start / incremental re-detection).  ``init_active``:
        optional (n,) unprocessed-seed mask — pass the delta's affected
        frontier (``repro.core.delta.affected_frontier``) so propagation
        is restricted to changed neighborhoods; honored only alongside
        warm labels (see ``_resolve_warm``).  ``backend`` overrides the
        configured strategy for this call only.
        """
        budget = memory_budget if memory_budget is not None \
            else self.config.memory_budget
        if budget is not None:
            from repro.partition.ooc import (
                IN_CORE_EDGE_BYTES,
                in_core_edge_bytes,
                open_source,
            )
            from repro.partition.plan import parse_bytes
            budget = parse_bytes(budget)
            if isinstance(graph, Graph):
                # metadata-only routing check; build no source unless
                # the partitioned path is actually taken
                too_big = graph.m_pad * IN_CORE_EDGE_BYTES > budget
                source = open_source(graph) if too_big else None
            else:
                source = open_source(graph)  # store-metadata handle
                too_big = in_core_edge_bytes(source) > budget
            if too_big:
                return self._fit_ooc(source, budget, init_labels,
                                     init_active, backend)
            if source is not None:
                # fits in core: materialize from the handle we already
                # opened — no second content hash / store open
                graph = source.to_graph()
        graph = _as_graph(graph)
        fp = self._auto_fp(graph)
        init_labels, init_active, warm_started = self._resolve_warm(
            graph.n, init_labels, init_active, fp, "init_labels")
        result = self._fit_resolved(graph, init_labels, init_active,
                                    backend, warm_started)
        if fp is not None:
            self._warm.put(fp, result.labels)
        return result

    def _fit_ooc(self, source, budget: int, init_labels, init_active,
                 backend: str | None) -> DetectionResult:
        """Out-of-core partitioned fit over an array source."""
        from repro.partition.ooc import fit_out_of_core
        cfg = self.config
        if cfg.compute_metrics:
            raise ValueError(
                "compute_metrics needs the full graph on device; compute "
                "quality metrics separately after an out-of-core fit")
        fp = tuple(source.fingerprint()) \
            if cfg.warm_start == "auto" and source.fingerprint() else None
        init_labels, init_active, warm_started = self._resolve_warm(
            source.n, init_labels, init_active, fp, "init_labels")

        with span("engine.fit_ooc", n=source.n):
            run = fit_out_of_core(source, cfg, memory_budget=budget,
                                  backend=backend, cache=self.cache,
                                  init_labels=init_labels,
                                  init_active=init_active)
            t0 = time.perf_counter()
            with span("engine.compact"):
                labels, k = _compact_host(run.labels)
            t_compact = time.perf_counter() - t0

        self._m_fits.inc()
        result = DetectionResult(
            labels=labels, num_communities=k, backend=run.backend,
            lpa_iterations=run.lpa_iterations,
            split_iterations=run.split_iterations,
            timings={"prepare": run.plan_seconds,
                     "propagation": run.lpa_seconds,
                     "split": run.split_seconds, "compact": t_compact},
            bucket=(source.n, source.num_edges), cache_hit=run.cache_hit,
            warm_started=warm_started,
            partitions=run.num_partitions, ooc=run.stats(),
            profile=getattr(run, "profile", None),
        )
        if cfg.quality != "off":
            # Host-only report: the full graph never sits on the device
            # out-of-core, so modularity and the disconnected fraction
            # stay None here; sizes / count / churn still flow.
            self._attach_quality(result, None, init_labels)
        if fp is not None:
            self._warm.put(fp, result.labels)
        return result

    def _fit_resolved(self, graph: Graph, init_labels, init_active,
                      backend: str | None, warm_started: bool,
                      ) -> DetectionResult:
        """One detection with warm state already resolved + validated
        (no auto-cache lookups or updates — callers own those)."""
        cfg = self.config
        name = backend or cfg.backend
        if name == "auto":
            name = choose_backend(graph, cfg)
        be = get_backend(name)

        bucket = bucket_for(graph, bucketing=cfg.bucketing,
                            min_vertex_bucket=cfg.min_vertex_bucket,
                            min_edge_bucket=cfg.min_edge_bucket)
        key = (name, bucket, cfg.bucketing, cfg.algo_key(), be.plan_key(cfg))
        with span("engine.fit", backend=name, n=graph.n):
            plan, cache_hit = self.cache.get_or_build(
                key, lambda: be.build(bucket, cfg))

            t0 = time.perf_counter()
            with span("engine.prepare"):
                inputs = be.prepare(graph, bucket, cfg)
            t_prep = time.perf_counter() - t0

            with trace_context(name, bucket), span("engine.dispatch"):
                run = be.run(plan, inputs, graph.n, init_labels,
                             init_active)
            labels = np.asarray(run.labels)[: graph.n]

            t0 = time.perf_counter()
            split_seconds = run.split_seconds
            if cfg.split == "bfs_host":
                with span("engine.split_host"):
                    labels = split_bfs_host(graph, labels)
                split_seconds += time.perf_counter() - t0

            t0 = time.perf_counter()
            with span("engine.compact"):
                labels, k = _compact_host(labels)
            t_compact = time.perf_counter() - t0

        self._m_fits.inc()
        result = DetectionResult(
            labels=labels, num_communities=k, backend=name,
            lpa_iterations=run.lpa_iterations,
            split_iterations=run.split_iterations,
            timings={"prepare": t_prep, "propagation": run.lpa_seconds,
                     "split": split_seconds, "compact": t_compact},
            bucket=tuple(bucket), cache_hit=cache_hit,
            warm_started=warm_started,
            profile=run.profile,
        )
        if cfg.compute_metrics:
            self._attach_metrics(result, graph)
        if cfg.quality != "off":
            self._attach_quality(result, graph, init_labels)
        return result

    def _attach_metrics(self, result: DetectionResult, graph: Graph) -> None:
        from repro.core.modularity import modularity
        result.modularity = float(
            modularity(graph, jnp.asarray(result.labels)))
        result.check_connected(graph)

    def _attach_quality(self, result: DetectionResult, graph,
                        prev_labels) -> None:
        """Post-fit quality telemetry (``EngineConfig.quality != "off"``).

        Runs strictly *after* convergence, on the final labels at a host
        stage boundary — it can never perturb the sweep loop, which is
        why ``quality`` stays out of ``algo_key()`` and labels/iteration
        counts are bit-identical across modes.  ``prev_labels`` is the
        resolved warm-start assignment (the previous fit of this
        fingerprint/tenant in steady state) — the churn baseline.
        ``graph=None`` produces the host-only report of the out-of-core
        path.

        Cost tiering: "basic" is host-only (bincount sizes + churn —
        negligible next to a fit, the <=5% CI gate measures it); only
        "full" pays the per-fit device passes (modularity ~ one extra
        sweep, connectivity via the fingerprint-cached
        ``check_connected``).
        """
        cfg = self.config
        from repro.obs.quality import compute_quality, record_report
        with span("engine.quality", mode=cfg.quality):
            full = cfg.quality == "full"
            if full and graph is not None:
                result.check_connected(graph)  # fingerprint-cached pass
            result.quality = compute_quality(
                result.labels, mode=cfg.quality,
                graph=graph if full else None,
                prev_labels=prev_labels,
                num_communities=result.num_communities,
                modularity=result.modularity,
                disconnected_fraction=result.disconnected_fraction)
            if result.modularity is None:
                result.modularity = result.quality.modularity
            record_report(self._q_obs, result.quality)

    # --- batched fit ---

    def fit_many(self, graphs, *, init_labels=None, init_active=None,
                 backend: str | None = None) -> list[DetectionResult]:
        """Detect communities for k graphs in one batched device dispatch.

        The graphs are packed into a disjoint-union super-graph
        (:class:`repro.core.batch.GraphBatch`) and executed by the
        backend's batched plan, cached per *batch bucket* — a
        (graph-count, total-vertex, total-edge, max-degree) shape key —
        so mixed traffic reuses compiled plans.  Per-graph results are
        bit-identical to ``fit`` on each graph alone, cold or warm (the
        parity suites in tests/test_batch.py and tests/test_stream.py
        pin this for ``segment`` and ``tile`` across every split mode).
        Backends without ``supports_batch`` (the ``sharded`` strategy)
        fall back to sequential ``fit`` calls with identical warm-start
        semantics.

        ``init_labels`` / ``init_active``: optional length-k sequences of
        per-member warm-start labels and unprocessed-seed masks (None
        entries for cold members) — the streaming re-detection path:
        apply each member's delta, then pass the previous labels and the
        delta's affected frontier.  With ``warm_start="auto"``, members
        without explicit labels consult the warm cache; lookups snapshot
        the cache *before* the dispatch, so members never warm-start off
        each other within one batch, and every member's result is stored
        back afterwards.

        Batch-level timings (prepare/propagation/split) are attributed
        pro rata by each graph's share of packed work (vertices + edges);
        compaction and the host BFS split are timed per graph.
        """
        graphs = [_as_graph(g) for g in graphs]
        if not graphs:
            return []
        cfg = self.config
        k = len(graphs)
        init_labels = self._per_member(init_labels, k, "init_labels")
        init_active = self._per_member(init_active, k, "init_active")

        fps = [self._auto_fp(g) for g in graphs]
        resolved = [
            self._resolve_warm(g.n, init_labels[i], init_active[i], fps[i],
                               f"init_labels[{i}]")
            for i, g in enumerate(graphs)
        ]
        labels_r = [r[0] for r in resolved]
        active_r = [r[1] for r in resolved]
        warm_r = [r[2] for r in resolved]

        name = backend or cfg.backend
        if name == "auto":
            name = choose_backend_batch(graphs, cfg)
        be = get_backend(name)
        if not getattr(be, "supports_batch", False):
            # Sequential fallback keeps batched semantics: warm state was
            # resolved against the pre-dispatch cache snapshot above, so
            # members never warm off each other mid-batch.
            results = [self._fit_resolved(g, labels_r[i], active_r[i],
                                          name, warm_r[i])
                       for i, g in enumerate(graphs)]
        else:
            results = self._fit_many_packed(graphs, labels_r, active_r,
                                            warm_r, name, be)
        for fp, res in zip(fps, results):
            if fp is not None:
                self._warm.put(fp, res.labels)
        return results

    @staticmethod
    def _per_member(seq, k: int, name: str) -> list:
        if seq is None:
            return [None] * k
        seq = list(seq)
        if len(seq) != k:
            raise ValueError(f"{name} has {len(seq)} entries for a batch "
                             f"of {k} graphs")
        return seq

    def _fit_many_packed(self, graphs, labels_r, active_r, warm_r,
                         name: str, be) -> list[DetectionResult]:
        cfg = self.config
        with span("engine.fit_many", backend=name, k=len(graphs)):
            t0 = time.perf_counter()
            with span("engine.prepare"):
                batch = GraphBatch.pack(graphs)
                bucket = batch_bucket_for(
                    batch, bucketing=cfg.bucketing,
                    min_vertex_bucket=cfg.min_vertex_bucket,
                    min_edge_bucket=cfg.min_edge_bucket)
                key = (name, "batch", bucket, cfg.bucketing, cfg.algo_key(),
                       be.plan_key(cfg))
                plan, cache_hit = self.cache.get_or_build(
                    key, lambda: be.build_batch(bucket, cfg))
                inputs = be.prepare_batch(batch, bucket, cfg)
                # Per-member labels are local-coordinate by construction
                # (a solo graph's vertex ids are its local ids), so
                # packing is a plain offset-sliced concatenation.
                labels0 = batch.pack_labels(labels_r)
                active0 = batch.pack_active(active_r)
            t_prep = time.perf_counter() - t0

            with trace_context(name, ("batch", *bucket)), \
                    span("engine.dispatch"):
                run = be.run_batch(plan, inputs, labels0, active0)
            labels_all = np.asarray(run.labels)

            # The one device dispatch serves every member, so per-member
            # stage seconds are not measurable; the real batch-level stage
            # timings live on the spans above, and each member carries an
            # explicitly-labeled work-share estimate ("prorated_*" —
            # vertices + edges pro rata), never dressed up as a
            # measurement.  Host split/compact run per member and stay
            # real timings.
            work = np.asarray(batch.sizes + batch.edge_counts,
                              dtype=np.float64)
            weights = work / work.sum() if work.sum() > 0 \
                else np.full(len(graphs), 1.0 / len(graphs))

            results = []
            for i, graph in enumerate(graphs):
                lo, hi = int(batch.offsets[i]), int(batch.offsets[i + 1])
                labels = labels_all[lo:hi]
                w = float(weights[i])

                t0 = time.perf_counter()
                split_host = 0.0
                if cfg.split == "bfs_host":
                    labels = split_bfs_host(graph, labels)
                    split_host = time.perf_counter() - t0

                t0 = time.perf_counter()
                labels, k = _compact_host(labels)
                t_compact = time.perf_counter() - t0

                result = DetectionResult(
                    labels=labels, num_communities=k, backend=name,
                    lpa_iterations=int(run.lpa_iterations[i]),
                    split_iterations=int(run.split_iterations[i]),
                    timings={"prorated_prepare": t_prep * w,
                             "prorated_propagation": run.lpa_seconds * w,
                             "prorated_split": run.split_seconds * w,
                             "split": split_host, "compact": t_compact},
                    bucket=tuple(bucket), cache_hit=cache_hit,
                    warm_started=warm_r[i],
                    batch_size=len(graphs), batch_index=i,
                    profile=run.profile[i] if run.profile else None,
                )
                if cfg.compute_metrics:
                    self._attach_metrics(result, graph)
                if cfg.quality != "off":
                    self._attach_quality(result, graph, labels_r[i])
                results.append(result)
        self._m_batch_fits.inc()
        self._m_fits.inc(len(graphs))
        return results

    def stats(self) -> dict:
        """Cache + trace observability (for serving dashboards / tests)."""
        from repro.engine.cache import TRACE_LOG
        return {**self.cache.stats(), "traces": TRACE_LOG.snapshot(),
                "warm_entries": len(self._warm),
                "warm_capacity": self._warm.max_entries}
