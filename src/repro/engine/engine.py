"""The unified Engine: one entry point for every GSL-LPA execution path.

    from repro.engine import Engine, EngineConfig

    eng = Engine(EngineConfig(backend="auto"))
    result = eng.fit(graph)                 # DetectionResult
    result = eng.fit(graph2)                # same bucket -> no recompile
    result = eng.fit(graph2, init_labels=result.labels)   # warm start
    results = eng.fit_many([g1, g2, g3])    # one batched dispatch

``fit`` is backend-agnostic: it buckets the graph, fetches (or builds) the
compiled plan from the shape-bucketed cache, runs the backend, applies the
host split when requested, compacts labels, and optionally attaches
quality metrics — returning the same :class:`DetectionResult` regardless
of execution strategy.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

import repro.engine.backends  # noqa: F401  (registers built-in strategies)
from repro.core.batch import GraphBatch
from repro.core.graph import Graph, graph_fingerprint
from repro.core.split import split_bfs_host
from repro.engine.bucketing import batch_bucket_for, bucket_for
from repro.engine.cache import GLOBAL_CACHE, CompileCache
from repro.engine.config import DetectionResult, EngineConfig
from repro.engine.registry import (
    choose_backend,
    choose_backend_batch,
    get_backend,
)


def _compact_host(labels: np.ndarray) -> tuple[np.ndarray, int]:
    """Dense [0, K) relabeling, host-side (same rank order as
    ``split.compact_labels``, but shape-polymorphic for free)."""
    uniq, inv = np.unique(np.asarray(labels), return_inverse=True)
    return inv.astype(np.int32), len(uniq)


class Engine:
    """Pluggable-backend GSL-LPA engine with a shape-bucketed jit cache.

    ``cache=None`` shares the process-wide :data:`GLOBAL_CACHE`, so
    independent Engine instances (and the legacy ``gsl_lpa`` wrapper)
    reuse each other's compiled plans.
    """

    def __init__(self, config: EngineConfig | None = None,
                 cache: CompileCache | None = None):
        self.config = config if config is not None else EngineConfig()
        self.cache = cache if cache is not None else GLOBAL_CACHE
        self._last: tuple[tuple, np.ndarray] | None = None  # (fingerprint, labels)

    def fit(self, graph: Graph, init_labels=None, *,
            backend: str | None = None) -> DetectionResult:
        """Detect communities; returns a unified :class:`DetectionResult`.

        ``init_labels``: optional (n,) vertex-id-valued initial assignment
        (warm start / incremental re-detection).  ``backend`` overrides the
        configured strategy for this call only.
        """
        cfg = self.config
        name = backend or cfg.backend
        if name == "auto":
            name = choose_backend(graph, cfg)
        be = get_backend(name)

        bucket = bucket_for(graph, bucketing=cfg.bucketing,
                            min_vertex_bucket=cfg.min_vertex_bucket,
                            min_edge_bucket=cfg.min_edge_bucket)
        key = (name, bucket, cfg.bucketing, cfg.algo_key(), be.plan_key(cfg))
        plan, cache_hit = self.cache.get_or_build(
            key, lambda: be.build(bucket, cfg))

        warm_started = init_labels is not None
        fp = graph_fingerprint(graph) if cfg.warm_start == "auto" else None
        if init_labels is None and fp is not None \
                and self._last is not None and self._last[0] == fp:
            init_labels = self._last[1]
            warm_started = True
        if init_labels is not None:
            init_labels = np.asarray(init_labels, dtype=np.int32)

        t0 = time.perf_counter()
        inputs = be.prepare(graph, bucket, cfg)
        t_prep = time.perf_counter() - t0

        run = be.run(plan, inputs, graph.n, init_labels)
        labels = np.asarray(run.labels)[: graph.n]

        t0 = time.perf_counter()
        split_seconds = run.split_seconds
        if cfg.split == "bfs_host":
            labels = split_bfs_host(graph, labels)
            split_seconds += time.perf_counter() - t0

        t0 = time.perf_counter()
        labels, k = _compact_host(labels)
        t_compact = time.perf_counter() - t0

        result = DetectionResult(
            labels=labels, num_communities=k, backend=name,
            lpa_iterations=run.lpa_iterations,
            split_iterations=run.split_iterations,
            timings={"prepare": t_prep, "propagation": run.lpa_seconds,
                     "split": split_seconds, "compact": t_compact},
            bucket=tuple(bucket), cache_hit=cache_hit,
            warm_started=warm_started,
        )
        if cfg.compute_metrics:
            from repro.core.detect import disconnected_fraction
            from repro.core.modularity import modularity
            lab = jnp.asarray(labels)
            result.modularity = float(modularity(graph, lab))
            result.disconnected_fraction = float(
                disconnected_fraction(graph, lab))
        if fp is not None:
            self._last = (fp, labels)
        return result

    def fit_many(self, graphs, *, backend: str | None = None,
                 ) -> list[DetectionResult]:
        """Detect communities for k graphs in one batched device dispatch.

        The graphs are packed into a disjoint-union super-graph
        (:class:`repro.core.batch.GraphBatch`) and executed by the
        backend's batched plan, cached per *batch bucket* — a
        (graph-count, total-vertex, total-edge, max-degree) shape key —
        so mixed traffic reuses compiled plans.  Per-graph results are
        bit-identical to ``fit`` on each graph alone (the parity suite in
        tests/test_batch.py pins this for ``segment`` and ``tile`` across
        every split mode).  Backends without ``supports_batch`` (the
        ``sharded`` strategy) fall back to sequential ``fit`` calls.

        Batch-level timings (prepare/propagation/split) are attributed
        pro rata by each graph's share of packed work (vertices + edges);
        compaction and the host BFS split are timed per graph.  Warm
        starts do not apply to batched dispatch.
        """
        graphs = list(graphs)
        if not graphs:
            return []
        cfg = self.config
        name = backend or cfg.backend
        if name == "auto":
            name = choose_backend_batch(graphs, cfg)
        be = get_backend(name)
        if not getattr(be, "supports_batch", False):
            # Sequential fallback keeps batched semantics: no warm starts
            # between batch members (suppress the auto-keying state, then
            # restore it so interleaved fit() callers are unaffected).
            saved = self._last
            try:
                results = []
                for g in graphs:
                    self._last = None
                    results.append(self.fit(g, backend=name))
            finally:
                self._last = saved
            return results

        t0 = time.perf_counter()
        batch = GraphBatch.pack(graphs)
        bucket = batch_bucket_for(batch, bucketing=cfg.bucketing,
                                  min_vertex_bucket=cfg.min_vertex_bucket,
                                  min_edge_bucket=cfg.min_edge_bucket)
        key = (name, "batch", bucket, cfg.bucketing, cfg.algo_key(),
               be.plan_key(cfg))
        plan, cache_hit = self.cache.get_or_build(
            key, lambda: be.build_batch(bucket, cfg))
        inputs = be.prepare_batch(batch, bucket, cfg)
        t_prep = time.perf_counter() - t0

        run = be.run_batch(plan, inputs)
        labels_all = np.asarray(run.labels)

        work = np.asarray(batch.sizes + batch.edge_counts, dtype=np.float64)
        weights = work / work.sum() if work.sum() > 0 \
            else np.full(len(graphs), 1.0 / len(graphs))

        results = []
        for i, graph in enumerate(graphs):
            lo, hi = int(batch.offsets[i]), int(batch.offsets[i + 1])
            labels = labels_all[lo:hi]
            w = float(weights[i])

            t0 = time.perf_counter()
            split_seconds = run.split_seconds * w
            if cfg.split == "bfs_host":
                labels = split_bfs_host(graph, labels)
                split_seconds += time.perf_counter() - t0

            t0 = time.perf_counter()
            labels, k = _compact_host(labels)
            t_compact = time.perf_counter() - t0

            result = DetectionResult(
                labels=labels, num_communities=k, backend=name,
                lpa_iterations=int(run.lpa_iterations[i]),
                split_iterations=int(run.split_iterations[i]),
                timings={"prepare": t_prep * w,
                         "propagation": run.lpa_seconds * w,
                         "split": split_seconds, "compact": t_compact},
                bucket=tuple(bucket), cache_hit=cache_hit,
                warm_started=False,
                batch_size=len(graphs), batch_index=i,
            )
            if cfg.compute_metrics:
                from repro.core.detect import disconnected_fraction
                from repro.core.modularity import modularity
                lab = jnp.asarray(labels)
                result.modularity = float(modularity(graph, lab))
                result.disconnected_fraction = float(
                    disconnected_fraction(graph, lab))
            results.append(result)
        return results

    def stats(self) -> dict:
        """Cache + trace observability (for serving dashboards / tests)."""
        from repro.engine.cache import TRACE_LOG
        return {**self.cache.stats(), "traces": TRACE_LOG.snapshot()}
