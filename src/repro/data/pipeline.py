"""Deterministic, checkpointable synthetic LM data pipeline.

Tokens are a pure function of (seed, host, step) so that (a) every host
draws disjoint shards without coordination, (b) restoring ``state()`` after
a restart replays the exact stream, and (c) elastic restarts with a
different host count stay deterministic (the stream is keyed by global
batch index, not host-local counters).

A light Zipf mixture over "topic" blocks gives the stream enough structure
for the GSL-LPA locality clustering (``repro.data.clustering``) to find
real communities in the doc-similarity graph.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_topics: int = 64
    host_index: int = 0
    host_count: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.host_batch = self.global_batch // self.host_count

    # ------------------------------------------------------------ state ----
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    # ------------------------------------------------------------- next ----
    def next_batch(self) -> dict:
        b, s = self.host_batch, self.seq_len
        tokens = np.zeros((b, s + 1), dtype=np.int32)
        for i in range(b):
            gidx = self.step * self.global_batch \
                + self.host_index * self.host_batch + i
            rng = np.random.default_rng((self.seed << 20) ^ gidx)
            topic = rng.integers(0, self.n_topics)
            # topic block: a contiguous slice of the vocab + shared commons
            lo = (self.vocab // self.n_topics) * topic
            hi = lo + max(self.vocab // self.n_topics, 16)
            topical = rng.integers(lo, min(hi, self.vocab), size=s + 1)
            common = rng.integers(0, min(1024, self.vocab), size=s + 1)
            pick = rng.random(s + 1) < 0.7
            tokens[i] = np.where(pick, topical, common)
        self.step += 1
        return {"tokens": tokens[:, :-1],
                "targets": tokens[:, 1:].astype(np.int32)}
