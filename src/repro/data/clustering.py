"""GSL-LPA applied to the data pipeline: locality-aware batch clustering.

Builds a document-similarity graph (shingle/vocab-block overlap) over a
corpus shard and runs the paper's algorithm to group related documents.
The no-internally-disconnected-communities guarantee matters here: a
disconnected 'community' would merge unrelated documents into one bucket
(DESIGN.md §4).  Used by ``examples/community_pipeline.py`` and the data
loader's optional ``cluster_batches`` mode.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_graph, gsl_lpa


def doc_similarity_graph(docs: np.ndarray, n_hash_buckets: int = 512,
                         min_shared: int = 2):
    """docs: (n_docs, seq) int tokens -> similarity Graph.

    Two documents are connected with weight = #shared vocab buckets
    (capped shingle overlap) when they share >= min_shared buckets.
    Buckets quantise the vocab range (NOT modulo — modulo would alias
    distinct vocab blocks onto the same buckets).
    """
    n = docs.shape[0]
    vmax = max(int(docs.max()) + 1, n_hash_buckets)
    sigs = [set((np.unique(d) * n_hash_buckets // vmax).tolist())
            for d in docs]
    edges, weights = [], []
    for i in range(n):
        for j in range(i + 1, n):
            shared = len(sigs[i] & sigs[j])
            denom = min(len(sigs[i]), len(sigs[j])) or 1
            if shared >= min_shared and shared / denom > 0.25:
                edges.append((i, j))
                weights.append(float(shared))
    if not edges:
        edges, weights = [(0, min(1, n - 1))], [1e-6]
    return build_graph(np.array(edges), np.array(weights), n=n)


def cluster_documents(docs: np.ndarray, **lpa_kw) -> np.ndarray:
    """Community label per document (GSL-LPA: guaranteed connected)."""
    g = doc_similarity_graph(docs)
    res = gsl_lpa(g, split=lpa_kw.pop("split", "lp"), **lpa_kw)
    return res.labels


def locality_batches(docs: np.ndarray, batch_size: int) -> list[np.ndarray]:
    """Greedy community-contiguous batch index lists."""
    labels = cluster_documents(docs)
    order = np.argsort(labels, kind="stable")
    return [order[i:i + batch_size]
            for i in range(0, len(order), batch_size)]
