from repro.data.pipeline import SyntheticLMDataset  # noqa: F401
