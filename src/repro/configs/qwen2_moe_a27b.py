"""Qwen1.5/2-MoE-A2.7B — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts are padded to 64 for TP divisibility (padded experts
masked to -inf in the router); the 4 shared experts are fused into one
always-on gated FFN of width 4 x 1408 = 5632.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
    vocab=151936, head_dim=128, qkv_bias=True,
    moe_experts=60, moe_experts_padded=64, moe_top_k=4, moe_ff=1408,
    moe_period=1, moe_offset=0, shared_expert_ff=5632,
)
