"""Qwen1.5-32B — dense, QKV bias [hf:Qwen/Qwen1.5-32B].

Note: 40 heads are not divisible by TP=16; GSPMD pads the head axis (5%
waste on the q projection) — recorded in EXPERIMENTS.md §Roofline notes.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392,
    vocab=152064, head_dim=128, rope_theta=1000000.0, qkv_bias=True,
    # 48 (padded) MHA kv heads x 32k x b128 = 6.6 TB bf16 KV cache — more
    # than a pod's aggregate HBM; int8 cache halves it (EXPERIMENTS §Dry-run)
    kv_cache_dtype="int8",
)
