"""Architecture config schema + shape-set definitions (assigned cells)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import round_up


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int | None = None
    tie_embeddings: bool = False
    norm: str = "rms"           # rms | layer
    kind: str = "decoder"       # decoder | encdec | rwkv
    # --- MoE ---
    moe_experts: int = 0
    moe_experts_padded: int = 0
    moe_top_k: int = 0
    moe_ff: int = 0             # per-expert ffn width
    moe_period: int = 0         # MoE on layers with i % period == moe_offset
    moe_offset: int = 0
    shared_expert_ff: int = 0   # qwen2-moe shared experts (fused width)
    dense_residual: bool = False  # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25
    # --- hybrid (jamba) ---
    attn_period: int = 0        # 0 = attention everywhere
    attn_offset: int = 0
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # --- rwkv ---
    lora_r: int = 64
    # --- frontend stubs (vlm / audio) ---
    frontend_len: int = 0       # prepended precomputed-embedding positions
    # --- encdec ---
    enc_layers: int = 0
    cross_memory_len: int = 4096  # encoder memory length for decode cells
    # --- training / memory knobs ---
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (serving cache)
    remat: str = "full"         # none | full | dots
    optimizer_state_dtype: str = "float32"   # float32 | bfloat16
    group_size: int = 1         # layers per scan group
    scan_unroll: int = 1        # dry-run sets n_groups: XLA cost analysis
    #                             counts while bodies once; unrolling makes
    #                             per-layer FLOPs/collectives visible
    attn_chunk: int = 512
    mamba_chunk: int = 64
    # --- which assigned shapes run (long_500k only for sub-quadratic) ---
    supports_long: bool = False

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    # --- TP-divisibility head padding (DESIGN.md §9) ---------------------
    # 40-head (Qwen1.5) / 56-head (Arctic) attention does not divide the
    # 16-way 'model' axis.  The head axis is padded to the next multiple of
    # 16 with *masked-dead* heads: their weights are zero-masked at use, so
    # gradients through them are identically zero and the model is exactly
    # the logical architecture, at the cost of padded attention FLOPs
    # (reported in EXPERIMENTS.md §Roofline notes).
    TP = 16

    @property
    def n_heads_padded(self) -> int:
        if self.n_heads >= self.TP and self.n_heads % self.TP:
            return round_up(self.n_heads, self.TP)
        return self.n_heads

    @property
    def n_kv_padded(self) -> int:
        if self.n_kv >= self.TP and self.n_kv % self.TP:
            return round_up(self.n_kv, self.TP)
        return self.n_kv

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0
        return self.n_layers // self.group_size

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab_padded
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for kind_mix, kind_mlp in self.layer_kinds():
            if kind_mix == "attn":
                total += d * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
            elif kind_mix == "mamba":
                di = self.d_inner
                total += d * 2 * di + di * (self.dt_rank + 2 * self.d_state)
                total += self.dt_rank * di + di * d + self.d_conv * di
            elif kind_mix == "rwkv":
                total += 5 * d * d + d * self.lora_r * 2
            if kind_mlp == "dense":
                # swiglu = 3 matrices; gelu-mlp (layer-norm archs) = 2
                total += (3 if self.norm == "rms" else 2) * d * self.d_ff
            elif kind_mlp == "moe":
                ff = self.moe_ff or self.d_ff
                total += 3 * d * ff * self.moe_experts + d * self.moe_experts
                if self.shared_expert_ff:
                    total += 3 * d * self.shared_expert_ff
                if self.dense_residual:
                    total += 3 * d * self.d_ff
            elif kind_mlp == "rwkv_ffn":
                total += d * self.d_ff + self.d_ff * d + d * d
        if self.kind == "encdec":
            # encoder layers + decoder cross-attention
            total += self.enc_layers * (
                d * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
                + 3 * d * self.d_ff)
            total += self.n_layers * d * self.head_dim * (
                self.n_heads * 2 + self.n_kv * 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of E experts)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        ff = self.moe_ff or self.d_ff
        per_layer_moe = 3 * d * ff
        n_moe_layers = sum(1 for _, m in self.layer_kinds() if m == "moe")
        inactive = per_layer_moe * (self.moe_experts - self.moe_top_k)
        return self.param_count() - n_moe_layers * inactive

    def layer_kinds(self) -> list[tuple[str, str]]:
        """(mixer, mlp) kind per layer index."""
        kinds = []
        for i in range(self.n_layers):
            if self.kind == "rwkv":
                kinds.append(("rwkv", "rwkv_ffn"))
                continue
            if self.attn_period:
                mix = ("attn" if i % self.attn_period == self.attn_offset
                       else "mamba")
            else:
                mix = "attn"
            if self.moe_period and i % self.moe_period == self.moe_offset:
                mlp = "moe"
            else:
                mlp = "dense"
            kinds.append((mix, mlp))
        return kinds

    def group_kinds(self) -> list[tuple[str, str]]:
        """Layer kinds within one scan group (pattern repeats per group)."""
        kinds = self.layer_kinds()
        pattern = kinds[: self.group_size]
        assert kinds == pattern * self.n_groups, \
            f"{self.name}: layer pattern not periodic with {self.group_size}"
        return pattern


# ------------------------------------------------------- assigned shapes ---
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def supported_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    # vlm: the vision prefix counts toward seq_len (total positions = s)
    s_tok = s - cfg.frontend_len if cfg.family == "vlm" else s
    if sp.step == "train":
        d = {"tokens": jax.ShapeDtypeStruct((b, s_tok), i32),
             "targets": jax.ShapeDtypeStruct((b, s_tok), i32)}
    elif sp.step == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((b, s_tok), i32)}
    else:  # decode: one new token against a cache of size s
        d = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "vlm" and sp.step != "decode":
        d["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        # audio stub: precomputed frame embeddings replace source tokens
        enc_len = s if sp.step != "decode" else cfg.cross_memory_len
        d["frames"] = jax.ShapeDtypeStruct((b, enc_len, cfg.d_model),
                                           jnp.bfloat16)
        if sp.step == "prefill":
            # decoder prefill length: short transcript prefix
            d["tokens"] = jax.ShapeDtypeStruct((b, min(s, 4096)), i32)
    return d
