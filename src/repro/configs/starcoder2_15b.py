"""StarCoder2-15B — GQA + RoPE, LayerNorm/GELU MLP, 4k sliding window
[arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576,
    vocab=49152, head_dim=128, rope_theta=100000.0,
    norm="layer", qkv_bias=True, window=4096,
)
