"""Arch registry + reduced (smoke-test) config derivation."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs import (
    arctic_480b,
    internvl2_26b,
    jamba_52b,
    mistral_nemo_12b,
    qwen15_32b,
    qwen2_moe_a27b,
    rwkv6_7b,
    seamless_m4t_large_v2,
    starcoder2_15b,
    yi_9b,
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        yi_9b.CONFIG,
        mistral_nemo_12b.CONFIG,
        starcoder2_15b.CONFIG,
        qwen15_32b.CONFIG,
        jamba_52b.CONFIG,
        rwkv6_7b.CONFIG,
        seamless_m4t_large_v2.CONFIG,
        arctic_480b.CONFIG,
        qwen2_moe_a27b.CONFIG,
        internvl2_26b.CONFIG,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests (one real step)."""
    cfg = get_config(name)
    d = 256
    heads = 4 if cfg.kind != "rwkv" else d // 64
    kv = min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else heads
    changes = dict(
        n_layers=cfg.group_size * 2,
        d_model=d,
        n_heads=heads,
        n_kv=kv if cfg.kind != "rwkv" else heads,
        head_dim=64,
        d_ff=512,
        vocab=512,
        frontend_len=8 if cfg.frontend_len else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        cross_memory_len=32,
        lora_r=8,
        attn_chunk=64,
        mamba_chunk=8,
        remat="none",
    )
    if cfg.moe_experts:
        changes.update(moe_experts=4, moe_experts_padded=4, moe_top_k=2,
                       moe_ff=128)
    if cfg.shared_expert_ff:
        changes.update(shared_expert_ff=128)
    return dataclasses.replace(cfg, **changes)
