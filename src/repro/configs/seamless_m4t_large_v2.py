"""SeamlessM4T-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596; hf].

The speech/text frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings (B, S_enc, d) to the encoder; the
transformer backbone (24L enc + 24L dec with cross-attention) is real.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio", kind="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, head_dim=64, norm="layer",
    cross_memory_len=4096,
)
