"""Assigned architecture configs (--arch <id>)."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeSpec,
    input_specs,
    supported_shapes,
)
from repro.configs.registry import ARCHS, get_config, reduced_config  # noqa: F401
