"""Jamba-v0.1 (52B) — Mamba+attention 1:7 hybrid with MoE every other layer
[arXiv:2403.19887; hf].

Scan group = the period-8 block (1 attention layer at offset 4, 7 Mamba
layers; MoE on odd offsets).  Sub-quadratic: runs the ``long_500k`` cell —
only the 4 attention layers hold a 512k KV cache (sequence-sharded, SP).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=65536, head_dim=128,
    moe_experts=16, moe_experts_padded=16, moe_top_k=2, moe_ff=14336,
    moe_period=2, moe_offset=1,
    attn_period=8, attn_offset=4,
    d_state=16, d_conv=4, expand=2,
    group_size=8, supports_long=True,
    optimizer_state_dtype="bfloat16",
)
