"""Mistral-Nemo-12B — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

Full (quadratic) attention: the ``long_500k`` decode cell is skipped per the
assignment rules (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14336,
    vocab=131072, head_dim=128, rope_theta=1000000.0,
)
