"""RWKV6 "Finch" 7B — attention-free, data-dependent decay
[arXiv:2404.05892; hf].  Constant-size state: runs ``long_500k``."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm", kind="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336,
    vocab=65536, head_dim=64, norm="layer",
    lora_r=64, supports_long=True,
)
