"""InternVL2-26B — InternViT frontend (STUB) + InternLM2-20B backbone
[arXiv:2404.16821; hf].

``input_specs()`` supplies precomputed patch embeddings (B, 1024, d) which
are prepended to the text sequence; the 48L GQA backbone is real.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=92553, head_dim=128, rope_theta=1000000.0,
    frontend_len=1024,
)
