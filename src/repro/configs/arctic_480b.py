"""Snowflake Arctic (480B) — 128-expert top-2 MoE + parallel dense residual
[hf:Snowflake/snowflake-arctic-base].

Experts are sharded over ('data','model') = 256-way expert-parallelism;
optimizer state runs in bf16 (distributed-optimization trick, DESIGN.md §6)
— with fp32 Adam state the 480B parameters cannot fit 256 x 16 GB.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128,
    moe_experts=128, moe_experts_padded=128, moe_top_k=2, moe_ff=4864,
    moe_period=1, moe_offset=0, dense_residual=True,
    optimizer_state_dtype="bfloat16",
)
