"""Pallas TPU flash attention with block-triangular causal skipping.

The XLA-level chunked attention (models/attention.py) computes the full
causal *rectangle* and masks — a 2x FLOPs tax on attention that §Roofline
lists as the top compute lever for the prefill/train cells.  This kernel
iterates KV blocks per query block and *predicates away* blocks entirely
above the causal diagonal (`pl.when`): the MXU executes only the lower
block triangle (+ the masked diagonal blocks).

Layout: grid (B, H, Sq/bq, Skv/bk), innermost = KV blocks.  The online-
softmax state (m, l, acc) lives in revisited output blocks whose index map
ignores the KV grid dim — TPU grids iterate sequentially, so accumulation
across the innermost dimension is well-defined (and interpret mode matches).
GQA maps query head h to KV head h // (H / K) inside the index maps.

VMEM per step: q/k/v blocks (bq|bk x hd) + (bq, bk) scores + f32 acc
(bq x hd) — ~1.3 MB at bq=bk=256, hd=128: far under budget, so ops.py picks
larger bq for small models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, scale: float, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: KV block strictly above the diagonal does nothing
    run = (ki * block_k <= qi * block_q + block_q - 1) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_old = m_ref[0, 0]                               # (bq, 1)
        l_old = l_ref[0, 0]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_old - m_new)                     # (bq, 1)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_new = l_old * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, hd)
        acc_ref[0, 0] = acc_ref[0, 0] * corr + pv
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, K, Skv, hd).  Returns (B, H, Sq, hd).

    Sq % block_q == 0 and Skv % block_k == 0 (ops.py pads).
    """
    b, h, sq, hd = q.shape
    kk, skv = k.shape[1], k.shape[2]
    g = h // kk
    assert h % kk == 0 and sq % block_q == 0 and skv % block_k == 0
    grid = (b, h, sq // block_q, skv // block_k)
    scale = 1.0 / (hd ** 0.5)

    q_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd),
                           lambda bi, hi, qi, ki: (bi, hi // g, ki, 0))
    acc_spec = pl.BlockSpec((1, 1, block_q, hd),
                            lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    ml_spec = pl.BlockSpec((1, 1, block_q, 1),
                           lambda bi, hi, qi, ki: (bi, hi, qi, 0))

    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, scale=scale, causal=causal)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=(acc_spec, ml_spec, ml_spec),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
        ),
        interpret=interpret,
    )(q, k, v)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
