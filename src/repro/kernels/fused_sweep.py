"""Pallas TPU kernels: fused wake + LPA-move and wake + min-label sweeps.

The unfused hot loop pays two dispatches per sub-sweep with the (B, D)
neighbor tiles round-tripping through HBM between them: ``label_argmax``
reads label/weight/mask tiles (9 B/cell) and a second wake pass re-reads
the changed/mask tiles (2 B/cell).  The move and split *phases* are
sequential by construction (split consumes the converged move labels), so
the fusion that actually removes HBM traffic is per-phase: fold the wake
reduction, the active-set update, and the adopt rule into the same grid
sweep that already holds the tiles in VMEM.

This requires the lazy-wake loop form (the wake for sweep *k* is applied
at the start of sweep *k+1* from the carried changed mask) — the exact
restructure the out-of-core driver already uses, proven bit-identical:
labels and iteration counts depend only on the per-sweep ``dn`` and the
active sequence, both unchanged under the reordering.

Per-sub-sweep HBM tile traffic (B*D cells dominate; columns are O(B)):

    move:  fused 10 B/cell (lab 4 + w 4 + mask 1 + changed 1)
           vs. separate 11 B/cell (argmax 9 + wake changed 1 + mask 1)
    split (lpp): fused 10 B/cell (lab 4 + comm 4 + mask 1 + changed 1)
           vs. separate 11 B/cell (min_label 9 + wake changed 1 + same 1)

Block layout matches ``label_argmax``: grid over row tiles, (TILE_B, D)
row tiles + (TILE_B, 1) state columns; the equality cube stays under the
``tiling.CUBE_BUDGET_BYTES`` VMEM cap (asserted below, checked by R004).

Tie-breaks and the adopt rule are shared with the standalone kernels via
``argmax_tile_math`` so float sums are bit-identical across paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.label_argmax import argmax_tile_math
from repro.kernels.tiling import CUBE_BUDGET_BYTES

_SENTINEL = 2147483647  # python literal: materialised in-trace, not captured


def _fused_move_kernel(seed_ref, lab_ref, w_ref, mask_ref, chg_ref,
                       cur_ref, active_ref, candp_ref, klass_ref, real_ref,
                       new_ref, act_ref):
    lab = lab_ref[...]                                   # (B, D) int32
    mask = mask_ref[...]                                 # (B, D) bool

    # Lazy wake: apply the previous sub-sweep's changed mask, retire its
    # candidate set, then pick this sub-sweep's candidates.
    wake = jnp.any(chg_ref[...] & mask, axis=1, keepdims=True)   # (B, 1)
    act = (active_ref[...] & ~candp_ref[...]) | (wake & real_ref[...])
    cand = act & klass_ref[...]

    cur = cur_ref[...]                                   # (B, 1)
    best_lab, best_w, cur_w = argmax_tile_math(
        lab, w_ref[...], mask, cur, seed_ref[0, 0])
    adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))

    new_ref[...] = jnp.where(adopt, best_lab, cur)
    act_ref[...] = act


def fused_move_pallas(nbr_lab: jnp.ndarray, nbr_w: jnp.ndarray,
                      nbr_mask: jnp.ndarray, chg_nbr: jnp.ndarray,
                      cur: jnp.ndarray, active: jnp.ndarray,
                      cand_prev: jnp.ndarray, klass: jnp.ndarray,
                      real: jnp.ndarray, seed: jnp.ndarray, *, tile_b: int,
                      interpret: bool = False):
    """One-dispatch wake + move.  Row tiles (n_pad, d_max); state (n_pad,).

    Returns (new_labels, active_out), each (n_pad,).  ``chg_nbr`` is the
    previous sub-sweep's changed mask gathered to neighbor slots;
    ``cand_prev`` that sub-sweep's candidate set (zeros on the first).
    """
    n_pad, d_max = nbr_lab.shape
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    assert tile_b == 1 or tile_b * d_max * d_max * 4 <= CUBE_BUDGET_BYTES, \
        (tile_b, d_max)
    grid = (n_pad // tile_b,)

    row_spec = pl.BlockSpec((tile_b, d_max), lambda i: (i, 0))
    col_spec = pl.BlockSpec((tile_b, 1), lambda i: (i, 0))
    seed_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    def col(x, dtype):
        return x.reshape(-1, 1).astype(dtype)

    new, act = pl.pallas_call(
        _fused_move_kernel,
        grid=grid,
        in_specs=[seed_spec, row_spec, row_spec, row_spec, row_spec,
                  col_spec, col_spec, col_spec, col_spec, col_spec],
        out_specs=(col_spec, col_spec),
        out_shape=(jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad, 1), jnp.bool_)),
        interpret=interpret,
    )(seed.reshape(1, 1).astype(jnp.int32), nbr_lab, nbr_w, nbr_mask,
      chg_nbr, col(cur, jnp.int32), col(active, jnp.bool_),
      col(cand_prev, jnp.bool_), col(klass, jnp.bool_),
      col(real, jnp.bool_))
    return new[:, 0], act[:, 0]


def _fused_split_prune_kernel(lab_ref, comm_ref, mask_ref, chg_ref,
                              cur_ref, scomm_ref, new_ref):
    same = mask_ref[...] & (comm_ref[...] == scomm_ref[...])   # (B, D)
    # Lazy wake over same-community edges; rows not woken keep their label
    # (the lpp prune).  First iteration passes chg = ones: rows with no
    # same-community neighbor reduce to their own label anyway, so the
    # result matches the eager active0 = ones initialisation bit-for-bit.
    wake = jnp.any(chg_ref[...] & same, axis=1, keepdims=True)  # (B, 1)
    cand = jnp.where(same, lab_ref[...], _SENTINEL)
    cur = cur_ref[...]
    mres = jnp.minimum(cur, jnp.min(cand, axis=1, keepdims=True))
    new_ref[...] = jnp.where(wake, mres, cur)


def _fused_split_kernel(lab_ref, comm_ref, mask_ref, cur_ref, scomm_ref,
                        new_ref):
    same = mask_ref[...] & (comm_ref[...] == scomm_ref[...])   # (B, D)
    cand = jnp.where(same, lab_ref[...], _SENTINEL)
    new_ref[...] = jnp.minimum(cur_ref[...],
                               jnp.min(cand, axis=1, keepdims=True))


def fused_split_pallas(nbr_lab: jnp.ndarray, nbr_comm: jnp.ndarray,
                       nbr_mask: jnp.ndarray, chg_nbr: jnp.ndarray,
                       self_lab: jnp.ndarray, self_comm: jnp.ndarray, *,
                       prune: bool, tile_b: int,
                       interpret: bool = False) -> jnp.ndarray:
    """One-dispatch split-wake + min-label.  Returns new labels (n_pad,).

    ``chg_nbr`` is last iteration's changed mask gathered to neighbor
    slots (ones on the first iteration); ignored when ``prune`` is False
    (the lp mode has no active-set prune, so the wake leg is dropped and
    its tile is never read).
    """
    n_pad, d_max = nbr_lab.shape
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    grid = (n_pad // tile_b,)
    row_spec = pl.BlockSpec((tile_b, d_max), lambda i: (i, 0))
    col_spec = pl.BlockSpec((tile_b, 1), lambda i: (i, 0))

    def col(x):
        return x.reshape(-1, 1).astype(jnp.int32)

    if prune:
        kernel = _fused_split_prune_kernel
        in_specs = [row_spec, row_spec, row_spec, row_spec,
                    col_spec, col_spec]
        operands = (nbr_lab, nbr_comm, nbr_mask, chg_nbr,
                    col(self_lab), col(self_comm))
    else:
        kernel = _fused_split_kernel
        in_specs = [row_spec, row_spec, row_spec, col_spec, col_spec]
        operands = (nbr_lab, nbr_comm, nbr_mask,
                    col(self_lab), col(self_comm))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:, 0]
