"""VMEM tile budgeting shared by the kernel wrappers and ops dispatch.

The label-scan kernels materialise a (TILE_B, D, D) equality cube in VMEM;
the budget here caps that cube at 4 MB, leaving headroom for the (TILE_B, D)
operand tiles, double-buffering, and MXU accumulators in a 16 MB VMEM.
Wrappers that build the cube assert the bound explicitly (R004 checks the
assert is present), and ``pick_tile_b`` is the one place tile sizes are
derived so every cube-building dispatch goes through the same budget.
"""
from __future__ import annotations

CUBE_BUDGET_BYTES = 4 * 1024 * 1024


def pick_tile_b(n_pad: int, d_max: int) -> int:
    """Largest row tile whose equality cube fits the VMEM budget."""
    tile = max(CUBE_BUDGET_BYTES // max(d_max * d_max * 4, 1), 1)
    tile = min(tile, 256, n_pad)
    while n_pad % tile:
        tile -= 1
    return max(tile, 1)
