"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; elsewhere the
default is the pure-jnp oracle (fast XLA:CPU path) with ``interpret=True``
Pallas execution available for kernel-body validation (used by tests).

VMEM budgeting: the label_argmax equality cube costs TILE_B * D * D * 4
bytes; we target <= 4 MB for the cube (leaving headroom for the (TILE_B, D)
operands, double-buffering, and the MXU accumulators in a 16 MB VMEM), and
keep TILE_B a multiple of 8 (sublane) where possible.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_sweep import fused_move_pallas, fused_split_pallas
from repro.kernels.label_argmax import label_argmax_pallas
from repro.kernels.min_label import min_label_pallas
from repro.kernels.tiling import CUBE_BUDGET_BYTES, pick_tile_b

_CUBE_BUDGET_BYTES = CUBE_BUDGET_BYTES  # re-export (see kernels/tiling.py)

__all__ = ["pick_tile_b", "label_argmax", "min_label", "fused_move",
           "fused_split", "resolve_fuse", "flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_fuse(fuse_sweeps: str, kernel_mode: str) -> bool:
    """Resolve ``EngineConfig.fuse_sweeps`` against the kernel dispatch.

    'auto' fuses only when a real Pallas kernel body executes (pallas on
    TPU, or explicit interpret mode); the jnp oracle path gains nothing
    from fusion — XLA already fuses the elementwise glue — and stays the
    default-dispatch parity reference.
    """
    if fuse_sweeps == "off":
        return False
    if fuse_sweeps == "on":
        return True
    mode = kernel_mode
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    return mode in ("pallas", "interpret")


@partial(jax.jit, static_argnames=("mode",))
def label_argmax(nbr_lab, nbr_w, nbr_mask, cur, seed, mode: str = "auto"):
    """Best community label per padded row (see kernels/label_argmax.py).

    mode: 'auto' (pallas on TPU, ref elsewhere), 'pallas', 'interpret', 'ref'.
    Returns (best_label, best_weight, current_weight), each (n_pad,).
    """
    n_pad, d_max = nbr_lab.shape
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "ref":
        return ref.label_argmax_ref(nbr_lab, nbr_w, nbr_mask, cur, seed)
    tile_b = pick_tile_b(n_pad, d_max)
    return label_argmax_pallas(nbr_lab, nbr_w, nbr_mask, cur,
                               jnp.asarray(seed, jnp.int32), tile_b=tile_b,
                               interpret=(mode == "interpret"))


@partial(jax.jit, static_argnames=("causal", "mode"))
def flash_attention(q, k, v, causal: bool = True, mode: str = "auto"):
    """Flash attention (kernels/flash_attention.py).

    q: (B, S, H, hd); k/v: (B, S_kv, K, hd) — the models' layout; padding to
    block multiples handled here (padded KV positions are masked by the
    causal/softmax math: they sort above the diagonal or contribute
    exp(-inf)=0 via the -inf pad of q... padded q rows are sliced off).
    mode: 'auto' (pallas on TPU, XLA oracle elsewhere) | 'interpret' | 'ref'.
    """
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import chunked_attention

    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    if mode == "ref":
        pos_q = jnp.arange(sq, dtype=jnp.int32)
        pos_k = jnp.arange(skv, dtype=jnp.int32)
        return chunked_attention(q, k, v, pos_q, pos_k, causal=causal,
                                 chunk=min(512, skv))
    bq = bk = 256
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pk and not causal:
        # padded KV under full attention would leak mass; encoders use
        # block-multiple lengths — fall back to the oracle otherwise
        pos_q = jnp.arange(sq, dtype=jnp.int32)
        pos_k = jnp.arange(skv, dtype=jnp.int32)
        return chunked_attention(q, k, v, pos_q, pos_k, causal=causal,
                                 chunk=min(512, skv))
    qt = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))), 2, 1)
    kt = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    vt = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=bq,
                                 block_k=bk,
                                 interpret=(mode == "interpret"))
    return jnp.moveaxis(out, 1, 2)[:, :sq]


@partial(jax.jit, static_argnames=("mode",))
def min_label(nbr_lab, nbr_comm, nbr_mask, self_lab, self_comm,
              mode: str = "auto"):
    """Split-phase same-community neighbor min (see kernels/min_label.py)."""
    n_pad, d_max = nbr_lab.shape
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "ref":
        return ref.min_label_ref(nbr_lab, nbr_comm, nbr_mask, self_lab,
                                 self_comm)
    tile_b = pick_tile_b(n_pad, d_max)
    return min_label_pallas(nbr_lab, nbr_comm, nbr_mask, self_lab, self_comm,
                            tile_b=tile_b, interpret=(mode == "interpret"))


@partial(jax.jit, static_argnames=("mode",))
def fused_move(nbr_lab, nbr_w, nbr_mask, chg_nbr, cur, active, cand_prev,
               klass, real, seed, mode: str = "auto"):
    """One-dispatch lazy-wake + LPA move (see kernels/fused_sweep.py).

    ``chg_nbr`` is the previous sub-sweep's changed mask gathered to
    neighbor slots; ``cand_prev`` its candidate set (zeros on the first
    sub-sweep).  Returns (new_labels, active_out), each (n_pad,).
    """
    n_pad, d_max = nbr_lab.shape
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "ref":
        return ref.fused_move_ref(nbr_lab, nbr_w, nbr_mask, chg_nbr, cur,
                                  active, cand_prev, klass, real, seed)
    tile_b = pick_tile_b(n_pad, d_max)
    return fused_move_pallas(nbr_lab, nbr_w, nbr_mask, chg_nbr, cur, active,
                             cand_prev, klass, real,
                             jnp.asarray(seed, jnp.int32), tile_b=tile_b,
                             interpret=(mode == "interpret"))


@partial(jax.jit, static_argnames=("prune", "mode"))
def fused_split(nbr_lab, nbr_comm, nbr_mask, chg_nbr, self_lab, self_comm,
                prune: bool = True, mode: str = "auto"):
    """One-dispatch lazy split-wake + min-label (kernels/fused_sweep.py).

    ``chg_nbr`` is last iteration's changed mask gathered to neighbor
    slots (ones on the first iteration); ignored when ``prune`` is False.
    """
    n_pad, d_max = nbr_lab.shape
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "ref":
        return ref.fused_split_ref(nbr_lab, nbr_comm, nbr_mask, chg_nbr,
                                   self_lab, self_comm, prune)
    tile_b = pick_tile_b(n_pad, d_max)
    return fused_split_pallas(nbr_lab, nbr_comm, nbr_mask, chg_nbr,
                              self_lab, self_comm, prune=prune,
                              tile_b=tile_b,
                              interpret=(mode == "interpret"))
