"""Pallas TPU kernel: the ``scanCommunities`` + best-label hot spot.

The paper accumulates per-community weights in per-thread hashtables.  On
TPU, the histogram over a padded neighbor tile is recast as an
*equality-masked matmul*:

    scores[b, k] = sum_j w[b, j] * [labels[b, j] == labels[b, k]]

i.e. every neighbor slot k is scored with the total weight of slots carrying
the same label.  The (D, D) equality mask contracted with the weight vector
is MXU-shaped work, entirely VMEM-resident per block, and needs no data-
dependent memory access (the TPU has no efficient hashtable analogue).

Block layout: grid over row tiles; each step sees (TILE_B, D) label /
weight / mask tiles plus (TILE_B, 1) current-label column, and writes
(TILE_B, 1) best-label / best-weight / current-weight columns.  VMEM per
step: 3 * TILE_B * D * 4B for inputs + TILE_B * D * D * 4B for the equality
cube — ``ops.py`` picks TILE_B so this stays well under 16 MB VMEM.

Tie-breaks match ``core.lpa`` exactly: max weight, then max label-hash
(per-iteration seed), then min label.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import CUBE_BUDGET_BYTES

_SENTINEL = 2147483647  # python literal: materialised in-trace, not captured


def _hash(labels: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    x = labels.astype(jnp.uint32) * jnp.uint32(2654435761)
    x ^= seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    return x.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)


def argmax_tile_math(lab, w_raw, mask, cur, seed):
    """The (B, D)-tile argmax tie-break chain, shared with fused_sweep.

    Both the standalone and fused kernels must run the *same* op sequence so
    their float sums (and hence tie-break decisions) are bit-identical.
    Returns (best_lab, best_w, cur_w), each (B, 1).
    """
    w = jnp.where(mask, w_raw, 0.0)                      # (B, D) f32

    # Equality cube -> per-slot community scores via batched dot (MXU).
    eq = (lab[:, :, None] == lab[:, None, :]).astype(w.dtype)  # (B, D, D)
    scores = jax.lax.dot_general(
        w[:, None, :], eq,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]     # (B, D)
    scores = jnp.where(mask, scores, -1.0)

    best_w = jnp.max(scores, axis=1, keepdims=True)      # (B, 1)
    is_best = mask & (scores >= best_w) & (best_w > 0)
    h = _hash(lab, seed)
    best_h = jnp.max(jnp.where(is_best, h, -1), axis=1, keepdims=True)
    pick = is_best & (h == best_h)
    best_lab = jnp.min(jnp.where(pick, lab, _SENTINEL), axis=1, keepdims=True)

    cur_w = jnp.sum(jnp.where(lab == cur, w, 0.0), axis=1, keepdims=True)
    return best_lab, jnp.maximum(best_w, 0.0), cur_w


def _label_argmax_kernel(seed_ref, lab_ref, w_ref, mask_ref, cur_ref,
                         best_lab_ref, best_w_ref, cur_w_ref):
    best_lab, best_w, cur_w = argmax_tile_math(
        lab_ref[...], w_ref[...], mask_ref[...], cur_ref[...],
        seed_ref[0, 0])
    best_lab_ref[...] = best_lab
    best_w_ref[...] = best_w
    cur_w_ref[...] = cur_w


def label_argmax_pallas(nbr_lab: jnp.ndarray, nbr_w: jnp.ndarray,
                        nbr_mask: jnp.ndarray, cur: jnp.ndarray,
                        seed: jnp.ndarray, *, tile_b: int,
                        interpret: bool = False):
    """pallas_call wrapper.  Shapes: (n_pad, d_max) tiles, (n_pad,) cur."""
    n_pad, d_max = nbr_lab.shape
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    assert tile_b == 1 or tile_b * d_max * d_max * 4 <= CUBE_BUDGET_BYTES, \
        (tile_b, d_max)
    grid = (n_pad // tile_b,)

    row_spec = pl.BlockSpec((tile_b, d_max), lambda i: (i, 0))
    col_spec = pl.BlockSpec((tile_b, 1), lambda i: (i, 0))
    seed_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    out_shape = (
        jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),    # best label
        jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),  # best weight
        jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),  # weight to current
    )
    best_lab, best_w, cur_w = pl.pallas_call(
        _label_argmax_kernel,
        grid=grid,
        in_specs=[seed_spec, row_spec, row_spec, row_spec, col_spec],
        out_specs=(col_spec, col_spec, col_spec),
        out_shape=out_shape,
        interpret=interpret,
    )(seed.reshape(1, 1).astype(jnp.int32), nbr_lab, nbr_w, nbr_mask,
      cur.reshape(-1, 1).astype(jnp.int32))
    return best_lab[:, 0], best_w[:, 0], cur_w[:, 0]
