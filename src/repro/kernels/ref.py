"""Pure-jnp oracles for the Pallas kernels (bit-exact semantics)."""
from __future__ import annotations

import jax.numpy as jnp

_SENTINEL = jnp.int32(2147483647)


def _hash(labels: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    x = labels.astype(jnp.uint32) * jnp.uint32(2654435761)
    x ^= seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    return x.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)


def label_argmax_ref(nbr_lab: jnp.ndarray, nbr_w: jnp.ndarray,
                     nbr_mask: jnp.ndarray, cur: jnp.ndarray,
                     seed: jnp.ndarray):
    """Oracle for ``label_argmax_pallas`` (same tie-break chain)."""
    w = jnp.where(nbr_mask, nbr_w, 0.0)
    eq = (nbr_lab[:, :, None] == nbr_lab[:, None, :]).astype(w.dtype)
    scores = jnp.einsum("bj,bjk->bk", w, eq)
    scores = jnp.where(nbr_mask, scores, -1.0)

    best_w = jnp.max(scores, axis=1, keepdims=True)
    is_best = nbr_mask & (scores >= best_w) & (best_w > 0)
    h = _hash(nbr_lab, jnp.asarray(seed, jnp.int32))
    best_h = jnp.max(jnp.where(is_best, h, -1), axis=1, keepdims=True)
    pick = is_best & (h == best_h)
    best_lab = jnp.min(jnp.where(pick, nbr_lab, _SENTINEL), axis=1)

    cur_w = jnp.sum(jnp.where(nbr_lab == cur[:, None], w, 0.0), axis=1)
    return best_lab, jnp.maximum(best_w[:, 0], 0.0), cur_w


def min_label_ref(nbr_lab: jnp.ndarray, nbr_comm: jnp.ndarray,
                  nbr_mask: jnp.ndarray, self_lab: jnp.ndarray,
                  self_comm: jnp.ndarray) -> jnp.ndarray:
    """Oracle for ``min_label_pallas``."""
    ok = nbr_mask & (nbr_comm == self_comm[:, None])
    cand = jnp.where(ok, nbr_lab, _SENTINEL)
    return jnp.minimum(self_lab.astype(jnp.int32), jnp.min(cand, axis=1))


def fused_move_ref(nbr_lab: jnp.ndarray, nbr_w: jnp.ndarray,
                   nbr_mask: jnp.ndarray, chg_nbr: jnp.ndarray,
                   cur: jnp.ndarray, active: jnp.ndarray,
                   cand_prev: jnp.ndarray, klass: jnp.ndarray,
                   real: jnp.ndarray, seed: jnp.ndarray):
    """Oracle for ``fused_move_pallas`` (lazy wake + argmax + adopt).

    Composes ``label_argmax_ref`` so the float sums — and hence every
    tie-break and adopt decision — are bit-identical to the unfused
    reference path.
    """
    wake = jnp.any(chg_nbr & nbr_mask, axis=1)
    act = (active & ~cand_prev) | (wake & real)
    cand = act & klass
    best_lab, best_w, cur_w = label_argmax_ref(nbr_lab, nbr_w, nbr_mask,
                                               cur, seed)
    adopt = cand & (best_w > jnp.maximum(cur_w, 0.0))
    return jnp.where(adopt, best_lab.astype(jnp.int32),
                     cur.astype(jnp.int32)), act


def fused_split_ref(nbr_lab: jnp.ndarray, nbr_comm: jnp.ndarray,
                    nbr_mask: jnp.ndarray, chg_nbr: jnp.ndarray,
                    self_lab: jnp.ndarray, self_comm: jnp.ndarray,
                    prune: bool) -> jnp.ndarray:
    """Oracle for ``fused_split_pallas`` (lazy split-wake + min-label)."""
    mres = min_label_ref(nbr_lab, nbr_comm, nbr_mask, self_lab, self_comm)
    if not prune:
        return mres
    same = nbr_mask & (nbr_comm == self_comm[:, None])
    wake = jnp.any(chg_nbr & same, axis=1)
    return jnp.where(wake, mres, self_lab.astype(jnp.int32))
