"""Pallas TPU kernel: the Split-Last min-label sweep (Algorithm 1 body).

Per vertex row: the minimum label among same-community neighbors, folded
with the vertex's own label.  Pure VPU work — a masked row-min over a
(TILE_B, D) tile.  The neighbor label/community gathers happen outside (XLA
gather from HBM); the kernel fuses mask construction + reduction so the
(B, D) intermediates never round-trip to HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SENTINEL = 2147483647  # python literal: materialised in-trace, not captured


def _min_label_kernel(nbr_lab_ref, nbr_comm_ref, mask_ref, self_lab_ref,
                      self_comm_ref, out_ref):
    nl = nbr_lab_ref[...]        # (B, D) int32: L[nbr]
    nc = nbr_comm_ref[...]       # (B, D) int32: C[nbr]
    ok = mask_ref[...] & (nc == self_comm_ref[...])   # same-community & real
    cand = jnp.where(ok, nl, _SENTINEL)
    out_ref[...] = jnp.minimum(self_lab_ref[...],
                               jnp.min(cand, axis=1, keepdims=True))


def min_label_pallas(nbr_lab: jnp.ndarray, nbr_comm: jnp.ndarray,
                     nbr_mask: jnp.ndarray, self_lab: jnp.ndarray,
                     self_comm: jnp.ndarray, *, tile_b: int,
                     interpret: bool = False) -> jnp.ndarray:
    n_pad, d_max = nbr_lab.shape
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    grid = (n_pad // tile_b,)
    row_spec = pl.BlockSpec((tile_b, d_max), lambda i: (i, 0))
    col_spec = pl.BlockSpec((tile_b, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _min_label_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, col_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(nbr_lab, nbr_comm, nbr_mask, self_lab.reshape(-1, 1).astype(jnp.int32),
      self_comm.reshape(-1, 1).astype(jnp.int32))
    return out[:, 0]
