"""Bounded, tenant-fair request admission with explicit backpressure.

The serving tier's front door: every request from every tenant lands in
one :class:`AdmissionQueue` with a **global capacity bound** — when the
queue is full, :meth:`AdmissionQueue.offer` raises :class:`Rejected`
carrying a ``retry_after_s`` hint instead of growing without bound (the
caller sleeps and retries; nothing is silently dropped, nothing queues
forever).

Dequeue order is **round-robin across tenants**: each tenant has its own
FIFO, and :meth:`AdmissionQueue.take` serves the next tenant in rotation
that (a) has queued work and (b) is not *held*.  A tenant is held from
the moment one of its requests is taken until the service calls
:meth:`AdmissionQueue.release` — the one-in-flight-per-tenant rule that
both keeps per-tenant request order (a delta must apply to the graph its
predecessor produced) and makes the rotation an actual fairness
guarantee: a tenant flooding its FIFO only ever occupies one dispatch
slot per cycle, so a quiet tenant's single request is served within one
rotation, not behind the flood.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, OrderedDict, deque


class Rejected(RuntimeError):
    """Backpressure: the global admission queue is full.

    Carries ``retry_after_s`` — the client-facing hint for when to retry.
    This is the *only* way the serving tier sheds load: a request is
    either rejected here, visibly, or it is admitted and will resolve
    (with a result or an exception).  Nothing in between.
    """

    def __init__(self, depth: int, capacity: int, retry_after_s: float):
        self.depth = depth
        self.capacity = capacity
        self.retry_after_s = retry_after_s
        super().__init__(
            f"admission queue full ({depth}/{capacity}); "
            f"retry after {retry_after_s:.3f}s")


class AdmissionQueue:
    """Global-capacity, per-tenant-FIFO, round-robin-drained queue.

    capacity: hard bound on queued (not yet taken) requests across all
      tenants; ``offer`` past it raises :class:`Rejected`.
    retry_after_s: the hint attached to rejections.
    served_label_cap: how many tenants get a dedicated
      ``served.<tenant>`` registry counter; later tenants share
      ``served.other`` (see :class:`repro.obs.CappedCounterSet`).
    """

    def __init__(self, capacity: int, retry_after_s: float = 0.05,
                 scope=None, served_label_cap: int = 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.retry_after_s = float(retry_after_s)
        self._cond = threading.Condition()
        # tenant -> FIFO of queued items; dict order IS the rotation:
        # a served tenant is moved to the back of the cycle.
        self._fifos: OrderedDict[object, deque] = OrderedDict()
        self._held: set = set()
        self._closed = False
        self.depth = 0
        self.peak_depth = 0
        self.accepted = 0
        self.rejected = 0
        self.served: Counter = Counter()   # tenant -> requests taken
        # Registry write-through; the fields above stay authoritative.
        # Per-tenant served counts enter the registry through a *capped*
        # label space (first ``served_label_cap`` tenants get their own
        # ``served.<tenant>`` counter, the rest share ``served.other``) —
        # tenant ids are unbounded, registry cardinality must not be.
        # Exact per-tenant numbers stay in ``stats()``.
        from repro.obs import CappedCounterSet
        self._served_metrics = CappedCounterSet(
            scope, "served", max_labels=served_label_cap) if scope else None
        self._m_accepted = scope.counter("accepted") if scope else None
        self._m_rejected = scope.counter("rejected") if scope else None
        self._m_taken = scope.counter("taken") if scope else None
        self._g_depth = scope.gauge("depth") if scope else None
        self._g_peak = scope.gauge("peak_depth") if scope else None
        self._g_held = scope.gauge("held") if scope else None

    # --- producer side ---

    def offer(self, tenant, item) -> None:
        """Enqueue one request, or raise :class:`Rejected` when full."""
        with self._cond:
            if self._closed:
                raise RuntimeError("admission queue is closed")
            if self.depth >= self.capacity:
                self.rejected += 1
                if self._m_rejected is not None:
                    self._m_rejected.inc()
                raise Rejected(self.depth, self.capacity, self.retry_after_s)
            fifo = self._fifos.get(tenant)
            if fifo is None:
                fifo = self._fifos[tenant] = deque()
            fifo.append(item)
            self.depth += 1
            self.peak_depth = max(self.peak_depth, self.depth)
            self.accepted += 1
            if self._m_accepted is not None:
                self._m_accepted.inc()
                self._g_depth.set(self.depth)
                self._g_peak.set(self.peak_depth)
            self._cond.notify()

    def close(self) -> None:
        """Stop accepting; queued work remains takeable (drain mode)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    # --- consumer side (the dispatcher) ---

    def take(self, timeout: float | None = None):
        """Next ``(tenant, item)`` in rotation; holds the tenant.

        Skips held tenants (their next request becomes eligible on
        :meth:`release`).  Returns None on timeout, or immediately when
        the queue is closed and drained.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                for tenant, fifo in self._fifos.items():
                    if tenant in self._held or not fifo:
                        continue
                    item = fifo.popleft()
                    self.depth -= 1
                    self._held.add(tenant)
                    self.served[tenant] += 1
                    # back of the cycle: round-robin fairness
                    self._fifos.move_to_end(tenant)
                    if not fifo:
                        del self._fifos[tenant]
                    if self._m_taken is not None:
                        self._m_taken.inc()
                        self._g_depth.set(self.depth)
                        self._g_held.set(len(self._held))
                    if self._served_metrics is not None:
                        self._served_metrics.inc(tenant)
                    return tenant, item
                if self._closed and self.depth == 0:
                    return None
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return None
                self._cond.wait(remaining)

    def release(self, tenant) -> None:
        """The tenant's in-flight request settled; its next queued
        request becomes takeable."""
        with self._cond:
            self._held.discard(tenant)
            if self._g_held is not None:
                self._g_held.set(len(self._held))
            self._cond.notify_all()

    # --- observability ---

    def drained(self) -> bool:
        with self._cond:
            return self._closed and self.depth == 0

    def stats(self) -> dict:
        with self._cond:
            return {
                "capacity": self.capacity,
                "depth": self.depth,
                "peak_depth": self.peak_depth,
                "accepted": self.accepted,
                "rejected": self.rejected,
                "held": len(self._held),
                "tenants_queued": len(self._fifos),
                "served_per_tenant": dict(self.served),
            }
