"""Per-tenant quality/SLO health: ring-buffer timelines + drift alerts.

The serving tier completes thousands of fits across tenants; this module
keeps a bounded per-tenant timeline of :class:`QualitySample` records
(latency + the fit's :class:`repro.obs.QualityReport` fields) and raises
:class:`Alert` records when a tenant drifts:

* ``modularity_drop`` — modularity fell more than
  ``HealthConfig.modularity_drop`` below the tenant's previous sample
  (the answers are getting worse faster than streaming drift explains);
* ``disconnected`` — the disconnected-community fraction went nonzero
  (the paper's headline invariant broke — this should never fire);
* ``slo_burn`` — the tenant's rolling p99 latency exceeded
  ``HealthConfig.slo_p99_ms`` (edge-triggered: one alert per excursion,
  re-armed when p99 recovers).

Aggregate counts go through the metrics registry (alert counters, last
modularity / disconnected-fraction gauges); per-tenant detail stays on
``stats()`` — tenant ids are an unbounded label space the registry must
never absorb (see ``CappedCounterSet`` for the bounded exception).
Everything is host-side bookkeeping under one lock; nothing here touches
the device.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Drift/SLO thresholds for :class:`HealthMonitor`."""

    timeline_len: int = 128        # samples kept per tenant (ring buffer)
    modularity_drop: float = 0.05  # alert when modularity falls > this
    slo_p99_ms: float | None = None  # latency SLO; None disables slo_burn
    latency_window: int = 32       # samples in the rolling p99
    max_alerts: int = 256          # alert records kept (ring buffer)

    def __post_init__(self):
        if self.timeline_len < 1:
            raise ValueError("timeline_len must be >= 1")
        if self.modularity_drop <= 0:
            raise ValueError("modularity_drop must be > 0")
        if self.slo_p99_ms is not None and self.slo_p99_ms <= 0:
            raise ValueError("slo_p99_ms must be > 0")
        if self.latency_window < 1:
            raise ValueError("latency_window must be >= 1")


@dataclasses.dataclass
class QualitySample:
    """One completed fit on a tenant's timeline."""

    ts: float
    kind: str                      # request kind: register | update | ...
    latency_ms: float
    modularity: float | None = None
    disconnected_fraction: float | None = None
    communities: int | None = None
    churn: float | None = None

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Alert:
    """One drift/SLO violation record."""

    ts: float
    tenant: Any
    kind: str                      # modularity_drop | disconnected | slo_burn
    value: float
    threshold: float
    message: str

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tenant"] = str(self.tenant)
        return d


class TenantTimeline:
    """Bounded sample history for one tenant (not thread-safe on its own
    — :class:`HealthMonitor` serializes access under its lock)."""

    def __init__(self, maxlen: int):
        self.samples: deque[QualitySample] = deque(maxlen=maxlen)
        self.total = 0  # samples ever recorded (ring drops old ones)

    def append(self, sample: QualitySample) -> None:
        self.samples.append(sample)
        self.total += 1

    @property
    def last(self) -> QualitySample | None:
        return self.samples[-1] if self.samples else None

    def p99_latency(self, window: int) -> float:
        xs = sorted(s.latency_ms for s in
                    list(self.samples)[-window:])
        if not xs:
            return 0.0
        return xs[min(int(0.99 * len(xs)), len(xs) - 1)]

    def to_dict(self) -> dict[str, Any]:
        last = self.last
        return {"samples": self.total,
                "window": len(self.samples),
                "last": last.to_dict() if last else None}


class HealthMonitor:
    """Aggregates per-tenant timelines and emits drift/SLO alerts."""

    def __init__(self, config: HealthConfig | None = None, scope=None):
        self.config = config if config is not None else HealthConfig()
        self._lock = threading.Lock()
        self._timelines: dict[Any, TenantTimeline] = {}
        self.alerts: deque[Alert] = deque(maxlen=self.config.max_alerts)
        self._alert_counts: dict[str, int] = {}
        self._burning: set[Any] = set()   # tenants in an slo_burn excursion
        self._scope = scope
        if scope is not None:
            self._m_samples = scope.counter("samples")
            self._m_alerts = {
                kind: scope.counter(f"alerts_{kind}")
                for kind in ("modularity_drop", "disconnected", "slo_burn")}
            self._g_modularity = scope.gauge("modularity")
            self._g_disconnected = scope.gauge("disconnected_fraction")
            self._g_tenants = scope.gauge("tenants")
        else:
            self._m_samples = None

    def record(self, tenant: Any, sample: QualitySample) -> list[Alert]:
        """Append a sample; return (and retain) any alerts it triggered."""
        cfg = self.config
        fired: list[Alert] = []
        with self._lock:
            tl = self._timelines.get(tenant)
            if tl is None:
                tl = self._timelines[tenant] = TenantTimeline(
                    cfg.timeline_len)
            prev = tl.last
            tl.append(sample)

            if (sample.modularity is not None and prev is not None
                    and prev.modularity is not None):
                drop = prev.modularity - sample.modularity
                if drop > cfg.modularity_drop:
                    fired.append(Alert(
                        ts=sample.ts, tenant=tenant, kind="modularity_drop",
                        value=drop, threshold=cfg.modularity_drop,
                        message=(f"tenant {tenant}: modularity fell "
                                 f"{drop:.4f} (> {cfg.modularity_drop:g}) "
                                 f"to {sample.modularity:.4f}")))
            if sample.disconnected_fraction:
                fired.append(Alert(
                    ts=sample.ts, tenant=tenant, kind="disconnected",
                    value=float(sample.disconnected_fraction), threshold=0.0,
                    message=(f"tenant {tenant}: disconnected-community "
                             f"fraction {sample.disconnected_fraction:.4f} "
                             f"> 0 — paper invariant violated")))
            if cfg.slo_p99_ms is not None:
                p99 = tl.p99_latency(cfg.latency_window)
                if p99 > cfg.slo_p99_ms:
                    if tenant not in self._burning:  # edge-triggered
                        self._burning.add(tenant)
                        fired.append(Alert(
                            ts=sample.ts, tenant=tenant, kind="slo_burn",
                            value=p99, threshold=cfg.slo_p99_ms,
                            message=(f"tenant {tenant}: p99 latency "
                                     f"{p99:.2f}ms burns the "
                                     f"{cfg.slo_p99_ms:g}ms SLO")))
                else:
                    self._burning.discard(tenant)

            for a in fired:
                self.alerts.append(a)
                self._alert_counts[a.kind] = \
                    self._alert_counts.get(a.kind, 0) + 1
            n_tenants = len(self._timelines)

        if self._m_samples is not None:
            self._m_samples.inc()
            self._g_tenants.set(n_tenants)
            if sample.modularity is not None:
                self._g_modularity.set(sample.modularity)
            if sample.disconnected_fraction is not None:
                self._g_disconnected.set(
                    float(sample.disconnected_fraction))
            for a in fired:
                self._m_alerts[a.kind].inc()
        return fired

    def timeline(self, tenant: Any) -> TenantTimeline | None:
        with self._lock:
            return self._timelines.get(tenant)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {
                "tenants": {str(t): tl.to_dict()
                            for t, tl in self._timelines.items()},
                "alert_counts": dict(self._alert_counts),
                "alerts": [a.to_dict() for a in list(self.alerts)[-16:]],
                "burning": sorted(str(t) for t in self._burning),
            }


def sample_from_result(result: Any, *, kind: str,
                       latency_ms: float) -> QualitySample:
    """Build a sample from a ``DetectionResult`` (quality optional)."""
    q = getattr(result, "quality", None)
    return QualitySample(
        ts=time.time(), kind=kind, latency_ms=float(latency_ms),
        modularity=getattr(q, "modularity", None),
        disconnected_fraction=getattr(q, "disconnected_fraction", None),
        communities=getattr(q, "num_communities", None),
        churn=getattr(q, "churn", None))
