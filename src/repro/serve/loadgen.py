"""Mixed cold/warm/delta load generation for the multi-tenant tier.

Shared by ``python -m repro.launch.serve --mode tenants`` and
``benchmarks/bench_serve_tenants.py`` (the CI SLO harness): builds
per-tenant evolving-graph traces, drives them from concurrent client
threads through a :class:`~repro.serve.service.TenantService`, retries
on :class:`~repro.serve.admission.Rejected` backpressure (honouring the
``retry_after_s`` hint), samples queue depth, and reports the SLO
surface — sustained aggregate edges/s, latency percentiles, queue depth,
rejection rate — plus the hard liveness invariant: **every admitted
request resolves** (zero stranded futures, zero drops without an
explicit rejection).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.serve.admission import Rejected


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Shape of the generated traffic.

    tenants: number of concurrent tenants (each one evolving graph).
    rounds: delta updates per tenant after the cold register.
    size / avg_degree / delta_edges: per-tenant ``evolving_sequence``
      trace parameters.
    refresh_every: every k-th round, tenants outside ``parity_tenants``
      issue a cold ``refresh`` instead of a delta update (the mixed
      cold/warm traffic leg).  0 disables refreshes.
    parity_tenants: the first k tenants never refresh, so their warm
      chains can be replayed solo and compared bit-for-bit.
    client_threads: concurrent client threads driving disjoint tenant
      subsets.
    max_retries: attempts per request under backpressure before the
      client gives up (counted, never silent).
    """
    tenants: int = 32
    rounds: int = 4
    size: int = 120
    avg_degree: float = 5.0
    delta_edges: int = 4
    refresh_every: int = 3
    parity_tenants: int = 4
    client_threads: int = 8
    max_retries: int = 200
    seed: int = 0


def build_traces(cfg: LoadConfig) -> dict:
    """Per-tenant (base graph, [deltas]) evolving traces."""
    from repro.graphgen import evolving_sequence
    return {f"tenant-{i:03d}": evolving_sequence(
        cfg.size, cfg.avg_degree, cfg.rounds, cfg.delta_edges,
        seed=cfg.seed + 17 * i)
        for i in range(cfg.tenants)}


def _submit_with_retry(fn, record, max_retries: int):
    """Call ``fn()`` (an admission attempt), sleeping out Rejected
    backpressure.  Returns the ticket; records retry count."""
    for attempt in range(max_retries):
        try:
            ticket = fn()
            record["retries"] += attempt
            return ticket
        except Rejected as rej:
            time.sleep(rej.retry_after_s)
    raise RuntimeError(f"request not admitted after {max_retries} retries")


def run_load(service, traces: dict, cfg: LoadConfig) -> tuple[list, dict]:
    """Drive the traces through ``service`` from concurrent clients.

    Every tenant: one cold register, then ``rounds`` requests — deltas
    (warm) except every ``refresh_every``-th round for non-parity
    tenants, which goes cold via ``refresh``.  Returns ``(records,
    summary)``: one record per resolved request, and the SLO summary.
    """
    tenant_ids = list(traces)
    parity = set(tenant_ids[: cfg.parity_tenants])
    counters = {"retries": 0, "give_ups": 0, "errors": 0}
    counters_lock = threading.Lock()
    records: list[dict] = []
    records_lock = threading.Lock()
    depth_samples: list[int] = []
    stop_sampling = threading.Event()

    def sample_depth() -> None:
        while not stop_sampling.is_set():
            depth_samples.append(service.admission.stats()["depth"])
            time.sleep(0.002)

    def wait_all(tickets: list) -> None:
        for tid, kind, ticket in tickets:
            exc = ticket.exception()
            rec = {"tenant": tid, "kind": kind,
                   "latency_s": ticket.latency_s,
                   "ok": exc is None}
            if exc is None:
                res = ticket.result()
                rec.update(edges=_edges_of(service, tid),
                           warm_started=bool(res.warm_started),
                           lpa_iterations=int(res.lpa_iterations))
            with records_lock:
                records.append(rec)
            if exc is not None:
                with counters_lock:
                    counters["errors"] += 1

    def client(my_tenants: list) -> None:
        local = {"retries": 0}
        try:
            tickets = []
            for tid in my_tenants:
                base, _deltas = traces[tid]
                tickets.append((tid, "register", _submit_with_retry(
                    lambda tid=tid, base=base: service.register(tid, base),
                    local, cfg.max_retries)))
            wait_all(tickets)   # registers settle before deltas apply
            for r in range(cfg.rounds):
                tickets = []
                for tid in my_tenants:
                    _base, deltas = traces[tid]
                    cold = (cfg.refresh_every
                            and tid not in parity
                            and r % cfg.refresh_every == cfg.refresh_every - 1)
                    if cold:
                        tickets.append((tid, "refresh", _submit_with_retry(
                            lambda tid=tid: service.refresh(tid),
                            local, cfg.max_retries)))
                    else:
                        tickets.append((tid, "update", _submit_with_retry(
                            lambda tid=tid, d=deltas[r]:
                            service.update(tid, d),
                            local, cfg.max_retries)))
                wait_all(tickets)
        except RuntimeError:
            with counters_lock:
                counters["give_ups"] += 1
        finally:
            with counters_lock:
                counters["retries"] += local["retries"]

    # disjoint tenant subsets per client thread
    chunks: list[list] = [[] for _ in range(cfg.client_threads)]
    for i, tid in enumerate(tenant_ids):
        chunks[i % cfg.client_threads].append(tid)

    sampler = threading.Thread(target=sample_depth, daemon=True)
    sampler.start()
    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(chunk,), daemon=True)
               for chunk in chunks if chunk]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    stop_sampling.set()
    sampler.join()

    stats = service.stats()
    lat = np.asarray([r["latency_s"] for r in records
                      if r["latency_s"] is not None]) * 1e3
    total_edges = sum(r.get("edges", 0) for r in records if r["ok"])
    adm = stats["admission"]
    # liveness: every admitted request resolved, one way or the other —
    # no stranded futures, no drops without an explicit rejection
    resolved = stats["completed"] + stats["failed"]
    summary = {
        "tenants": cfg.tenants,
        "rounds": cfg.rounds,
        "requests": len(records),
        "completed": stats["completed"],
        "failed": stats["failed"],
        "admitted": adm["accepted"],
        "resolved": resolved,
        "stranded": adm["accepted"] - resolved,
        "outstanding": stats["outstanding"],
        "rejections": adm["rejected"],
        "rejection_rate": adm["rejected"]
        / max(adm["rejected"] + adm["accepted"], 1),
        "retries": counters["retries"],
        "give_ups": counters["give_ups"],
        "errors": counters["errors"],
        "queue_depth_peak": adm["peak_depth"],
        "queue_depth_mean": float(np.mean(depth_samples))
        if depth_samples else 0.0,
        "warm_bytes_peak": stats["warm_bytes"]["peak"],
        "warm_budget": stats["warm_bytes"]["budget"],
        "spills": stats["spills"],
        "wall_s": wall_s,
        "edges_per_s": total_edges / max(wall_s, 1e-9),
        "p50_ms": float(np.percentile(lat, 50)) if len(lat) else 0.0,
        "p99_ms": float(np.percentile(lat, 99)) if len(lat) else 0.0,
        "mean_batch": stats["batcher"]["mean_batch"],
    }
    return records, summary


def _edges_of(service, tenant) -> int:
    try:
        return int(service.graph(tenant).num_edges)
    except KeyError:
        return 0


def replay_parity(traces: dict, parity_records: dict, engine_config) -> dict:
    """Solo-oracle replay for the parity tenants.

    Re-runs each parity tenant's exact op sequence (cold register, then
    warm delta updates with frontier seeding) through a fresh solo
    engine — no batching, no admission, no sharing — and returns the
    final labels per tenant.  The harness asserts these bit-identical to
    the service's committed labels: multiplexing over one engine changes
    latency, never results.
    """
    from repro.core.delta import affected_frontier, apply_delta
    from repro.engine import CompileCache, Engine
    out = {}
    for tid in parity_records:
        eng = Engine(engine_config, cache=CompileCache())
        base, deltas = traces[tid]
        labels = eng.fit(base).labels
        graph = base
        for d in deltas:
            graph = apply_delta(graph, d)
            init = labels
            if graph.n > len(init):
                init = np.concatenate([
                    init, np.arange(len(init), graph.n, dtype=np.int32)])
            front = affected_frontier(d, graph.n)
            labels = eng.fit(graph, init_labels=init,
                             init_active=front).labels
        out[tid] = labels
    return out
