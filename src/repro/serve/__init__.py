"""Multi-tenant serving tier: admission, fairness, warm-state budget,
snapshot/restore, per-tenant quality/SLO health — N concurrent tenants
over one shared Engine.

    from repro.serve import TenantService, ServiceConfig, Rejected
"""
from repro.serve.admission import AdmissionQueue, Rejected
from repro.serve.health import (
    Alert,
    HealthConfig,
    HealthMonitor,
    QualitySample,
    TenantTimeline,
)
from repro.serve.service import ServiceConfig, TenantService, TenantTicket

__all__ = [
    "AdmissionQueue",
    "Rejected",
    "ServiceConfig",
    "TenantService",
    "TenantTicket",
    "Alert",
    "HealthConfig",
    "HealthMonitor",
    "QualitySample",
    "TenantTimeline",
]
