"""Multi-tenant community-detection serving over one shared Engine.

:class:`TenantService` multiplexes N tenants — each an evolving-graph
:class:`~repro.launch.stream.StreamSession` — over **one** shared
:class:`~repro.engine.Engine` through **one** shared
:class:`~repro.launch.microbatch.MicroBatcher`, so concurrent tenants'
updates coalesce into single ``fit_many`` device dispatches while every
tenant keeps its own warm labels, versions, and counters.  Per-member
results stay bit-identical to a solo warm ``fit`` (the engine's parity
contract, extended to this path by tests/test_serve_tenants.py).

The moving parts:

* **Admission** (:mod:`repro.serve.admission`): every request enters a
  bounded global queue with per-tenant FIFOs drained round-robin; a full
  queue rejects with a ``retry_after_s`` hint (explicit backpressure —
  the queue never grows without bound, and an admitted request always
  resolves).  One request per tenant is in flight at a time, which both
  preserves per-tenant delta order and makes the rotation fair.
* **Dispatch**: a single dispatcher thread takes admitted requests,
  applies deltas (splice-patch vs rebuild via the engine's measured
  churn threshold — the per-tenant ``StreamSession`` owns that), and
  submits to the shared batcher *without waiting*: settlement happens in
  a completion callback, so up to ``max_batch`` different tenants ride
  one device dispatch.
* **Warm-state budget**: every tenant's committed labels are charged to
  a shared :class:`~repro.partition.slices.MemoryLedger`.  When a commit
  would exceed the budget, the least-recently-served tenants' warm
  labels **spill** (drop to cold — correctness is unaffected, the next
  update just re-detects from singletons) until the newcomer fits.  The
  ledger's ``peak`` is the asserted bound in the load harness.
* **Snapshot/restore** (:mod:`repro.checkpoint.manager`): the committed
  per-tenant labels + graph fingerprints write as one atomic checkpoint;
  a restarted service re-seeds them (fingerprint-verified) so tenants
  resume *warm* — no cold re-detection storm after a restart.

    eng = Engine(EngineConfig())
    svc = TenantService(eng, ServiceConfig(queue_capacity=64,
                                           warm_budget="1MB"))
    svc.register("acme", graph).result()
    ticket = svc.update("acme", delta)       # async; Rejected => backoff
    res = ticket.result()
    svc.snapshot(CheckpointManager(path))
    svc.close()
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future

import numpy as np

from repro.core.graph import Graph, graph_fingerprint
from repro.launch.microbatch import MicroBatcher
from repro.launch.stream import PreparedUpdate, StreamSession, StreamState
from repro.obs import REGISTRY, span
from repro.partition.plan import parse_bytes
from repro.partition.slices import MemoryLedger
from repro.serve.health import HealthConfig, HealthMonitor, sample_from_result


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs for :class:`TenantService`.

    queue_capacity / retry_after_s: the admission bound and the hint
      attached to :class:`~repro.serve.admission.Rejected`.
    warm_budget: global byte budget for tenants' warm labels (bytes or
      ``"64KB"``-style; None = unbounded).  Over-budget commits spill
      the least-recently-served tenants to cold.
    max_batch / batch_timeout_ms / backend: shared micro-batcher knobs.
    warm / frontier: per-tenant session semantics (see
      :class:`~repro.launch.stream.StreamSession`).
    health: drift/SLO thresholds for the per-tenant quality timelines
      (:class:`~repro.serve.health.HealthMonitor`).  Samples carry
      quality fields only when the shared engine runs with
      ``EngineConfig.quality != "off"``; latency SLO burn works either
      way.
    served_label_cap: how many tenants get a dedicated
      ``admission.served.<tenant>`` registry counter before the rest
      share ``admission.served.other`` (cardinality bound; exact
      per-tenant counts stay in ``stats()``).
    """
    queue_capacity: int = 64
    retry_after_s: float = 0.05
    warm_budget: int | str | None = None
    max_batch: int = 8
    batch_timeout_ms: float = 2.0
    backend: str | None = None
    warm: bool = True
    frontier: bool = True
    health: "HealthConfig | None" = None
    served_label_cap: int = 16


class TenantTicket:
    """Client handle for one admitted request; resolves to the
    :class:`~repro.engine.DetectionResult` (or the request's exception)."""

    def __init__(self, tenant, kind: str):
        self.tenant = tenant
        self.kind = kind                    # register | update | refresh
        self.submitted = time.perf_counter()
        self.latency_s: float | None = None
        self._future: Future = Future()

    def result(self, timeout: float | None = None):
        return self._future.result(timeout)

    def exception(self, timeout: float | None = None):
        return self._future.exception(timeout)

    def done(self) -> bool:
        return self._future.done()


@dataclasses.dataclass
class _Request:
    tenant: object
    kind: str                   # register | update | refresh
    payload: object             # Graph | GraphDelta | None
    ticket: TenantTicket


class TenantService:
    """N tenants, one engine, one batcher — admission-controlled.

    ``engine`` is shared by every tenant (its compile + warm caches are
    thread-safe); pass ``batcher`` to share a scheduler with other
    services, otherwise one is owned.  All public methods are
    thread-safe: many client threads may register/update concurrently.
    """

    _STREAM = "g"   # the single stream key inside each tenant's session

    def __init__(self, engine, config: ServiceConfig | None = None,
                 batcher: MicroBatcher | None = None):
        self.engine = engine
        self.config = config if config is not None else ServiceConfig()
        cfg = self.config
        # Per-instance registry scope; children hang off it so the
        # hierarchy reads serve.admission.*, serve.warm.*, serve.batcher.*
        # (a shared batcher keeps whatever scope its owner gave it).
        self._obs = REGISTRY.scope("serve")
        self._own_batcher = batcher is None
        self.batcher = batcher if batcher is not None else MicroBatcher(
            engine, max_batch=cfg.max_batch,
            batch_timeout_ms=cfg.batch_timeout_ms, backend=cfg.backend,
            scope=self._obs.scope("batcher"))
        from repro.serve.admission import AdmissionQueue
        self.admission = AdmissionQueue(cfg.queue_capacity,
                                        retry_after_s=cfg.retry_after_s,
                                        scope=self._obs.scope("admission"),
                                        served_label_cap=cfg.served_label_cap)
        self.health = HealthMonitor(cfg.health or HealthConfig(),
                                    scope=self._obs.scope("health"))
        budget = None if cfg.warm_budget is None \
            else parse_bytes(cfg.warm_budget)
        self.ledger = MemoryLedger(budget, scope=self._obs.scope("warm"))

        self._lock = threading.RLock()
        self._sessions: dict = {}               # tenant -> StreamSession
        self._warm_lru: OrderedDict = OrderedDict()  # tenant -> charged bytes
        self._latencies: list[float] = []
        self._outstanding = 0
        self._done_cond = threading.Condition(self._lock)
        self.completed = 0
        self.failed = 0
        self.spills = 0       # warm labels dropped to fit the budget
        self.uncached = 0     # commits too large to cache even after spill
        self.restored = 0     # tenants re-seeded warm from a checkpoint
        self._m_completed = self._obs.counter("completed")
        self._m_failed = self._obs.counter("failed")
        self._m_spills = self._obs.counter("spills")
        self._m_uncached = self._obs.counter("uncached")
        self._m_restored = self._obs.counter("restored")
        self._g_outstanding = self._obs.gauge("outstanding")
        self._g_tenants = self._obs.gauge("tenants")
        self._h_latency = self._obs.histogram(
            "latency_ms", (0.5, 1, 2, 5, 10, 25, 50, 100, 250, 1000, 5000))

        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            daemon=True,
                                            name="tenant-dispatcher")
        self._dispatcher.start()

    # --- lifecycle ---

    def __enter__(self) -> "TenantService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, wait: bool = True) -> None:
        """Stop admitting; drain every outstanding request, then stop."""
        self.admission.close()
        if wait:
            with self._done_cond:
                while self._outstanding > 0:
                    self._done_cond.wait(timeout=1.0)
        self._dispatcher.join()
        if self._own_batcher:
            self.batcher.close()
        # drop this instance's metrics (children release by prefix)
        self._obs.release()

    # --- client surface ---

    def register(self, tenant, graph: Graph) -> TenantTicket:
        """Admit a tenant with its initial graph (cold first detection).

        Raises :class:`~repro.serve.admission.Rejected` under
        backpressure and ``ValueError`` on duplicate registration.
        """
        with self._lock:
            if tenant in self._sessions:
                raise ValueError(f"tenant {tenant!r} already registered")
            # per-tenant session sharing the service batcher; its own
            # close() is a no-op for shared batchers
            self._sessions[tenant] = StreamSession(
                self.engine, warm=self.config.warm,
                frontier=self.config.frontier, batcher=self.batcher)
        return self._admit(_Request(tenant, "register", graph,
                                    TenantTicket(tenant, "register")))

    def update(self, tenant, delta) -> TenantTicket:
        """Admit one delta update (warm incremental re-detection)."""
        self._known(tenant)
        return self._admit(_Request(tenant, "update", delta,
                                    TenantTicket(tenant, "update")))

    def refresh(self, tenant) -> TenantTicket:
        """Admit a cold full re-detection of the tenant's current graph
        (ignores warm labels — the periodic drift-correction request)."""
        self._known(tenant)
        return self._admit(_Request(tenant, "refresh", None,
                                    TenantTicket(tenant, "refresh")))

    def labels(self, tenant) -> np.ndarray | None:
        with self._lock:
            st = self._state(tenant)
            return None if st is None else st.labels

    def graph(self, tenant) -> Graph:
        with self._lock:
            st = self._state(tenant)
            if st is None:
                raise KeyError(f"tenant {tenant!r} has no committed graph")
            return st.graph

    def tenants(self) -> list:
        with self._lock:
            return list(self._sessions)

    # --- internals ---

    def _known(self, tenant) -> None:
        with self._lock:
            if tenant not in self._sessions:
                raise KeyError(f"tenant {tenant!r} is not registered")

    def _state(self, tenant) -> StreamState | None:
        sess = self._sessions.get(tenant)
        if sess is None:
            return None
        return sess.streams.get(self._STREAM)

    def _admit(self, req: _Request) -> TenantTicket:
        with span("serve.admit", kind=req.kind):
            try:
                self.admission.offer(req.tenant, req)
            except BaseException:
                if req.kind == "register":
                    # a rejected register never happened: allow the retry
                    with self._lock:
                        self._sessions.pop(req.tenant, None)
                raise
        with self._lock:
            self._outstanding += 1
            self._g_outstanding.set(self._outstanding)
            self._g_tenants.set(len(self._sessions))
        return req.ticket

    def _dispatch_loop(self) -> None:
        admission = self.admission
        while True:
            got = admission.take(timeout=0.05)
            if got is None:
                if admission.drained():
                    break
                continue
            tenant, req = got
            try:
                self._launch(req)
            except BaseException as e:
                # launch-side failure (bad delta, unregistered stream,
                # closed batcher): this request fails, siblings don't
                self._finish(req, None, e)

    def _launch(self, req: _Request) -> None:
        sess = self._sessions[req.tenant]
        with span("serve.launch", kind=req.kind):
            if req.kind == "register":
                prep: object = req.payload        # the initial Graph
                sub = self.batcher.submit(req.payload)
            elif req.kind == "update":
                # prepare under the service lock: a concurrent commit may
                # spill *this* tenant's labels mid-prepare otherwise
                with self._lock:
                    prep = sess.prepare_update(self._STREAM, req.payload)
                sub = self.batcher.submit(prep.graph,
                                          init_labels=prep.init_labels,
                                          init_active=prep.init_active)
            else:  # refresh: cold re-fit of the committed graph
                with self._lock:
                    prep = sess.streams[self._STREAM].graph
                sub = self.batcher.submit(prep)
        sub.add_done_callback(
            lambda s, req=req, prep=prep: self._settle(req, prep, s))

    def _settle(self, req: _Request, prep, sub) -> None:
        """Completion callback (runs on the batcher worker): commit the
        tenant's state and resolve the client ticket.  Defensive to the
        bone — any exception here must land in the ticket, never strand
        it."""
        try:
            with span("serve.settle", kind=req.kind):
                exc = sub.exception()
                if exc is not None:
                    self._finish(req, None, exc)
                    return
                res = sub.result()
                with self._lock:
                    sess = self._sessions[req.tenant]
                    if isinstance(prep, PreparedUpdate):
                        sess.commit_update(self._STREAM, prep, res)
                    elif req.kind == "register":
                        sess.streams[self._STREAM] = StreamState(
                            graph=prep, labels=res.labels)
                    else:  # refresh: same graph, fresh cold labels
                        st = sess.streams[self._STREAM]
                        st.labels = res.labels
                    self._account_warm(req.tenant)
                self._finish(req, res, None)
        except BaseException as e:
            self._finish(req, None, e)

    def _finish(self, req: _Request, res, exc) -> None:
        now = time.perf_counter()
        with self._lock:
            req.ticket.latency_s = now - req.ticket.submitted
            if exc is None:
                self.completed += 1
                self._latencies.append(req.ticket.latency_s)
                self._m_completed.inc()
                self._h_latency.observe(req.ticket.latency_s * 1e3)
            else:
                self.failed += 1
                self._m_failed.inc()
            self._outstanding -= 1
            self._g_outstanding.set(self._outstanding)
            self._done_cond.notify_all()
        if exc is None:
            # Feed the tenant's quality/SLO timeline (drift detection);
            # outside self._lock — the monitor has its own, and per-tenant
            # ordering holds because one request per tenant is in flight.
            self.health.record(req.tenant, sample_from_result(
                res, kind=req.kind, latency_ms=req.ticket.latency_s * 1e3))
        # release before resolving: the tenant's next queued request can
        # start coalescing into the batch the client's reaction would miss
        self.admission.release(req.tenant)
        if exc is None:
            req.ticket._future.set_result(res)
        else:
            req.ticket._future.set_exception(exc)

    # --- warm-state budget (callers hold self._lock) ---

    def _account_warm(self, tenant) -> None:
        """Charge the tenant's committed labels to the shared ledger,
        spilling least-recently-served tenants' warm labels to fit."""
        st = self._state(tenant)
        old = self._warm_lru.pop(tenant, 0)
        if old:
            self.ledger.release(old)
        if st is None or st.labels is None:
            return
        nbytes = int(st.labels.nbytes)
        while not self.ledger.try_acquire(nbytes, f"warm labels {tenant!r}"):
            victim = next(iter(self._warm_lru), None)
            if victim is None:
                # nothing left to spill: this tenant runs cold next time
                st.labels = None
                self.uncached += 1
                self._m_uncached.inc()
                return
            self._spill(victim)
        self._warm_lru[tenant] = nbytes   # most-recently served

    def _spill(self, victim) -> None:
        nbytes = self._warm_lru.pop(victim)
        self.ledger.release(nbytes)
        st = self._state(victim)
        if st is not None:
            st.labels = None              # cold next update; still correct
        self.spills += 1
        self._m_spills.inc()

    # --- snapshot / restore ---

    def snapshot(self, manager, step: int | None = None) -> dict:
        """Write every tenant's committed warm state as one atomic
        checkpoint (labels + graph fingerprint + version).

        ``manager`` is a :class:`repro.checkpoint.CheckpointManager`;
        the write inherits its atomic tmp+rename and keep-k GC.  Tenants
        whose labels are currently spilled snapshot as cold (their
        fingerprint still records membership).  Returns the manifest
        metadata that was saved.
        """
        with self._lock:
            arrays: dict[str, np.ndarray] = {}
            meta: dict[str, dict] = {}
            for i, tenant in enumerate(sorted(self._sessions, key=str)):
                st = self._state(tenant)
                if st is None:
                    continue                       # register still in flight
                entry = {"index": i, "version": st.version,
                         "fingerprint": list(graph_fingerprint(st.graph)),
                         "warm": st.labels is not None}
                if st.labels is not None:
                    arrays[f"t{i}/labels"] = st.labels
                meta[str(tenant)] = entry
            if step is None:
                step = self.completed
        manager.save(step, arrays, extra={"tenants": meta})
        return {"step": step, "tenants": meta}

    def restore(self, manager, graphs: dict, step: int | None = None) -> dict:
        """Re-seed tenants from a checkpoint — warm across restarts.

        ``graphs`` maps tenant id -> its current :class:`Graph` (the
        graphs themselves live in the clients / the CSR store; the
        checkpoint holds only labels + fingerprints).  A tenant whose
        graph fingerprint matches the snapshot is registered *without
        any fit*, its warm labels re-attached — the next update is a
        warm incremental re-detection, exactly as if the process never
        restarted.  Mismatched or snapshot-cold tenants are reported
        (register them cold via :meth:`register`).  Returns a report:
        ``{"restored": [...], "mismatched": [...], "cold": [...],
        "unknown": [...]}``.
        """
        named, _step, extra = manager.load_named(step)
        meta = extra.get("tenants", {})
        report: dict[str, list] = {"restored": [], "mismatched": [],
                                   "cold": [], "unknown": []}
        for tenant, graph in graphs.items():
            entry = meta.get(str(tenant))
            if entry is None:
                report["unknown"].append(tenant)
                continue
            key = f"t{entry['index']}/labels"
            if not entry.get("warm") or key not in named:
                report["cold"].append(tenant)
                continue
            if list(graph_fingerprint(graph)) != list(entry["fingerprint"]):
                report["mismatched"].append(tenant)
                continue
            labels = np.asarray(named[key], dtype=np.int32)
            with self._lock:
                if tenant in self._sessions:
                    raise ValueError(
                        f"tenant {tenant!r} already registered")
                sess = StreamSession(
                    self.engine, warm=self.config.warm,
                    frontier=self.config.frontier, batcher=self.batcher)
                sess.streams[self._STREAM] = StreamState(
                    graph=graph, labels=labels,
                    version=int(entry.get("version", 0)))
                self._sessions[tenant] = sess
                self._account_warm(tenant)
                self.restored += 1
                self._m_restored.inc()
                self._g_tenants.set(len(self._sessions))
            report["restored"].append(tenant)
        return report

    # --- observability ---

    def stats(self) -> dict:
        """Service counters + admission + ledger + batcher stats."""
        with self._lock:
            lat_ms = np.asarray(self._latencies) * 1e3
            out = {
                "tenants": len(self._sessions),
                "outstanding": self._outstanding,
                "completed": self.completed,
                "failed": self.failed,
                "spills": self.spills,
                "uncached": self.uncached,
                "restored": self.restored,
                "warm_cached_tenants": len(self._warm_lru),
                "warm_bytes": {**self.ledger.stats()},
            }
        if len(lat_ms):
            out.update(p50_ms=float(np.percentile(lat_ms, 50)),
                       p99_ms=float(np.percentile(lat_ms, 99)),
                       mean_ms=float(np.mean(lat_ms)))
        else:
            out.update(p50_ms=0.0, p99_ms=0.0, mean_ms=0.0)
        out["admission"] = self.admission.stats()
        out["batcher"] = self.batcher.stats()
        out["health"] = self.health.stats()
        return out
