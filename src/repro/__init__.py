"""GSL-LPA reproduction: fast label propagation with connected communities.

Top-level convenience surface (lazy — importing :mod:`repro` stays
cheap; jax and the engine load on first attribute access):

    from repro import Engine, EngineConfig, load_graph, datasets

    eng = Engine(EngineConfig(backend="auto"))
    result = eng.fit("com-orkut.mtx")          # parse-once file ingest
    result = eng.fit(datasets.get("web_rmat"))  # registry lookup

Submodules keep their own focused surfaces: :mod:`repro.core` (the
algorithm), :mod:`repro.engine` (execution strategies + caches),
:mod:`repro.io` (real-graph ingestion), :mod:`repro.graphgen`
(synthetic suites), :mod:`repro.launch` (CLIs).
"""
from __future__ import annotations

_LAZY = {
    # engine surface
    "Engine": ("repro.engine", "Engine"),
    "EngineConfig": ("repro.engine", "EngineConfig"),
    "DetectionResult": ("repro.engine", "DetectionResult"),
    # core graph + deltas
    "Graph": ("repro.core.graph", "Graph"),
    "build_graph": ("repro.core.graph", "build_graph"),
    "graph_fingerprint": ("repro.core.graph", "graph_fingerprint"),
    "GraphDelta": ("repro.core.delta", "GraphDelta"),
    "apply_delta": ("repro.core.delta", "apply_delta"),
    "apply_delta_patch": ("repro.core.delta", "apply_delta_patch"),
    "affected_frontier": ("repro.core.delta", "affected_frontier"),
    # facades
    "gsl_lpa": ("repro.core.gsl", "gsl_lpa"),
    "gve_lpa": ("repro.core.gsl", "gve_lpa"),
    "modularity": ("repro.core.modularity", "modularity"),
    # io / ingestion
    "load_graph": ("repro.io.store", "load_graph"),
    "open_graph": ("repro.io.store", "open_graph"),
    "PreprocessOptions": ("repro.io.preprocess", "PreprocessOptions"),
    "CsrStore": ("repro.io.store", "CsrStore"),
    "datasets": ("repro.io", "datasets"),
    # out-of-core partitioned detection
    "fit_out_of_core": ("repro.partition.ooc", "fit_out_of_core"),
    "plan_partitions": ("repro.partition.plan", "plan_partitions"),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    import importlib
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache: next access skips the import
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
