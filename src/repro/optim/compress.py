"""Gradient compression for cross-pod reduction (distributed-optimization).

Two mechanisms (DESIGN.md §6):

1. **bf16 reduction** — free with bf16 params (grads are bf16); halves
   cross-pod all-reduce bytes vs fp32.  Always on in this framework.
2. **int8 + error feedback** — per-tensor symmetric quantisation with a
   residual carried to the next step, for the *cross-pod* hop only (the
   slowest link).  Convergence-safe: EF-SGD-style, the quantisation error is
   re-injected so the compressed reducer is unbiased over time.

``ef_int8_reduce`` is expressed with shard_map over the 'pod' axis so the
int8 all-reduce is visible in lowered HLO (the §Perf collective lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, error: jnp.ndarray):
    """Error-feedback compress: returns (q, scale, new_error)."""
    corrected = g.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected)
    new_error = corrected - dequantize_int8(q, scale)
    return q, scale, new_error


def make_ef_int8_pod_reduce(mesh: Mesh):
    """Cross-pod mean of per-pod gradients with int8+EF compression.

    g, error: arrays sharded with P('pod', ...) on the leading axis is NOT
    required — inputs are per-pod *replicated-within-pod* values; shard_map
    binds only the 'pod' axis and all-reduces the int8 payload across it.
    """
    assert "pod" in mesh.axis_names

    def reduce_fn(g, error):
        q, scale, new_error = ef_compress(g, error)
        # int8 payload all-reduce across pods (sum), fp32 scale all-gather
        qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
        ssum = jax.lax.psum(scale, "pod")  # scales ~equal; mean scale
        npod = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
        mean = qsum.astype(jnp.float32) * (ssum / npod) / npod
        return mean.astype(g.dtype), new_error

    # everything replicated on other axes; 'pod' carries distinct values
    return shard_map(reduce_fn, mesh=mesh,
                     in_specs=(P(), P()), out_specs=(P(), P()),
                     check_vma=False)
