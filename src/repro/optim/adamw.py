"""AdamW with dtype-configurable state (bf16 states for the 480B MoE cells)
and global-norm clipping.  Plain pytree functions — no optax dependency.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state: AdamWState, params, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state.count + 1
    b1c = 1.0 - b1 ** count.astype(jnp.float32)
    b2c = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1.0 - b1) * gf
        v_new = b2 * v32 + (1.0 - b2) * jnp.square(gf)
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + weight_decay * p32)
        return p_new.astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, count=count), \
        {"grad_norm": gnorm}
