"""Jitted train / prefill / decode step builders with full sharding wiring.

Every builder returns (jitted_fn, shardings...) where the jitted function is
ready both for real execution (reduced configs on CPU) and for AOT
``.lower(...).compile()`` against ShapeDtypeStructs (the 512-device dry-run).

Train step semantics:
  * loss in fp32, params/grads bf16 (bf16 gradient reduction — the free
    2x collective compression, DESIGN.md §6);
  * grads constrained to the ZeRO-1 shardings => XLA emits reduce-scatter
    instead of all-reduce, optimizer update runs on 1/DP of the state,
    updated params all-gather back;
  * optional microbatch gradient accumulation (fp32 accumulator) via scan;
  * remat policy comes from the arch config (scan-over-groups boundary).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES
from repro.models import transformer as T
from repro.models.common import abstract_from_specs, logical_axes
from repro.optim import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.parallel.api import use_rules
from repro.parallel.rules import (
    cache_logical_axes,
    data_axes,
    make_rules,
    param_shardings,
    zero1_shardings,
)


def state_shardings(cfg: ArchConfig, mesh: Mesh, shape: str):
    """(rules, param shardings, optimizer-state shardings, abstract params)."""
    specs = T.model_specs(cfg)
    axes = logical_axes(specs)
    rules = make_rules(mesh, cfg, shape)
    psh = param_shardings(rules, axes)
    abstract = abstract_from_specs(specs)
    zsh = zero1_shardings(rules, axes, abstract)
    osh = AdamWState(m=zsh, v=zsh, count=NamedSharding(mesh, P()))
    return rules, psh, osh, abstract


def batch_shardings(cfg: ArchConfig, mesh: Mesh, shape: str, batch_tree):
    """Batch arrays shard on the leading (batch) dim over ('pod','data')."""
    sp = SHAPES[shape]
    daxes = data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    lead = daxes if sp.global_batch % dp == 0 else None
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P(lead, *([None] * (len(x.shape) - 1)))),
        batch_tree)


def make_train_step(cfg: ArchConfig, mesh: Mesh, shape: str = "train_4k",
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, microbatch: int | None = None,
                    donate: bool = True):
    """Returns (jitted step, rules, psh, osh).

    step(params, opt_state, batch, step_idx) ->
        (params, opt_state, {"loss", "grad_norm", "lr"})
    """
    rules, psh, osh, abstract = state_shardings(cfg, mesh, shape)
    state_dtype = (jnp.bfloat16 if cfg.optimizer_state_dtype == "bfloat16"
                   else jnp.float32)
    zsh = osh.m

    def compute_grads(params, batch):
        if microbatch and microbatch > 1:
            def micro(acc, mb):
                loss, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, mb))(params)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / microbatch,
                    acc, g)
                return acc, loss
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatch, -1) + x.shape[1:]), batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, acc0, mbs)
            return jnp.mean(losses), jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, params)
        return jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch))(params)

    def step_fn(params, opt_state, batch, step_idx):
        with use_rules(rules):
            loss, grads = compute_grads(params, batch)
            # ZeRO-1: reduce-scatter gradients onto the state sharding
            grads = jax.lax.with_sharding_constraint(grads, zsh)
            lr = cosine_schedule(step_idx, peak_lr=peak_lr,
                                 warmup_steps=warmup,
                                 total_steps=total_steps)
            new_params, new_opt, metrics = adamw_update(
                grads, opt_state, params, lr)
            metrics.update(loss=loss, lr=lr)
            return new_params, new_opt, metrics

    bsh = None  # inferred from inputs; dry-run passes explicit shardings
    jitted = jax.jit(
        step_fn,
        in_shardings=(psh, osh, bsh, None),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, rules, psh, osh

    # NOTE: state_dtype is applied by the caller at adamw_init time.


def init_opt_state(cfg: ArchConfig, params) -> AdamWState:
    dtype = (jnp.bfloat16 if cfg.optimizer_state_dtype == "bfloat16"
             else jnp.float32)
    return adamw_init(params, dtype)


def abstract_opt_state(cfg: ArchConfig, abstract_params) -> AdamWState:
    dtype = (jnp.bfloat16 if cfg.optimizer_state_dtype == "bfloat16"
             else jnp.float32)
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
                     abstract_params)
    return AdamWState(m=z, v=jax.tree.map(lambda x: x, z),
                      count=jax.ShapeDtypeStruct((), jnp.int32))


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, shape: str):
    """prefill(params, batch) -> (last logits, caches)."""
    sp = SHAPES[shape]
    rules, psh, _osh, _ = state_shardings(cfg, mesh, shape)
    s_max = sp.seq_len

    def fn(params, batch):
        with use_rules(rules):
            return T.prefill(cfg, params, batch, s_max)

    caches = T.init_decode_caches(cfg, sp.global_batch, s_max, abstract=True)
    cax = cache_logical_axes(cfg, caches)
    csh = jax.tree.map(lambda ax: rules.sharding(tuple(ax)), cax,
                       is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(fn, in_shardings=(psh, None),
                     out_shardings=(None, csh))
    return jitted, rules, psh, csh


def make_decode_step(cfg: ArchConfig, mesh: Mesh, shape: str,
                     donate: bool = True):
    """decode(params, caches, batch) -> (logits, caches)."""
    sp = SHAPES[shape]
    rules, psh, _osh, _ = state_shardings(cfg, mesh, shape)

    def fn(params, caches, batch):
        with use_rules(rules):
            return T.decode_step(cfg, params, caches, batch)

    caches = T.init_decode_caches(cfg, sp.global_batch, sp.seq_len,
                                  abstract=True)
    cax = cache_logical_axes(cfg, caches)
    csh = jax.tree.map(lambda ax: rules.sharding(tuple(ax)), cax,
                       is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(fn, in_shardings=(psh, csh, None),
                     out_shardings=(None, csh),
                     donate_argnums=(1,) if donate else ())
    return jitted, rules, psh, csh
