from repro.train.steps import (  # noqa: F401
    batch_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_shardings,
)
