"""Observability CLI: run a workload, dump the unified metrics
registry, export spans as a Chrome trace, print convergence profiles,
or watch a live metrics endpoint top-style.

    python -m repro.launch.obs                      # quick fit + registry dump
    python -m repro.launch.obs --profile full       # + split-phase curve
    python -m repro.launch.obs --graph web.mtx      # profile a real graph
    python -m repro.launch.obs --workload audit     # every dispatch family
    python -m repro.launch.obs --trace trace.json   # chrome://tracing / Perfetto
    python -m repro.launch.obs --json obs.json      # machine-readable snapshot
    python -m repro.launch.obs --workload top \\
        --endpoint http://127.0.0.1:9100            # live snapshot loop

The trace JSON loads directly into ``chrome://tracing`` or
https://ui.perfetto.dev; the registry dump is the same ``snapshot()``
surface every component's ``stats()`` dict is a view of.  The ``top``
workload polls a ``serve --metrics-port`` endpoint's ``/metrics.json``
(or the in-process registry, for tests) and renders the busiest metrics
sorted by activity — histograms by observation count, counters/gauges by
value.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.obs import REGISTRY, TRACER


def _print_profile(profile) -> None:
    for phase in (profile.propagation, profile.split):
        if phase is None:
            continue
        print(f"[obs] {phase.phase} curve ({phase.num_sub_sweeps} sub-sweeps "
              f"over n={profile.n}):")
        print(f"  {'sweep':>5} {'active':>8} {'changed':>8} {'decay':>7}")
        for s, a, c in zip(phase.sweep, phase.active, phase.changed):
            decay = a / profile.n if profile.n else 0.0
            print(f"  {int(s):>5} {int(a):>8} {int(c):>8} {decay:>7.3f}")


def _fit_workload(a) -> dict:
    from repro.engine import CompileCache, Engine, EngineConfig

    if a.graph:
        from repro.io import load_graph
        graph = load_graph(a.graph)
    else:
        from repro.graphgen import erdos_renyi
        graph = erdos_renyi(a.n, a.degree, seed=a.seed)
    eng = Engine(EngineConfig(backend=a.backend, split=a.split,
                              profile=a.profile), cache=CompileCache())
    r = eng.fit(graph)
    print(f"[obs] fit n={graph.n} m={graph.num_edges} backend={r.backend} "
          f"split={a.split}: {r.num_communities} communities in "
          f"{r.lpa_iterations} lpa + {r.split_iterations} split iterations")
    if r.profile is not None:
        _print_profile(r.profile)
    return {"profile": r.profile.to_dict() if r.profile else None}


def _audit_workload(a) -> dict:
    from repro.analysis.workload import run_workload
    coverage = run_workload()
    print(f"[obs] audit workload coverage: "
          + " ".join(f"{k}={v}" for k, v in sorted(coverage.items())))
    return {"coverage": coverage}


def _activity(value) -> float:
    """Sort key for top mode: histograms by count, scalars by magnitude."""
    if isinstance(value, dict):
        return float(value.get("count", 0))
    try:
        return abs(float(value))
    except (TypeError, ValueError):
        return 0.0


def render_top(snapshot: dict, limit: int = 20) -> str:
    """One top-style frame over a registry snapshot dict."""
    rows = sorted(snapshot.items(), key=lambda kv: (-_activity(kv[1]), kv[0]))
    lines = [f"{'metric':<48} {'value/count':>12} {'mean':>10} {'p99':>10}"]
    for name, v in rows[:limit]:
        if isinstance(v, dict):  # histogram summary
            lines.append(f"{name:<48} {v['count']:>12} "
                         f"{v['mean']:>10.4g} {v['p99']:>10.4g}")
        else:
            sv = f"{v:.6g}" if isinstance(v, float) else str(v)
            lines.append(f"{name:<48} {sv:>12} {'-':>10} {'-':>10}")
    if len(rows) > limit:
        lines.append(f"... {len(rows) - limit} more metrics")
    return "\n".join(lines)


def run_top(endpoint: str | None = None, every_s: float = 2.0,
            iterations: int = 0, limit: int = 20, registry=None,
            out=print) -> int:
    """Live snapshot loop (``--workload top``).

    ``endpoint`` polls a :class:`repro.obs.MetricsServer`'s
    ``/metrics.json`` route; without one the in-process registry is
    rendered (what a test or an embedded run wants).  ``iterations=0``
    loops until interrupted.  Returns the number of frames rendered.
    """
    frames = 0
    while True:
        if endpoint is not None:
            import urllib.request
            with urllib.request.urlopen(
                    endpoint.rstrip("/") + "/metrics.json",
                    timeout=10) as resp:
                snapshot = json.loads(resp.read().decode())
        else:
            snapshot = (registry if registry is not None
                        else REGISTRY).snapshot()
        frames += 1
        src = endpoint or "in-process registry"
        out(f"[obs top] frame {frames} ({src}, {len(snapshot)} metrics)")
        out(render_top(snapshot, limit))
        if iterations and frames >= iterations:
            return frames
        try:
            time.sleep(every_s)
        except KeyboardInterrupt:
            return frames


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.obs",
        description=__doc__.splitlines()[0])
    ap.add_argument("--workload", choices=("fit", "audit", "top"),
                    default="fit",
                    help="fit: one profiled detection; audit: the full "
                         "dispatch-family sweep from repro.analysis.workload; "
                         "top: live metric snapshots from --endpoint (or "
                         "the in-process registry)")
    ap.add_argument("--graph", default=None, metavar="PATH",
                    help="fit workload: real graph file (.mtx / SNAP edge "
                         "list) instead of a synthetic one")
    ap.add_argument("--n", type=int, default=600,
                    help="fit workload: synthetic graph size")
    ap.add_argument("--degree", type=float, default=6.0,
                    help="fit workload: synthetic average degree")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--split", default="lp",
                    choices=("none", "lp", "lpp", "bfs_host"))
    ap.add_argument("--profile", default="full",
                    choices=("off", "convergence", "full"),
                    help="fit workload: convergence-profile mode")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write spans as Chrome-trace JSON")
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="write registry snapshot (+ profile) as JSON")
    ap.add_argument("--endpoint", default=None, metavar="URL",
                    help="top workload: serve --metrics-port base URL "
                         "(polls /metrics.json); default: the in-process "
                         "registry")
    ap.add_argument("--every-s", type=float, default=2.0,
                    help="top workload: refresh interval")
    ap.add_argument("--iterations", type=int, default=0,
                    help="top workload: frames to render (0 = until ^C)")
    ap.add_argument("--limit", type=int, default=20,
                    help="top workload: rows per frame")
    a = ap.parse_args(argv)

    if a.workload == "top":
        run_top(endpoint=a.endpoint, every_s=a.every_s,
                iterations=a.iterations, limit=a.limit)
        return 0

    extra = _audit_workload(a) if a.workload == "audit" else _fit_workload(a)

    text = REGISTRY.render_text()
    print("[obs] metrics registry:")
    print(text if text.strip() else "  (empty)")
    spans = TRACER.spans()
    print(f"[obs] {len(spans)} spans recorded "
          f"({len({s.name for s in spans})} distinct names)")
    if a.trace:
        n = TRACER.export_chrome(a.trace)
        print(f"[obs] wrote {n} trace events -> {a.trace}")
    if a.json_out:
        payload = {"metrics": REGISTRY.snapshot(),
                   "num_spans": len(spans), **extra}
        with open(a.json_out, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"[obs] wrote {a.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
