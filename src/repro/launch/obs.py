"""Observability CLI: run a workload, dump the unified metrics
registry, export spans as a Chrome trace, print convergence profiles.

    python -m repro.launch.obs                      # quick fit + registry dump
    python -m repro.launch.obs --profile full       # + split-phase curve
    python -m repro.launch.obs --graph web.mtx      # profile a real graph
    python -m repro.launch.obs --workload audit     # every dispatch family
    python -m repro.launch.obs --trace trace.json   # chrome://tracing / Perfetto
    python -m repro.launch.obs --json obs.json      # machine-readable snapshot

The trace JSON loads directly into ``chrome://tracing`` or
https://ui.perfetto.dev; the registry dump is the same ``snapshot()``
surface every component's ``stats()`` dict is a view of.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import REGISTRY, TRACER


def _print_profile(profile) -> None:
    for phase in (profile.propagation, profile.split):
        if phase is None:
            continue
        print(f"[obs] {phase.phase} curve ({phase.num_sub_sweeps} sub-sweeps "
              f"over n={profile.n}):")
        print(f"  {'sweep':>5} {'active':>8} {'changed':>8} {'decay':>7}")
        for s, a, c in zip(phase.sweep, phase.active, phase.changed):
            decay = a / profile.n if profile.n else 0.0
            print(f"  {int(s):>5} {int(a):>8} {int(c):>8} {decay:>7.3f}")


def _fit_workload(a) -> dict:
    from repro.engine import CompileCache, Engine, EngineConfig

    if a.graph:
        from repro.io import load_graph
        graph = load_graph(a.graph)
    else:
        from repro.graphgen import erdos_renyi
        graph = erdos_renyi(a.n, a.degree, seed=a.seed)
    eng = Engine(EngineConfig(backend=a.backend, split=a.split,
                              profile=a.profile), cache=CompileCache())
    r = eng.fit(graph)
    print(f"[obs] fit n={graph.n} m={graph.num_edges} backend={r.backend} "
          f"split={a.split}: {r.num_communities} communities in "
          f"{r.lpa_iterations} lpa + {r.split_iterations} split iterations")
    if r.profile is not None:
        _print_profile(r.profile)
    return {"profile": r.profile.to_dict() if r.profile else None}


def _audit_workload(a) -> dict:
    from repro.analysis.workload import run_workload
    coverage = run_workload()
    print(f"[obs] audit workload coverage: "
          + " ".join(f"{k}={v}" for k, v in sorted(coverage.items())))
    return {"coverage": coverage}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.obs",
        description=__doc__.splitlines()[0])
    ap.add_argument("--workload", choices=("fit", "audit"), default="fit",
                    help="fit: one profiled detection; audit: the full "
                         "dispatch-family sweep from repro.analysis.workload")
    ap.add_argument("--graph", default=None, metavar="PATH",
                    help="fit workload: real graph file (.mtx / SNAP edge "
                         "list) instead of a synthetic one")
    ap.add_argument("--n", type=int, default=600,
                    help="fit workload: synthetic graph size")
    ap.add_argument("--degree", type=float, default=6.0,
                    help="fit workload: synthetic average degree")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--split", default="lp",
                    choices=("none", "lp", "lpp", "bfs_host"))
    ap.add_argument("--profile", default="full",
                    choices=("off", "convergence", "full"),
                    help="fit workload: convergence-profile mode")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write spans as Chrome-trace JSON")
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="write registry snapshot (+ profile) as JSON")
    a = ap.parse_args(argv)

    extra = _audit_workload(a) if a.workload == "audit" else _fit_workload(a)

    text = REGISTRY.render_text()
    print("[obs] metrics registry:")
    print(text if text.strip() else "  (empty)")
    spans = TRACER.spans()
    print(f"[obs] {len(spans)} spans recorded "
          f"({len({s.name for s in spans})} distinct names)")
    if a.trace:
        n = TRACER.export_chrome(a.trace)
        print(f"[obs] wrote {n} trace events -> {a.trace}")
    if a.json_out:
        payload = {"metrics": REGISTRY.snapshot(),
                   "num_spans": len(spans), **extra}
        with open(a.json_out, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"[obs] wrote {a.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
