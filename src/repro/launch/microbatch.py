"""Micro-batching scheduler for community-detection serving.

Small-graph traffic is dispatch-bound: one device launch per request
caps throughput far below the hardware.  :class:`MicroBatcher` drains a
request queue in batches of up to ``max_batch`` graphs — lingering up to
``batch_timeout_ms`` after the first request of a batch so concurrent
traffic can coalesce — and executes each batch as a single
``Engine.fit_many`` dispatch.  Every submission resolves to the same
per-graph :class:`DetectionResult` a solo ``fit`` would return (the
parity suite pins this), so batching is invisible to callers except in
latency/throughput.

    eng = Engine(EngineConfig())
    with MicroBatcher(eng, max_batch=16, batch_timeout_ms=2.0) as mb:
        subs = [mb.submit(g) for g in graphs]
        results = [s.result() for s in subs]
    print(mb.stats())   # batch-size histogram, p50/p95 latency
"""
from __future__ import annotations

import queue
import threading
import time
from collections import Counter
from concurrent.futures import Future

import numpy as np

from repro.obs import REGISTRY, span

# Histogram bucket bounds (cumulative upper edges, Prometheus-style).
_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64)
_LATENCY_MS_BUCKETS = (0.5, 1, 2, 5, 10, 25, 50, 100, 250, 1000, 5000)


class Submission:
    """Handle for one enqueued request; resolves to a DetectionResult."""

    def __init__(self, graph, submitted: float, init_labels=None,
                 init_active=None):
        self.graph = graph
        self.init_labels = init_labels  # warm-start labels (or None: cold)
        self.init_active = init_active  # unprocessed-seed mask (frontier)
        self.submitted = submitted     # perf_counter at submit
        self.latency_s: float | None = None   # set when the result lands
        self.batch_size: int | None = None    # size of the batch it rode in
        self._future: Future = Future()

    def result(self, timeout: float | None = None):
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()

    def exception(self, timeout: float | None = None):
        return self._future.exception(timeout)

    def add_done_callback(self, fn) -> None:
        """Call ``fn(self)`` when the result (or exception) lands.

        Runs on the worker thread that settles the future (or inline if
        already done) — the async-settle hook the multi-tenant serving
        tier uses instead of blocking a thread per request.
        """
        self._future.add_done_callback(lambda _f: fn(self))


class MicroBatcher:
    """Queue-draining micro-batch scheduler over ``Engine.fit_many``.

    max_batch: largest number of requests packed into one dispatch.
    batch_timeout_ms: linger after the first request of a batch — the
      scheduler waits this long for more traffic before dispatching a
      partial batch (0 dispatches whatever is immediately available).
    autostart: start the worker thread right away.  ``autostart=False``
      lets callers enqueue a burst first and then :meth:`start`, which
      makes batch composition deterministic (used by tests and the
      serving driver's closed-loop mode).
    """

    def __init__(self, engine, max_batch: int = 8,
                 batch_timeout_ms: float = 2.0, backend: str | None = None,
                 autostart: bool = True, scope=None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = max_batch
        self.batch_timeout_s = batch_timeout_ms / 1e3
        self.backend = backend
        self.batch_sizes: list[int] = []   # one entry per dispatched batch
        self._latencies: list[float] = []  # one entry per completed request
        # Registry write-through.  A standalone batcher claims its own
        # "batcher" scope (released in close()); the serving tier passes
        # a child of its scope so the hierarchy reads serve.batcher.*.
        self._own_scope = scope is None
        self._obs = REGISTRY.scope("batcher") if scope is None else scope
        self._m_requests = self._obs.counter("requests")
        self._m_batches = self._obs.counter("batches")
        self._h_batch = self._obs.histogram("batch_size", _BATCH_BUCKETS)
        self._h_latency = self._obs.histogram("latency_ms",
                                              _LATENCY_MS_BUCKETS)
        self._q: "queue.Queue[Submission | None]" = queue.Queue()
        self._lock = threading.Lock()  # orders submits against the sentinel
        self._closed = False
        self._fatal: BaseException | None = None  # worker died with this
        self._inflight: tuple | list = ()  # batch currently in _dispatch
        self._started = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="microbatcher")
        if autostart:
            self.start()

    # --- lifecycle ---

    def start(self) -> "MicroBatcher":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def close(self, wait: bool = True) -> None:
        """Stop accepting requests; drain the queue, then stop the worker."""
        with self._lock:
            if self._closed:
                already = True
            else:
                already = False
                self._closed = True
                self._q.put(None)  # sentinel: drain-and-exit
        if already:
            if wait and self._started:
                self._thread.join()
                if self._own_scope:
                    self._obs.release()
            return
        if not self._started:
            self.start()
        if wait:
            self._thread.join()
            if self._own_scope:
                self._obs.release()

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # --- request path ---

    def submit(self, graph, init_labels=None, init_active=None) -> Submission:
        """Enqueue one detection request.

        ``init_labels`` / ``init_active``: optional per-request warm-start
        labels and unprocessed-seed mask (a delta's affected frontier) —
        the streaming re-detection path.  Warm and cold requests coalesce
        into the same batches; the engine keeps per-member parity either
        way.
        """
        sub = Submission(graph, time.perf_counter(), init_labels, init_active)
        # The lock orders accepted submissions before close()'s sentinel
        # (FIFO queue), so every accepted submission is dispatched before
        # the worker exits — a submit racing close() either lands before
        # the sentinel or raises.
        with self._lock:
            if self._fatal is not None:
                raise RuntimeError(
                    "MicroBatcher worker died; no submission will ever be "
                    "dispatched") from self._fatal
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            self._q.put(sub)
        self._m_requests.inc()
        return sub

    # --- worker ---

    def _run(self) -> None:
        # A crash anywhere outside _dispatch's protected engine call used
        # to exit this thread silently: every pending Submission.result()
        # then blocked forever and later submits enqueued into a dead
        # worker.  Abnormal exit now fails the in-flight batch + every
        # queued future and poisons submit().
        try:
            self._run_loop()
        except BaseException as e:
            self._abort(e)

    def _run_loop(self) -> None:
        stop = False
        while not stop:
            item = self._q.get()
            if item is None:
                break
            batch = [item]
            deadline = time.perf_counter() + self.batch_timeout_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                try:
                    nxt = self._q.get_nowait() if remaining <= 0 \
                        else self._q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True
                    break
                batch.append(nxt)
            self._inflight = batch
            self._dispatch(batch)
            self._inflight = ()
        # FIFO + the submit/close lock guarantee the sentinel is the last
        # item ever enqueued, so reaching it means the queue is drained.

    def _abort(self, exc: BaseException) -> None:
        """Worker died: strand nothing.  Fail the batch being dispatched
        and everything still queued, and make later submits raise."""
        with self._lock:
            self._fatal = exc
            self._closed = True
        for s in self._inflight:
            if not s._future.done():
                s._future.set_exception(exc)
        self._inflight = ()
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not None and not item._future.done():
                item._future.set_exception(exc)

    def _dispatch(self, batch: list[Submission]) -> None:
        try:
            # Only thread warm-start kwargs through when some request
            # actually carries them — cold-only traffic keeps the bare
            # fit_many(graphs, backend=...) call shape.
            kwargs = {}
            if any(s.init_labels is not None for s in batch):
                kwargs["init_labels"] = [s.init_labels for s in batch]
            if any(s.init_active is not None for s in batch):
                kwargs["init_active"] = [s.init_active for s in batch]
            with span("batch.dispatch", size=len(batch)):
                results = self.engine.fit_many([s.graph for s in batch],
                                               backend=self.backend,
                                               **kwargs)
        except BaseException as e:  # propagate to every waiter
            for s in batch:
                s._future.set_exception(e)
            return
        now = time.perf_counter()
        # Settlement under its own span so the latency histogram's
        # exemplars carry a span id (done-callbacks — e.g. the serving
        # tier's settle path — run inside it, on this worker thread).
        with span("batch.settle", size=len(batch)):
            self.batch_sizes.append(len(batch))
            self._m_batches.inc()
            self._h_batch.observe(len(batch))
            for s, res in zip(batch, results):
                s.latency_s = now - s.submitted
                s.batch_size = len(batch)
                self._latencies.append(s.latency_s)
                self._h_latency.observe(s.latency_s * 1e3)
                s._future.set_result(res)

    # --- observability ---

    def stats(self) -> dict:
        """Aggregate serving stats: batch histogram + latency percentiles."""
        lat_ms = np.asarray(self._latencies) * 1e3
        out = {
            "requests": len(self._latencies),
            "batches": len(self.batch_sizes),
            "batch_size_hist": dict(sorted(Counter(self.batch_sizes).items())),
            "mean_batch": (float(np.mean(self.batch_sizes))
                           if self.batch_sizes else 0.0),
        }
        if len(lat_ms):
            out.update(p50_ms=float(np.percentile(lat_ms, 50)),
                       p95_ms=float(np.percentile(lat_ms, 95)),
                       mean_ms=float(np.mean(lat_ms)))
        else:
            out.update(p50_ms=0.0, p95_ms=0.0, mean_ms=0.0)
        return out
