"""Serving drivers.

Two workloads share this entry point:

  * ``serve``               — LM serving: prefill a batch of prompts,
    decode greedily (reduced configs run for real on CPU; full configs
    exercise the same path through the dry-run cells).
  * ``serve_communities``   — community-detection serving: a stream of
    graph requests of mixed sizes driven through one
    :class:`repro.engine.Engine` behind a micro-batching scheduler
    (:mod:`repro.launch.microbatch`).  The shape-bucketed compile cache
    makes the service viable (after the first batch of each shape class
    everything hits compiled executables); micro-batching makes it
    *fast* — up to ``--max-batch`` requests ride one device dispatch,
    so small-graph throughput is no longer bounded by per-launch
    overhead.  The summary reports per-request latency (p50/p95), the
    batch-size histogram, and aggregate edges/s.
"""
from __future__ import annotations

import argparse
import contextlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.models.common import init_from_specs


def serve(arch: str, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, max_new: int = 16, s_max: int = 128,
          seed: int = 0, params=None, greedy: bool = True):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if params is None:
        params = init_from_specs(T.model_specs(cfg),
                                 jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len)
                           ).astype(np.int32)
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, 32, cfg.d_model)), jnp.bfloat16)

    # jitted once per serving session at fixed (batch, s_max) shapes; no
    # per-request shape traffic flows through these two executables
    # lint: retrace-ok — one-off session jit, shapes fixed above
    prefill_jit = jax.jit(lambda p, bb: T.prefill(cfg, p, bb, s_max))
    # lint: retrace-ok — one-off session jit, shapes fixed above
    decode_jit = jax.jit(lambda p, c, bb: T.decode_step(cfg, p, c, bb))

    t0 = time.time()
    logits, caches = prefill_jit(params, b)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t_prefill = time.time() - t0

    t0 = time.time()
    for _ in range(max_new - 1):
        logits, caches = decode_jit(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tput = batch * max_new / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill {t_prefill:.2f}s, "
          f"{max_new} tokens in {t_decode:.2f}s ({tput:.1f} tok/s)",
          flush=True)
    return {"generated": gen, "prefill_s": t_prefill, "decode_s": t_decode}


def serve_communities(num_requests: int = 24, backend: str = "auto",
                      size_classes=(150, 400, 900), avg_degree: float = 6.0,
                      seed: int = 0, max_batch: int = 8,
                      batch_timeout_ms: float = 2.0,
                      graph_path: str | None = None):
    """Drive a community-detection request stream through the scheduler.

    Requests (random graphs drawn from a few size classes — a traffic
    mix) are **pre-generated outside the timed region**, submitted as a
    burst to a :class:`repro.launch.microbatch.MicroBatcher`, and drained
    in batches of up to ``max_batch`` with a ``batch_timeout_ms`` linger;
    each batch is one ``Engine.fit_many`` device dispatch.  Returns
    per-request records + a summary dict (printed) with per-request
    latency percentiles, the batch-size histogram, and aggregate edges/s.
    (Fresh-graph traffic, so every request is cold; evolving-graph
    traffic goes through ``--mode streaming``, where requests carry
    warm-start labels + delta frontiers through the same batcher.)
    """
    from repro.engine import Engine, EngineConfig
    from repro.graphgen import erdos_renyi
    from repro.launch.microbatch import MicroBatcher

    eng = Engine(EngineConfig(backend=backend))
    rng = np.random.default_rng(seed)
    # generation stays outside the timed region: request timers measure
    # serving latency, not graphgen (nor file ingest — a real graph is
    # loaded once through the parse-once CSR store up front)
    if graph_path is not None:
        from repro.io import load_graph
        real, rep = load_graph(graph_path, return_report=True)
        print(f"[serve-communities] serving {graph_path}: n={real.n} "
              f"m={real.num_edges} "
              f"({'CSR cache hit' if rep.cache_hit else 'ingested'})",
              flush=True)
        graphs = [real] * num_requests
        # Batching k copies of one real graph would pack k disjoint-union
        # replicas of its CSR into a single device dispatch — k times the
        # memory of a solo fit, on exactly the files big enough to care —
        # while measuring nothing a mixed stream would.  Dispatch solo;
        # repeat fits still exercise the compile + warm caches.
        max_batch = 1
    else:
        graphs = [erdos_renyi(int(rng.choice(size_classes)), avg_degree,
                              seed=int(rng.integers(1 << 30)))
                  for _ in range(num_requests)]

    batcher = MicroBatcher(eng, max_batch=max_batch,
                           batch_timeout_ms=batch_timeout_ms,
                           autostart=False)
    t0 = time.perf_counter()
    subs = [batcher.submit(g) for g in graphs]   # burst arrival
    batcher.start()
    results = [s.result() for s in subs]
    batcher.close()
    wall_s = time.perf_counter() - t0

    records = [{"n": g.n, "edges": g.num_edges, "bucket": r.bucket,
                "backend": r.backend, "cache_hit": r.cache_hit,
                "batch_size": s.batch_size, "latency_s": s.latency_s,
                "communities": r.num_communities}
               for g, s, r in zip(graphs, subs, results)]

    total_edges = sum(g.num_edges for g in graphs)
    hits = sum(r["cache_hit"] for r in records)
    summary = {
        **batcher.stats(),
        "buckets": len({r["bucket"] for r in records}),
        "hit_rate": hits / max(len(records), 1),
        "wall_s": wall_s,
        "edges_per_s": total_edges / max(wall_s, 1e-9),
    }
    hist = ", ".join(f"{k}x{v}" for k, v in summary["batch_size_hist"].items())
    print(f"[serve-communities] {summary['requests']} requests in "
          f"{summary['batches']} batches (sizes {hist}) over "
          f"{summary['buckets']} shape buckets: hit rate "
          f"{summary['hit_rate']:.0%}, latency p50 {summary['p50_ms']:.0f}ms "
          f"p95 {summary['p95_ms']:.0f}ms, {summary['edges_per_s']:.0f} "
          f"edges/s aggregate", flush=True)
    return records, summary


def serve_streaming(num_streams: int = 6, rounds: int = 5, size: int = 150,
                    avg_degree: float = 5.0, delta_edges: int = 4,
                    backend: str = "auto", max_batch: int = 16,
                    batch_timeout_ms: float = 2.0, seed: int = 0):
    """Replay evolving-graph delta traces: warm batched vs cold re-detect.

    ``num_streams`` evolving graphs (``evolving_sequence`` traces —
    small per-round edge churn) are replayed two ways, each processing
    the *same delta stream end to end* (delta application + re-detection
    both inside the timed region — a serving system has to rebuild the
    updated graph either way):

      * **cold**: every round applies each stream's delta and re-detects
        the post-delta graph from singletons, one solo ``fit`` per graph
        — the full re-detection baseline;
      * **warm**: a :class:`repro.launch.stream.StreamSession` applies
        the same deltas and drives each round through the
        :class:`MicroBatcher` as one batched dispatch, each member
        warm-started from its stream's previous labels with the delta's
        affected frontier seeded unprocessed.

    Both replays get a warm-up detection per stream first so compile
    cost cancels.  (For the pure-fit comparison with delta application
    hoisted out of the timed regions entirely, see
    ``benchmarks/bench_streaming_deltas.py``.)  Prints the
    full-vs-warm speedup and returns (records, summary): one record per
    stream with its final state.
    """
    from repro.core.delta import apply_delta
    from repro.engine import Engine, EngineConfig
    from repro.graphgen import evolving_sequence
    from repro.launch.stream import StreamSession

    traces = {f"s{i}": evolving_sequence(size, avg_degree, rounds,
                                         delta_edges, seed=seed + i)
              for i in range(num_streams)}

    # cold baseline: apply delta + solo full re-detection, per stream/round
    cold_eng = Engine(EngineConfig(backend=backend))
    for sid, (base, _) in traces.items():  # warm-up: compile solo plans
        cold_eng.fit(base)
    cold_graphs = {sid: base for sid, (base, _) in traces.items()}
    t0 = time.perf_counter()
    for r in range(rounds):
        for sid, (_, deltas) in traces.items():
            cold_graphs[sid] = apply_delta(cold_graphs[sid], deltas[r])
            cold_eng.fit(cold_graphs[sid])
    cold_s = time.perf_counter() - t0

    # warm streaming session: same deltas, batched + warm labels +
    # frontier seeds (update_many re-applies them internally)
    warm_eng = Engine(EngineConfig(backend=backend))
    session = StreamSession(warm_eng, max_batch=max_batch,
                            batch_timeout_ms=batch_timeout_ms)
    session.add_many({sid: base for sid, (base, _) in traces.items()})
    t0 = time.perf_counter()
    last = {}
    for r in range(rounds):
        last = session.update_many({sid: deltas[r]
                                    for sid, (_, deltas) in traces.items()})
    warm_s = time.perf_counter() - t0
    stats = session.stats()
    records = [{"stream": sid, "n": session.graph(sid).n,
                "edges": session.graph(sid).num_edges,
                "communities": res.num_communities,
                "warm_started": res.warm_started,
                "lpa_iterations": res.lpa_iterations}
               for sid, res in sorted(last.items())]
    session.close()

    total_fits = num_streams * rounds
    summary = {
        "streams": num_streams, "rounds": rounds,
        "cold_s": cold_s, "warm_s": warm_s,
        "speedup": cold_s / max(warm_s, 1e-9),
        "mean_frontier_frac": stats["mean_frontier_frac"],
        "p50_ms": stats["p50_ms"], "p95_ms": stats["p95_ms"],
        "mean_batch": stats["mean_batch"],
    }
    print(f"[serve-streaming] {num_streams} streams x {rounds} rounds "
          f"({total_fits} re-detections, ~{delta_edges} edges churned each): "
          f"cold {cold_s:.2f}s, warm batched {warm_s:.2f}s "
          f"({summary['speedup']:.1f}x), frontier "
          f"{summary['mean_frontier_frac']:.1%} of vertices, mean batch "
          f"{summary['mean_batch']:.1f}, p50 {summary['p50_ms']:.0f}ms",
          flush=True)
    return records, summary


def serve_tenants(num_tenants: int = 16, rounds: int = 3,
                  size: int = 120, avg_degree: float = 5.0,
                  delta_edges: int = 4, backend: str = "auto",
                  max_batch: int = 8, batch_timeout_ms: float = 2.0,
                  queue_capacity: int = 32, warm_budget: str = "256KB",
                  client_threads: int = 8, seed: int = 0,
                  snapshot_dir: str | None = None,
                  quality: str = "off", slo_p99_ms: float | None = None):
    """Drive K concurrent tenants through the multi-tenant service tier.

    Each tenant is one evolving graph served by a per-tenant
    :class:`~repro.launch.stream.StreamSession`, all multiplexed over
    **one** shared Engine through **one** shared MicroBatcher behind the
    bounded admission queue (:mod:`repro.serve`).  Traffic is the mixed
    cold/warm/delta trace from :mod:`repro.serve.loadgen`: cold
    registers, warm delta updates with frontier seeds, periodic cold
    refreshes — clients back off and retry on explicit ``Rejected``
    backpressure.  Prints the SLO surface (aggregate edges/s, p50/p99
    latency, queue depth, rejection rate, warm-ledger peak) and, with
    ``snapshot_dir``, writes the tenants' warm state as an atomic
    checkpoint a restarted service can resume warm from.

    ``quality`` wires :attr:`repro.engine.EngineConfig.quality` into the
    shared engine, so every completed fit feeds the per-tenant quality
    timelines (modularity / disconnected-fraction / churn drift alerts —
    ``stats()["health"]``) on top of latency; ``slo_p99_ms`` arms the
    p99-latency burn alert.
    """
    from repro.checkpoint.manager import CheckpointManager
    from repro.engine import Engine, EngineConfig
    from repro.serve import HealthConfig, ServiceConfig, TenantService
    from repro.serve.loadgen import LoadConfig, build_traces, run_load

    cfg = LoadConfig(tenants=num_tenants, rounds=rounds, size=size,
                     avg_degree=avg_degree, delta_edges=delta_edges,
                     client_threads=client_threads, seed=seed)
    eng = Engine(EngineConfig(backend=backend, quality=quality))
    service = TenantService(eng, ServiceConfig(
        queue_capacity=queue_capacity, warm_budget=warm_budget,
        max_batch=max_batch, batch_timeout_ms=batch_timeout_ms,
        health=HealthConfig(slo_p99_ms=slo_p99_ms)))
    records, summary = run_load(service, build_traces(cfg), cfg)
    health = service.stats()["health"]
    if snapshot_dir is not None:
        manifest = service.snapshot(CheckpointManager(snapshot_dir))
        print(f"[serve-tenants] snapshot step {manifest['step']}: "
              f"{len(manifest['tenants'])} tenants -> {snapshot_dir}",
              flush=True)
    service.close()
    summary["health"] = health
    if quality != "off" or slo_p99_ms is not None:
        lasts = [t["last"] for t in health["tenants"].values() if t["last"]]
        worst_disc = max((s["disconnected_fraction"] or 0.0 for s in lasts),
                         default=0.0)
        print(f"[serve-tenants] health: {len(health['tenants'])} timelines, "
              f"alerts {health['alert_counts'] or '{}'}, worst "
              f"disconnected fraction {worst_disc:g}", flush=True)
    print(f"[serve-tenants] {summary['tenants']} tenants x "
          f"{summary['rounds']} rounds: {summary['completed']} requests "
          f"({summary['stranded']} stranded, {summary['rejections']} "
          f"rejected, rate {summary['rejection_rate']:.1%}), latency p50 "
          f"{summary['p50_ms']:.0f}ms p99 {summary['p99_ms']:.0f}ms, queue "
          f"peak {summary['queue_depth_peak']}, warm bytes peak "
          f"{summary['warm_bytes_peak']} <= budget "
          f"{summary['warm_budget']}, {summary['edges_per_s']:.0f} edges/s "
          f"aggregate", flush=True)
    return records, summary


class _PeriodicStats(contextlib.AbstractContextManager):
    """Background reporter: prints the unified metrics registry every
    ``every_s`` seconds while a serving workload runs, plus one final
    snapshot on exit (``--stats-every-s``).  The final flush happens on
    ``__exit__`` — after the workload completes — so it carries whatever
    quality gauges the run populated.  An optional
    :class:`repro.obs.JsonlSink` mirrors every dump as one machine-
    readable line (``--metrics-jsonl``)."""

    def __init__(self, every_s: float, sink=None):
        self._every = every_s
        self._sink = sink
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="stats-reporter")

    def _dump(self, tag: str) -> None:
        from repro.obs import REGISTRY
        text = REGISTRY.render_text()
        body = "\n".join("  " + line for line in text.splitlines()) \
            if text.strip() else "  (empty)"
        print(f"[stats {tag}]\n{body}", flush=True)
        if self._sink is not None:
            self._sink.emit(tag=tag)

    def _run(self) -> None:
        tick = 0
        while not self._stop.wait(self._every):
            tick += 1
            self._dump(f"t+{tick * self._every:g}s")

    def __enter__(self):
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._dump("final")
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode",
                    choices=("lm", "communities", "streaming", "tenants"),
                    default="lm")
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--graph", default=None, metavar="PATH",
                    help="communities mode: serve a real graph file "
                         "(.mtx / SNAP edge list; parse-once CSR cache) "
                         "instead of the synthetic traffic mix")
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="largest request batch per device dispatch")
    ap.add_argument("--batch-timeout-ms", type=float, default=2.0,
                    help="linger after a batch's first request before "
                         "dispatching partial batches")
    ap.add_argument("--streams", type=int, default=6,
                    help="streaming mode: number of evolving graphs")
    ap.add_argument("--rounds", type=int, default=5,
                    help="streaming/tenants mode: delta rounds per stream")
    ap.add_argument("--delta-edges", type=int, default=4,
                    help="streaming/tenants mode: edges churned per delta")
    ap.add_argument("--tenants", type=int, default=16,
                    help="tenants mode: number of concurrent tenants")
    ap.add_argument("--queue-capacity", type=int, default=32,
                    help="tenants mode: global admission bound")
    ap.add_argument("--warm-budget", default="256KB",
                    help="tenants mode: global warm-labels byte budget")
    ap.add_argument("--snapshot-dir", default=None,
                    help="tenants mode: write a warm-state checkpoint "
                         "after the load (restore resumes warm)")
    ap.add_argument("--stats-every-s", type=float, default=None,
                    metavar="S",
                    help="print the unified metrics registry every S "
                         "seconds while serving (+ a final snapshot)")
    ap.add_argument("--quality", default="off",
                    choices=("off", "basic", "full"),
                    help="tenants mode: per-fit quality telemetry depth "
                         "(EngineConfig.quality) feeding the per-tenant "
                         "drift timelines")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="tenants mode: p99 latency SLO; burns raise "
                         "health alerts")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus text metrics over HTTP on this "
                         "port while the workload runs (0 = ephemeral; "
                         "also /metrics.json and /healthz)")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append registry snapshots as JSONL (one line per "
                         "--stats-every-s tick + a final one)")
    a = ap.parse_args()

    from repro.obs import JsonlSink, MetricsServer
    sink = JsonlSink(a.metrics_jsonl) if a.metrics_jsonl else None
    server = contextlib.nullcontext()
    if a.metrics_port is not None:
        server = MetricsServer(port=a.metrics_port)
        print(f"[serve] metrics endpoint: {server.url}/metrics", flush=True)
    reporter = _PeriodicStats(a.stats_every_s, sink=sink) \
        if a.stats_every_s else contextlib.nullcontext()
    with server, reporter:
        if a.mode == "tenants":
            serve_tenants(num_tenants=a.tenants, rounds=a.rounds,
                          delta_edges=a.delta_edges, backend=a.backend,
                          max_batch=a.max_batch,
                          batch_timeout_ms=a.batch_timeout_ms,
                          queue_capacity=a.queue_capacity,
                          warm_budget=a.warm_budget,
                          snapshot_dir=a.snapshot_dir,
                          quality=a.quality, slo_p99_ms=a.slo_p99_ms)
        elif a.mode == "communities":
            serve_communities(num_requests=a.requests, backend=a.backend,
                              max_batch=a.max_batch,
                              batch_timeout_ms=a.batch_timeout_ms,
                              graph_path=a.graph)
        elif a.mode == "streaming":
            serve_streaming(num_streams=a.streams, rounds=a.rounds,
                            delta_edges=a.delta_edges, backend=a.backend,
                            max_batch=a.max_batch,
                            batch_timeout_ms=a.batch_timeout_ms)
        else:
            if not a.arch:
                ap.error("--arch is required for --mode lm")
            serve(a.arch, batch=a.batch, max_new=a.max_new)
    if sink is not None:
        # guaranteed final flush, with or without --stats-every-s:
        # everything the run recorded, quality gauges included
        sink.emit(tag="shutdown")
        sink.close()
        print(f"[serve] metrics jsonl -> {a.metrics_jsonl}", flush=True)


if __name__ == "__main__":
    main()
