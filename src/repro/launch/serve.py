"""Serving drivers.

Two workloads share this entry point:

  * ``serve``               — LM serving: prefill a batch of prompts,
    decode greedily (reduced configs run for real on CPU; full configs
    exercise the same path through the dry-run cells).
  * ``serve_communities``   — community-detection serving: a stream of
    graph requests of mixed sizes driven through one
    :class:`repro.engine.Engine` behind a micro-batching scheduler
    (:mod:`repro.launch.microbatch`).  The shape-bucketed compile cache
    makes the service viable (after the first batch of each shape class
    everything hits compiled executables); micro-batching makes it
    *fast* — up to ``--max-batch`` requests ride one device dispatch,
    so small-graph throughput is no longer bounded by per-launch
    overhead.  The summary reports per-request latency (p50/p95), the
    batch-size histogram, and aggregate edges/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.models import transformer as T
from repro.models.common import init_from_specs


def serve(arch: str, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, max_new: int = 16, s_max: int = 128,
          seed: int = 0, params=None, greedy: bool = True):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if params is None:
        params = init_from_specs(T.model_specs(cfg),
                                 jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len)
                           ).astype(np.int32)
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, 32, cfg.d_model)), jnp.bfloat16)

    prefill_jit = jax.jit(lambda p, bb: T.prefill(cfg, p, bb, s_max))
    decode_jit = jax.jit(lambda p, c, bb: T.decode_step(cfg, p, c, bb))

    t0 = time.time()
    logits, caches = prefill_jit(params, b)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t_prefill = time.time() - t0

    t0 = time.time()
    for _ in range(max_new - 1):
        logits, caches = decode_jit(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tput = batch * max_new / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill {t_prefill:.2f}s, "
          f"{max_new} tokens in {t_decode:.2f}s ({tput:.1f} tok/s)",
          flush=True)
    return {"generated": gen, "prefill_s": t_prefill, "decode_s": t_decode}


def serve_communities(num_requests: int = 24, backend: str = "auto",
                      size_classes=(150, 400, 900), avg_degree: float = 6.0,
                      seed: int = 0, max_batch: int = 8,
                      batch_timeout_ms: float = 2.0):
    """Drive a community-detection request stream through the scheduler.

    Requests (random graphs drawn from a few size classes — a traffic
    mix) are **pre-generated outside the timed region**, submitted as a
    burst to a :class:`repro.launch.microbatch.MicroBatcher`, and drained
    in batches of up to ``max_batch`` with a ``batch_timeout_ms`` linger;
    each batch is one ``Engine.fit_many`` device dispatch.  Returns
    per-request records + a summary dict (printed) with per-request
    latency percentiles, the batch-size histogram, and aggregate edges/s.
    (No ``warm_start`` knob: the batched dispatch path never warm-starts;
    incremental re-detection stays a solo-``fit`` feature.)
    """
    from repro.engine import Engine, EngineConfig
    from repro.graphgen import erdos_renyi
    from repro.launch.microbatch import MicroBatcher

    eng = Engine(EngineConfig(backend=backend))
    rng = np.random.default_rng(seed)
    # generation stays outside the timed region: request timers measure
    # serving latency, not graphgen
    graphs = [erdos_renyi(int(rng.choice(size_classes)), avg_degree,
                          seed=int(rng.integers(1 << 30)))
              for _ in range(num_requests)]

    batcher = MicroBatcher(eng, max_batch=max_batch,
                           batch_timeout_ms=batch_timeout_ms,
                           autostart=False)
    t0 = time.perf_counter()
    subs = [batcher.submit(g) for g in graphs]   # burst arrival
    batcher.start()
    results = [s.result() for s in subs]
    batcher.close()
    wall_s = time.perf_counter() - t0

    records = [{"n": g.n, "edges": g.num_edges, "bucket": r.bucket,
                "backend": r.backend, "cache_hit": r.cache_hit,
                "batch_size": s.batch_size, "latency_s": s.latency_s,
                "communities": r.num_communities}
               for g, s, r in zip(graphs, subs, results)]

    total_edges = sum(g.num_edges for g in graphs)
    hits = sum(r["cache_hit"] for r in records)
    summary = {
        **batcher.stats(),
        "buckets": len({r["bucket"] for r in records}),
        "hit_rate": hits / max(len(records), 1),
        "wall_s": wall_s,
        "edges_per_s": total_edges / max(wall_s, 1e-9),
    }
    hist = ", ".join(f"{k}x{v}" for k, v in summary["batch_size_hist"].items())
    print(f"[serve-communities] {summary['requests']} requests in "
          f"{summary['batches']} batches (sizes {hist}) over "
          f"{summary['buckets']} shape buckets: hit rate "
          f"{summary['hit_rate']:.0%}, latency p50 {summary['p50_ms']:.0f}ms "
          f"p95 {summary['p95_ms']:.0f}ms, {summary['edges_per_s']:.0f} "
          f"edges/s aggregate", flush=True)
    return records, summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "communities"), default="lm")
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="largest request batch per device dispatch")
    ap.add_argument("--batch-timeout-ms", type=float, default=2.0,
                    help="linger after a batch's first request before "
                         "dispatching partial batches")
    a = ap.parse_args()
    if a.mode == "communities":
        serve_communities(num_requests=a.requests, backend=a.backend,
                          max_batch=a.max_batch,
                          batch_timeout_ms=a.batch_timeout_ms)
    else:
        if not a.arch:
            ap.error("--arch is required for --mode lm")
        serve(a.arch, batch=a.batch, max_new=a.max_new)


if __name__ == "__main__":
    main()
