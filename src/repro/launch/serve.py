"""Batched serving driver: prefill a batch of prompts, decode greedily.

Reduced configs serve for real on CPU (used by examples/serve_lm.py);
full configs exercise the same code path through the dry-run cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.models import transformer as T
from repro.models.common import init_from_specs


def serve(arch: str, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, max_new: int = 16, s_max: int = 128,
          seed: int = 0, params=None, greedy: bool = True):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if params is None:
        params = init_from_specs(T.model_specs(cfg),
                                 jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len)
                           ).astype(np.int32)
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, 32, cfg.d_model)), jnp.bfloat16)

    prefill_jit = jax.jit(lambda p, bb: T.prefill(cfg, p, bb, s_max))
    decode_jit = jax.jit(lambda p, c, bb: T.decode_step(cfg, p, c, bb))

    t0 = time.time()
    logits, caches = prefill_jit(params, b)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t_prefill = time.time() - t0

    t0 = time.time()
    for _ in range(max_new - 1):
        logits, caches = decode_jit(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tput = batch * max_new / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill {t_prefill:.2f}s, "
          f"{max_new} tokens in {t_decode:.2f}s ({tput:.1f} tok/s)",
          flush=True)
    return {"generated": gen, "prefill_s": t_prefill, "decode_s": t_decode}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    a = ap.parse_args()
    serve(a.arch, batch=a.batch, max_new=a.max_new)


if __name__ == "__main__":
    main()
