"""Serving drivers.

Two workloads share this entry point:

  * ``serve``               — LM serving: prefill a batch of prompts,
    decode greedily (reduced configs run for real on CPU; full configs
    exercise the same path through the dry-run cells).
  * ``serve_communities``   — community-detection serving: a stream of
    graph requests of mixed sizes driven through one
    :class:`repro.engine.Engine`.  The shape-bucketed compile cache is
    what makes this viable as a service: after the first request of each
    size class, every subsequent request hits an already-compiled
    executable (the summary prints cold/warm latency and hit rate).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.models import transformer as T
from repro.models.common import init_from_specs


def serve(arch: str, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, max_new: int = 16, s_max: int = 128,
          seed: int = 0, params=None, greedy: bool = True):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if params is None:
        params = init_from_specs(T.model_specs(cfg),
                                 jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len)
                           ).astype(np.int32)
    b = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, 32, cfg.d_model)), jnp.bfloat16)

    prefill_jit = jax.jit(lambda p, bb: T.prefill(cfg, p, bb, s_max))
    decode_jit = jax.jit(lambda p, c, bb: T.decode_step(cfg, p, c, bb))

    t0 = time.time()
    logits, caches = prefill_jit(params, b)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t_prefill = time.time() - t0

    t0 = time.time()
    for _ in range(max_new - 1):
        logits, caches = decode_jit(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tput = batch * max_new / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill {t_prefill:.2f}s, "
          f"{max_new} tokens in {t_decode:.2f}s ({tput:.1f} tok/s)",
          flush=True)
    return {"generated": gen, "prefill_s": t_prefill, "decode_s": t_decode}


def serve_communities(num_requests: int = 24, backend: str = "auto",
                      size_classes=(150, 400, 900), avg_degree: float = 6.0,
                      seed: int = 0, warm_start: str = "off"):
    """Drive a stream of community-detection requests through one Engine.

    Each request is a fresh random graph drawn from one of a few size
    classes (a traffic mix); the engine buckets shapes so requests in the
    same class reuse one compiled executable.  Returns per-request
    records + a summary dict (printed) — the serving-path smoke story.
    """
    from repro.engine import Engine, EngineConfig
    from repro.graphgen import erdos_renyi

    eng = Engine(EngineConfig(backend=backend, warm_start=warm_start))
    rng = np.random.default_rng(seed)
    records = []
    for i in range(num_requests):
        n = int(rng.choice(size_classes))
        g = erdos_renyi(n, avg_degree, seed=int(rng.integers(1 << 30)))
        t0 = time.time()
        res = eng.fit(g)
        dt = time.time() - t0
        records.append({"n": n, "bucket": res.bucket, "backend": res.backend,
                        "cache_hit": res.cache_hit, "seconds": dt,
                        "communities": res.num_communities})

    cold = [r["seconds"] for r in records if not r["cache_hit"]]
    warm = [r["seconds"] for r in records if r["cache_hit"]]
    summary = {
        "requests": len(records),
        "buckets": len({r["bucket"] for r in records}),
        "hit_rate": len(warm) / max(len(records), 1),
        "cold_mean_s": float(np.mean(cold)) if cold else 0.0,
        "warm_mean_s": float(np.mean(warm)) if warm else 0.0,
        "warm_p95_s": float(np.percentile(warm, 95)) if warm else 0.0,
    }
    print(f"[serve-communities] {summary['requests']} requests over "
          f"{summary['buckets']} shape buckets: hit rate "
          f"{summary['hit_rate']:.0%}, cold {summary['cold_mean_s']*1e3:.0f}ms"
          f" -> warm {summary['warm_mean_s']*1e3:.0f}ms "
          f"(p95 {summary['warm_p95_s']*1e3:.0f}ms)", flush=True)
    return records, summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "communities"), default="lm")
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--backend", default="auto")
    a = ap.parse_args()
    if a.mode == "communities":
        serve_communities(num_requests=a.requests, backend=a.backend)
    else:
        if not a.arch:
            ap.error("--arch is required for --mode lm")
        serve(a.arch, batch=a.batch, max_new=a.max_new)


if __name__ == "__main__":
    main()
