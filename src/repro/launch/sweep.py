"""Dry-run sweep driver: one subprocess per cell (memory isolation — a
cell failure or leak never takes down the sweep; jit caches don't
accumulate across cells).

  PYTHONPATH=src python -m repro.launch.sweep [--mesh pod|multipod|both]
"""
import argparse
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
OUT = REPO / "experiments" / "dryrun"


def cells():
    from repro.configs import ARCHS, supported_shapes
    out = []
    for arch, cfg in ARCHS.items():
        for shape in supported_shapes(cfg):
            out.append((arch, shape))
    out.append(("graph-lpa", "graph"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--force", dest="skip_existing", action="store_false")
    args = ap.parse_args()
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    todo = [(a, s, m) for a, s in cells() for m in meshes]
    failures = []
    t0 = time.time()
    for i, (arch, shape, mesh) in enumerate(todo):
        fname = OUT / f"{arch}_{shape}_{mesh}.json"
        if args.skip_existing and fname.exists():
            print(f"[sweep {i+1}/{len(todo)}] skip {fname.name}", flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--mesh", mesh]
        if arch != "graph-lpa":
            cmd += ["--shape", shape]
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        env.pop("XLA_FLAGS", None)   # dryrun sets its own
        t1 = time.time()
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=3600)
        status = "OK" if proc.returncode == 0 else "FAIL"
        print(f"[sweep {i+1}/{len(todo)}] {arch} {shape} {mesh}: {status} "
              f"({time.time()-t1:.0f}s)", flush=True)
        if proc.returncode != 0:
            failures.append((arch, shape, mesh))
            print(proc.stderr[-1500:], flush=True)
    print(f"[sweep] done in {time.time()-t0:.0f}s; "
          f"failures: {failures or 'none'}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
