"""Production mesh construction (function, not module constant: importing
this module never touches jax device state)."""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds the leading 'pod' axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (XLA host device count)."""
    return make_mesh(shape, axes)


def make_flat_mesh(axis: str = "data"):
    """One axis over every visible device — the engine's sharded default."""
    return make_mesh((jax.device_count(),), (axis,))
