"""Production mesh construction (function, not module constant: importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds the leading 'pod' axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_host_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (XLA host device count)."""
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)
