"""Lint CLI: the hot-path contract rules over src/repro (or any paths).

    PYTHONPATH=src python -m repro.launch.lint                 # report
    PYTHONPATH=src python -m repro.launch.lint --strict        # CI gate
    PYTHONPATH=src python -m repro.launch.lint --json
    PYTHONPATH=src python -m repro.launch.lint --list-rules
    PYTHONPATH=src python -m repro.launch.lint tests/fixtures/lint
    PYTHONPATH=src python -m repro.launch.lint --write-baseline

Exit codes: 0 clean (or every finding baselined / suppressed), 1 on
actionable findings, 2 on usage errors.  ``--strict`` is what CI runs:
it fails on any finding that is neither inline-suppressed
(``# lint: <tag>-ok — why``) nor in the committed baseline
(``src/repro/analysis/baseline.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="JAX-aware hot-path lint (R001-R006)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: the repro package)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined, non-suppressed "
                         "finding (the CI gate)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON (default: "
                         "src/repro/analysis/baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule IDs to run (e.g. R001,R004)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by inline "
                         "`# lint: <tag>-ok` comments")
    ap.add_argument("--vmem-ceiling", type=int, default=None,
                    help="R004 per-step block-bytes ceiling (default 16 MiB)")
    args = ap.parse_args(argv)

    import repro
    from repro.analysis import Baseline, all_rules, lint_paths

    rules = all_rules(vmem_ceiling=args.vmem_ceiling)
    if args.list_rules:
        for r in rules:
            print(f"{r.id}  [{r.tag}]  {r.description}")
        return 0
    if args.rules:
        wanted = {r.strip().upper() for r in args.rules.split(",")}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"unknown rule ids: {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    pkg_root = Path(repro.__file__).parent
    paths = args.paths or [pkg_root]
    baseline_path = args.baseline or pkg_root / "analysis" / "baseline.json"

    findings = lint_paths(paths, rules)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    if args.write_baseline:
        n = Baseline.dump(active, baseline_path)
        print(f"wrote {n} baseline entries to {baseline_path}")
        return 0

    baseline = Baseline.load(baseline_path) if baseline_path.exists() \
        else Baseline()
    new = [f for f in active if f not in baseline]
    known = [f for f in active if f in baseline]

    if args.as_json:
        json.dump({
            "findings": [f.to_json() for f in active],
            "suppressed": [f.to_json() for f in suppressed],
            "baselined": len(known),
            "new": len(new),
        }, sys.stdout, indent=2)
        print()
    else:
        for f in new:
            print(f.format())
        if known:
            print(f"# {len(known)} baselined finding(s) not shown "
                  f"(see {baseline_path})")
        if args.show_suppressed and suppressed:
            print("# inline-suppressed:")
            for f in suppressed:
                print(f"#   {f.format()}")
        if not new:
            print(f"clean: {len(active)} active finding(s), "
                  f"{len(known)} baselined, {len(suppressed)} suppressed")

    if args.strict:
        return 1 if new else 0
    return 0   # report-only by default; CI passes --strict


if __name__ == "__main__":
    sys.exit(main())
