"""Ingest CLI: parse + preprocess + cache a real graph file.

    PYTHONPATH=src python -m repro.launch.ingest file.mtx --stats
    PYTHONPATH=src python -m repro.launch.ingest file.snap.txt \
        --one-based --largest-cc --detect --backend segment
    PYTHONPATH=src python -m repro.launch.ingest big.mtx \
        --ooc --memory-budget 256MB
    PYTHONPATH=src python -m repro.launch.ingest --list-cache

One run pays the parse; the resulting CSR lands in the on-disk store
(``repro.io.store.default_cache_dir`` or ``--cache-dir``), so every
later ``load_graph`` / ``Engine.fit(path)`` / ``serve --graph`` on the
same file content is an mmap load.  ``--stats`` prints the §4.1
preprocessing report (raw vs. cleaned edge counts); ``--detect``
additionally runs one engine fit and reports communities + modularity.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.io.preprocess import PreprocessOptions
from repro.io.store import CsrStore, load_graph


def _human_bytes(n: int) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if n >= div:
            return f"{n / div:.1f}{unit}"
    return f"{n}B"


def _human_edges_per_s(edges: int, seconds: float) -> str:
    if seconds <= 0:
        return "-"
    rate = edges / seconds
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if rate >= div:
            return f"{rate / div:.2f}{unit} edges/s"
    return f"{rate:.0f} edges/s"


def ingest(path: str, args) -> dict:
    opts = PreprocessOptions(
        drop_self_loops=not args.keep_self_loops,
        dedup=not args.no_dedup,
        unit_weights=not args.keep_weights,
        largest_component=args.largest_cc,
        compact_ids=args.compact_ids,
    )
    if args.ooc:
        # The whole point of --ooc is never materializing the full edge
        # arrays: go through the windowed store handle, not load_graph.
        return _ingest_ooc(path, args, opts)

    graph, rep = load_graph(
        path, opts, fmt=args.format, one_based=args.one_based,
        cache=not args.no_cache, cache_dir=args.cache_dir,
        force=args.force, return_report=True)

    s = rep.stats
    mode = "cache hit" if rep.cache_hit else "ingested"
    print(f"[ingest] {path}: {mode} (key {rep.key or '-'})")
    print(f"  graph: n={graph.n} directed_edges={graph.num_edges} "
          f"d_avg={graph.num_edges / max(graph.n, 1):.1f}")
    if rep.cache_hit:
        print(f"  load: {rep.load_seconds * 1e3:.1f}ms mmap "
              f"(+{rep.hash_seconds * 1e3:.1f}ms content hash)")
    else:
        print(f"  parse: {rep.parse_seconds:.3f}s "
              f"({_human_edges_per_s(s.get('raw_edges', 0), rep.parse_seconds)})"
              f"  preprocess: {rep.preprocess_seconds:.3f}s"
              f"  build: {rep.build_seconds:.3f}s")
    _print_stats(args, s)

    out = {"path": path, "cache_hit": rep.cache_hit, "key": rep.key,
           "n": graph.n, "directed_edges": graph.num_edges,
           "parse_seconds": rep.parse_seconds,
           "preprocess_seconds": rep.preprocess_seconds,
           "build_seconds": rep.build_seconds,
           "load_seconds": rep.load_seconds, "stats": s}

    if args.detect:
        from repro.engine import Engine
        eng = Engine(_engine_config(args, compute_metrics=True))
        res = eng.fit(graph)
        print(f"  detect[{res.backend}]: |Gamma|={res.num_communities} "
              f"Q={res.modularity:.4f} iters={res.lpa_iterations}"
              f"+{res.split_iterations}split")
        out["detect"] = {"backend": res.backend,
                         "communities": res.num_communities,
                         "modularity": res.modularity,
                         "lpa_iterations": res.lpa_iterations}
    return out


def _ingest_ooc(path: str, args, opts) -> dict:
    """--ooc: windowed store reads end to end, full arrays never built.

    (A file not yet in the store still pays its one-time parse inside
    ``open_graph`` — out-of-core *ingest* is a ROADMAP follow-on; every
    later run here is pure windowed mmap.)
    """
    import numpy as np

    from repro.io.store import open_graph
    from repro.partition.ooc import fit_out_of_core
    from repro.partition.plan import parse_bytes
    from repro.partition.slices import StoreEntrySource

    if args.no_cache:
        raise SystemExit("--ooc reads partition windows from the on-disk "
                         "store and cannot combine with --no-cache")
    budget = parse_bytes(args.memory_budget or "64MB")
    handle = open_graph(path, opts, fmt=args.format,
                        one_based=args.one_based, cache_dir=args.cache_dir,
                        force=args.force)
    s = handle.meta.get("stats", {})
    print(f"[ingest] {path}: store entry (key {handle.key})")
    print(f"  graph: n={handle.n} directed_edges={handle.num_edges} "
          f"d_avg={handle.num_edges / max(handle.n, 1):.1f}")
    _print_stats(args, s)

    run = fit_out_of_core(
        StoreEntrySource(handle), _engine_config(args),
        memory_budget=budget,
        backend=None if args.backend == "auto" else args.backend)
    rate = _human_edges_per_s(handle.num_edges,
                              run.lpa_seconds + run.split_seconds)
    print(f"  ooc[{run.backend}]: |Gamma|={len(np.unique(run.labels))} "
          f"partitions={run.num_partitions} "
          f"peak={_human_bytes(run.peak_resident_bytes)} "
          f"(budget {_human_bytes(budget)}) "
          f"halo={run.halo_vertices} loads={run.partition_loads} "
          f"{rate}")
    if args.detect:
        print("  (skipping --detect: it needs the full graph in core — "
              "drop --ooc to run it)")
    return {"path": path, "key": handle.key, "n": handle.n,
            "directed_edges": handle.num_edges, "stats": s,
            "ooc": {"backend": run.backend, **run.stats(),
                    "lpa_seconds": run.lpa_seconds,
                    "split_seconds": run.split_seconds}}


def _print_stats(args, s: dict) -> None:
    if args.stats and s:
        print(f"  [§4.1] raw edges {s['raw_edges']} -> {s['edges']} "
              f"undirected (self-loops -{s['self_loops']}, duplicates "
              f"-{s['duplicates']})")
        print(f"  [§4.1] vertices {s['raw_vertices']} -> {s['vertices']} "
              f"(isolated {s['isolated_vertices']}, dropped off-LCC "
              f"{s['component_vertices_dropped']}); "
              f"weights: {'kept' if s['weighted'] else 'unit'}")


def _engine_config(args, **overrides):
    from repro.engine import EngineConfig
    return EngineConfig(backend=args.backend, **overrides)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.ingest",
        description="Parse, preprocess, and cache real graph files.")
    ap.add_argument("paths", nargs="*", help=".mtx / SNAP edge-list files")
    ap.add_argument("--format", choices=("mtx", "snap"),
                    help="override format sniffing")
    ap.add_argument("--one-based", action="store_true",
                    help="edge-list ids start at 1 (SNAP default is 0)")
    ap.add_argument("--stats", action="store_true",
                    help="print the §4.1 preprocessing report")
    ap.add_argument("--keep-self-loops", action="store_true")
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--keep-weights", action="store_true",
                    help="keep file weights (paper default is unit)")
    ap.add_argument("--largest-cc", action="store_true",
                    help="restrict to the largest connected component")
    ap.add_argument("--compact-ids", action="store_true",
                    help="dense-relabel the vertex ids that appear")
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the on-disk CSR store")
    ap.add_argument("--force", action="store_true",
                    help="re-ingest even on a cache hit")
    ap.add_argument("--cache-dir", help="CSR store location "
                    "(default: $REPRO_GRAPH_CACHE or ~/.cache/repro/graphs)")
    ap.add_argument("--detect", action="store_true",
                    help="run one engine fit on the ingested graph")
    ap.add_argument("--ooc", action="store_true",
                    help="run an out-of-core partitioned detection over "
                         "the store entry (windowed reads, never the "
                         "full edge arrays)")
    ap.add_argument("--memory-budget", default=None,
                    help="resident edge-byte cap for --ooc, e.g. 64MB "
                         "(default 64MB)")
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--json", help="write per-file reports to this path")
    ap.add_argument("--list-cache", action="store_true",
                    help="list on-disk store entries and exit")
    args = ap.parse_args(argv)

    if args.list_cache:
        store = CsrStore(args.cache_dir)
        entries = store.entries()
        print(f"[ingest] {len(entries)} cached graphs in {store.root}")
        for e in entries:
            print(f"  {e['key']}  n={e.get('n')} m={e.get('num_edges')}  "
                  f"{e.get('source', '?')}  [{e.get('options', '')}]")
        return 0

    if not args.paths:
        ap.error("no input files (or use --list-cache)")
    reports = [ingest(p, args) for p in args.paths]
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(reports, fh, indent=2)
        print(f"[ingest] wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
