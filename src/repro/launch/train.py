"""End-to-end training driver (reduced configs run for real on CPU; full
configs are exercised via the dry-run).

Wires together: config -> data pipeline -> jitted train step -> checkpoint
manager -> preemption handler -> straggler monitor.  ``--resume`` restores
params/optimizer/data state from the latest checkpoint (elastic: works on a
different device count than the run that wrote it).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --save-every 20 [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.data import SyntheticLMDataset
from repro.ft import PreemptionHandler, StragglerMonitor
from repro.models import transformer as T
from repro.models.common import init_from_specs
from repro.train import steps as S


def build_small_shape(cfg, seq_len: int, global_batch: int) -> str:
    """Register an ad-hoc shape for CPU-scale runs."""
    name = f"cpu_{seq_len}x{global_batch}"
    SHAPES[name] = ShapeSpec(name, seq_len, global_batch, "train")
    return name


def run(arch: str, reduced: bool = True, steps: int = 50,
        seq_len: int = 128, global_batch: int = 8,
        ckpt_dir: str | None = None, save_every: int = 20,
        resume: bool = False, seed: int = 0, mesh=None,
        log_every: int = 10, preempt: PreemptionHandler | None = None,
        peak_lr: float = 1e-3):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if mesh is None:
        ndev = len(jax.devices())
        from repro.parallel.compat import make_mesh
        mesh = make_mesh((ndev, 1), ("data", "model"))
    shape = build_small_shape(cfg, seq_len, global_batch)

    step_fn, rules, psh, osh = S.make_train_step(
        cfg, mesh, shape, peak_lr=peak_lr, warmup=5,
        total_steps=max(steps, 100), donate=False)
    params = init_from_specs(T.model_specs(cfg), jax.random.PRNGKey(seed))
    opt_state = S.init_opt_state(cfg, params)

    data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=seq_len,
                              global_batch=global_batch, seed=seed)
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start_step = 0
    if resume and mgr and mgr.latest_step() is not None:
        state = {"params": params, "opt": opt_state}
        restored, ck_step, extra = mgr.restore(state)
        params, opt_state = restored["params"], restored["opt"]
        data.restore(extra["data"])
        start_step = ck_step
        print(f"[train] resumed from step {ck_step}", flush=True)

    preempt = (preempt or PreemptionHandler()).install()
    monitor = StragglerMonitor()
    losses = []
    t_start = time.time()
    final_step = start_step
    for step in range(start_step, steps):
        monitor.step_start()
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.step_end(step)
        final_step = step + 1
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        want_ckpt = mgr and ((step + 1) % save_every == 0
                             or step == steps - 1 or preempt.should_stop)
        if want_ckpt:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     extra={"data": data.state(), "loss": loss},
                     blocking=False)
        if preempt.should_stop:
            print(f"[train] preempted at step {step}; checkpointed",
                  flush=True)
            break
    if mgr:
        mgr.wait()
    dt = time.time() - t_start
    print(f"[train] done: {final_step - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    return {"losses": losses, "final_step": final_step,
            "params": params, "monitor": monitor}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.arch, a.reduced, a.steps, a.seq_len, a.global_batch,
        a.ckpt_dir, a.save_every, a.resume, a.seed)


if __name__ == "__main__":
    main()
