"""Streaming re-detection sessions over evolving graphs.

A :class:`StreamSession` tracks any number of named *streams* — graphs
that evolve by :class:`repro.core.delta.GraphDelta` updates — and serves
their re-detections through a micro-batching scheduler: concurrent
updates coalesce into single ``Engine.fit_many`` dispatches, each member
warm-started from its stream's previous labels with the delta's affected
frontier seeded unprocessed (GVE-LPA's pruning rule).  The engine pins
bit-parity between this path and a solo warm ``fit`` per stream, so
batching + warm starts change latency and throughput, never results.

    eng = Engine(EngineConfig())
    with StreamSession(eng) as sess:
        sess.add("social", g0)                     # cold initial detection
        res = sess.update("social", delta)         # warm incremental refit
        out = sess.update_many({"a": d1, "b": d2})  # one batched dispatch
    print(sess.stats())

``warm=False`` turns the session into a cold-replay baseline (every
update re-detects from singletons, still batched) — what the streaming
benchmark compares against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delta import (
    GraphDelta,
    affected_frontier,
    apply_delta,
    apply_delta_patch,
)
from repro.core.graph import Graph
from repro.launch.microbatch import MicroBatcher, Submission


@dataclasses.dataclass
class StreamState:
    """Current per-stream snapshot: the graph and its last labels."""
    graph: Graph
    labels: np.ndarray | None = None  # compacted [0, K); None before 1st fit
    version: int = 0                  # number of deltas applied so far


class StreamSession:
    """Batched warm re-detection over named evolving-graph streams.

    engine: the :class:`repro.engine.Engine` serving the session.
    warm: warm-start updates from each stream's previous labels
      (``False``: cold re-detection per update — the baseline mode).
    frontier: additionally seed only the delta's affected frontier
      unprocessed (requires ``warm``; ignored otherwise) — propagation
      is then restricted to changed neighborhoods plus whatever they
      wake.
    max_batch / batch_timeout_ms / backend: micro-batcher knobs (see
      :class:`repro.launch.microbatch.MicroBatcher`); alternatively pass
      an existing ``batcher`` to share one scheduler across sessions.
    """

    def __init__(self, engine, *, warm: bool = True, frontier: bool = True,
                 max_batch: int = 16, batch_timeout_ms: float = 2.0,
                 backend: str | None = None, batcher: MicroBatcher | None = None):
        self.engine = engine
        self.warm = warm
        self.frontier = frontier and warm
        self._own_batcher = batcher is None
        self.batcher = batcher if batcher is not None else MicroBatcher(
            engine, max_batch=max_batch, batch_timeout_ms=batch_timeout_ms,
            backend=backend)
        self.streams: dict = {}
        self.updates = 0        # delta updates served
        self.warm_updates = 0   # ... of which warm-started
        self._frontier_fracs: list[float] = []

    # --- lifecycle ---

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._own_batcher:
            self.batcher.close()

    # --- stream registration ---

    def add(self, stream_id, graph: Graph):
        """Register a stream with its initial graph; cold initial fit."""
        return self.add_many({stream_id: graph})[stream_id]

    def add_many(self, graphs: dict) -> dict:
        """Register several streams at once (one coalesced dispatch)."""
        for sid in graphs:
            if sid in self.streams:
                raise ValueError(f"stream {sid!r} already registered")
        subs = {sid: self.batcher.submit(g) for sid, g in graphs.items()}
        return self._settle(graphs, subs)

    def graph(self, stream_id) -> Graph:
        return self.streams[stream_id].graph

    def labels(self, stream_id) -> np.ndarray | None:
        return self.streams[stream_id].labels

    # --- delta updates ---

    def update(self, stream_id, delta: GraphDelta):
        """Apply one delta and re-detect (rides the shared batcher)."""
        return self.update_many({stream_id: delta})[stream_id]

    def update_many(self, deltas: dict) -> dict:
        """Apply a delta per stream and re-detect the batch.

        All updates are submitted as one burst, so (up to ``max_batch``)
        they ride a single ``fit_many`` device dispatch — warm-started
        per member from each stream's previous labels, with the delta's
        affected frontier seeded unprocessed.  Returns ``{stream_id:
        DetectionResult}``.
        """
        graphs, warm_state = {}, {}
        churn_threshold = self.engine.config.patch_churn_threshold
        for sid, delta in deltas.items():
            st = self.streams[sid]
            # Tiny deltas (the streaming norm) take the splice patch —
            # bit-identical to the rebuild, without the O(m log m) sort;
            # heavy churn falls back to the vectorized rebuild, which
            # wins once most rows need touching anyway.  The crossover
            # is EngineConfig.patch_churn_threshold, defaulted from the
            # measured sweep in bench_streaming_deltas.py.
            small = len(delta.touched_vertices()) \
                < churn_threshold * max(st.graph.n, 1)
            post = (apply_delta_patch if small else apply_delta)(
                st.graph, delta)
            init = act = None
            if self.warm and st.labels is not None:
                init = st.labels
                if post.n > len(init):  # grown: new vertices start singleton
                    init = np.concatenate([
                        init, np.arange(len(init), post.n, dtype=np.int32)])
                if self.frontier:
                    act = affected_frontier(delta, post.n)
                    self._frontier_fracs.append(
                        float(act.sum()) / max(post.n, 1))
            graphs[sid] = post
            warm_state[sid] = (init, act)
        # Submit as one burst (after all host-side delta work) so the
        # updates coalesce into as few dispatches as possible.
        subs = {sid: self.batcher.submit(graphs[sid], init_labels=init,
                                         init_active=act)
                for sid, (init, act) in warm_state.items()}
        results = self._settle(graphs, subs)
        self.updates += len(results)
        self.warm_updates += sum(r.warm_started for r in results.values())
        return results

    def _settle(self, graphs: dict, subs: dict[object, Submission]) -> dict:
        results = {sid: sub.result() for sid, sub in subs.items()}
        for sid, res in results.items():
            st = self.streams.get(sid)
            if st is None:
                self.streams[sid] = StreamState(graph=graphs[sid],
                                                labels=res.labels)
            else:
                st.graph = graphs[sid]
                st.labels = res.labels
                st.version += 1
        return results

    # --- observability ---

    def stats(self) -> dict:
        """Session counters + the underlying batcher's serving stats."""
        fr = self._frontier_fracs
        return {
            **self.batcher.stats(),
            "streams": len(self.streams),
            "updates": self.updates,
            "warm_updates": self.warm_updates,
            "mean_frontier_frac": float(np.mean(fr)) if fr else 0.0,
        }
