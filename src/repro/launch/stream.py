"""Streaming re-detection sessions over evolving graphs.

A :class:`StreamSession` tracks any number of named *streams* — graphs
that evolve by :class:`repro.core.delta.GraphDelta` updates — and serves
their re-detections through a micro-batching scheduler: concurrent
updates coalesce into single ``Engine.fit_many`` dispatches, each member
warm-started from its stream's previous labels with the delta's affected
frontier seeded unprocessed (GVE-LPA's pruning rule).  The engine pins
bit-parity between this path and a solo warm ``fit`` per stream, so
batching + warm starts change latency and throughput, never results.

    eng = Engine(EngineConfig())
    with StreamSession(eng) as sess:
        sess.add("social", g0)                     # cold initial detection
        res = sess.update("social", delta)         # warm incremental refit
        out = sess.update_many({"a": d1, "b": d2})  # one batched dispatch
    print(sess.stats())

``warm=False`` turns the session into a cold-replay baseline (every
update re-detects from singletons, still batched) — what the streaming
benchmark compares against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delta import (
    GraphDelta,
    affected_frontier,
    apply_delta,
    apply_delta_patch,
)
from repro.core.graph import Graph
from repro.launch.microbatch import MicroBatcher, Submission


@dataclasses.dataclass
class StreamState:
    """Current per-stream snapshot: the graph and its last labels."""
    graph: Graph
    labels: np.ndarray | None = None  # compacted [0, K); None before 1st fit
    version: int = 0                  # number of deltas applied so far


@dataclasses.dataclass
class PreparedUpdate:
    """One stream's post-delta graph + resolved warm state, not yet
    dispatched or committed.  ``StreamSession.prepare_update`` builds it;
    ``commit_update`` applies it after the fit succeeds.  The serving
    tier drives these two halves from different threads (prepare on the
    dispatcher, commit from a result callback); ``update_many`` runs
    them back to back."""
    graph: Graph
    init_labels: np.ndarray | None
    init_active: np.ndarray | None
    frontier_frac: float | None  # None when no frontier seed was built


class StreamUpdateError(RuntimeError):
    """Some members of an ``update_many`` batch failed.

    Successful members are fully committed (graph, labels, counters)
    before this raises; failed streams keep their pre-delta state so a
    retry re-applies the same delta.  ``results`` holds the committed
    ``{stream_id: DetectionResult}``, ``errors`` the per-stream
    exceptions — one member's failure never poisons its siblings.
    """

    def __init__(self, errors: dict, results: dict):
        self.errors = errors
        self.results = results
        detail = "; ".join(f"{sid!r}: {type(e).__name__}: {e}"
                           for sid, e in errors.items())
        super().__init__(
            f"{len(errors)} of {len(errors) + len(results)} stream "
            f"updates failed ({detail}); {len(results)} committed")


class StreamSession:
    """Batched warm re-detection over named evolving-graph streams.

    engine: the :class:`repro.engine.Engine` serving the session.
    warm: warm-start updates from each stream's previous labels
      (``False``: cold re-detection per update — the baseline mode).
    frontier: additionally seed only the delta's affected frontier
      unprocessed (requires ``warm``; ignored otherwise) — propagation
      is then restricted to changed neighborhoods plus whatever they
      wake.
    max_batch / batch_timeout_ms / backend: micro-batcher knobs (see
      :class:`repro.launch.microbatch.MicroBatcher`); alternatively pass
      an existing ``batcher`` to share one scheduler across sessions.
    """

    def __init__(self, engine, *, warm: bool = True, frontier: bool = True,
                 max_batch: int = 16, batch_timeout_ms: float = 2.0,
                 backend: str | None = None, batcher: MicroBatcher | None = None):
        self.engine = engine
        self.warm = warm
        self.frontier = frontier and warm
        self._own_batcher = batcher is None
        self.batcher = batcher if batcher is not None else MicroBatcher(
            engine, max_batch=max_batch, batch_timeout_ms=batch_timeout_ms,
            backend=backend)
        self.streams: dict = {}
        self.updates = 0        # delta updates served
        self.warm_updates = 0   # ... of which warm-started
        self._frontier_fracs: list[float] = []

    # --- lifecycle ---

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._own_batcher:
            self.batcher.close()

    # --- stream registration ---

    def add(self, stream_id, graph: Graph):
        """Register a stream with its initial graph; cold initial fit."""
        return self.add_many({stream_id: graph})[stream_id]

    def add_many(self, graphs: dict) -> dict:
        """Register several streams at once (one coalesced dispatch)."""
        for sid in graphs:
            if sid in self.streams:
                raise ValueError(f"stream {sid!r} already registered")
        subs = {sid: self.batcher.submit(g) for sid, g in graphs.items()}
        return self._settle(graphs, subs)

    def graph(self, stream_id) -> Graph:
        return self.streams[stream_id].graph

    def labels(self, stream_id) -> np.ndarray | None:
        return self.streams[stream_id].labels

    # --- delta updates ---

    def update(self, stream_id, delta: GraphDelta):
        """Apply one delta and re-detect (rides the shared batcher)."""
        return self.update_many({stream_id: delta})[stream_id]

    def update_many(self, deltas: dict) -> dict:
        """Apply a delta per stream and re-detect the batch.

        All updates are submitted as one burst, so (up to ``max_batch``)
        they ride a single ``fit_many`` device dispatch — warm-started
        per member from each stream's previous labels, with the delta's
        affected frontier seeded unprocessed.  Returns ``{stream_id:
        DetectionResult}``.

        Settlement is per-stream: a member whose fit failed raises
        :class:`StreamUpdateError` *after* every successful sibling has
        been committed (post-delta graph, labels, counters).  Failed
        streams keep their pre-delta state — nothing is half-applied,
        and session accounting only ever counts fits that landed.
        """
        preps = {sid: self.prepare_update(sid, delta)
                 for sid, delta in deltas.items()}
        # Submit as one burst (after all host-side delta work) so the
        # updates coalesce into as few dispatches as possible.
        subs = {sid: self.batcher.submit(p.graph, init_labels=p.init_labels,
                                         init_active=p.init_active)
                for sid, p in preps.items()}
        return self._settle(preps, subs)

    def prepare_update(self, sid, delta: GraphDelta) -> PreparedUpdate:
        """Build one stream's post-delta graph + warm state without
        touching session state (commit happens after the fit succeeds)."""
        st = self.streams[sid]
        # Tiny deltas (the streaming norm) take the splice patch —
        # bit-identical to the rebuild, without the O(m log m) sort;
        # heavy churn falls back to the vectorized rebuild, which
        # wins once most rows need touching anyway.  The crossover
        # is EngineConfig.patch_churn_threshold, defaulted from the
        # measured sweep in bench_streaming_deltas.py.
        churn_threshold = self.engine.config.patch_churn_threshold
        small = len(delta.touched_vertices()) \
            < churn_threshold * max(st.graph.n, 1)
        post = (apply_delta_patch if small else apply_delta)(st.graph, delta)
        init = act = None
        frac = None
        if self.warm and st.labels is not None:
            init = st.labels
            if post.n > len(init):  # grown: new vertices start singleton
                init = np.concatenate([
                    init, np.arange(len(init), post.n, dtype=np.int32)])
            if self.frontier:
                act = affected_frontier(delta, post.n)
                frac = float(act.sum()) / max(post.n, 1)
        return PreparedUpdate(graph=post, init_labels=init, init_active=act,
                              frontier_frac=frac)

    def commit_update(self, sid, prep: PreparedUpdate, res) -> None:
        """Commit one successful member: state + counters, atomically
        per stream.  Accounting happens here — after the fit — so a
        failed sibling never leaves phantom ``updates`` counts or
        frontier stats behind."""
        st = self.streams.get(sid)
        if st is None:
            self.streams[sid] = StreamState(graph=prep.graph,
                                            labels=res.labels)
        else:
            st.graph = prep.graph
            st.labels = res.labels
            st.version += 1
        self.updates += 1
        self.warm_updates += bool(res.warm_started)
        if prep.frontier_frac is not None:
            self._frontier_fracs.append(prep.frontier_frac)

    def _settle(self, preps: dict, subs: dict[object, Submission]) -> dict:
        """Per-stream settlement: commit every success, then surface the
        failures together.  A raising ``sub.result()`` used to abort this
        loop mid-way — some streams updated, the rest holding pre-delta
        graphs with counters unrecorded."""
        results: dict = {}
        errors: dict = {}
        for sid, sub in subs.items():
            try:
                res = sub.result()
            except Exception as e:
                errors[sid] = e
                continue
            prep = preps[sid]
            if isinstance(prep, PreparedUpdate):
                self.commit_update(sid, prep, res)
            else:  # add_many path: initial graph, not a counted update
                self.streams[sid] = StreamState(graph=prep, labels=res.labels)
            results[sid] = res
        if errors:
            raise StreamUpdateError(errors, results)
        return results

    # --- observability ---

    def stats(self) -> dict:
        """Session counters + the underlying batcher's serving stats."""
        fr = self._frontier_fracs
        return {
            **self.batcher.stats(),
            "streams": len(self.streams),
            "updates": self.updates,
            "warm_updates": self.warm_updates,
            "mean_frontier_frac": float(np.mean(fr)) if fr else 0.0,
        }
