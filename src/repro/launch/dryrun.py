import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count at first init.
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this driver AOT-compiles the appropriate step function against
pure ShapeDtypeStructs (no allocation), then extracts:
  * compiled.memory_analysis()  — per-device bytes (argument/output/temp/peak)
  * compiled.cost_analysis()    — HLO FLOPs + bytes accessed
  * collective bytes            — parsed from optimized HLO text, summed per
                                  op kind (all-gather/all-reduce/...)
and writes one JSON per cell into experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --arch graph-lpa --mesh multipod
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, input_specs, supported_shapes
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train import steps as S

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_CALL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str, loop_trips: int = 1) -> dict:
    """Per-device collective traffic from optimized HLO text.

    CPU HLO prints operand *references* without shapes, so sizes come from
    the result tuple (left of '='), scaled to on-the-wire bytes per device
    with ring-algorithm formulas over the replica-group size S:
      all-reduce       2 * size * (S-1)/S
      all-gather       result * (S-1)/S      (result = S x operand)
      reduce-scatter   result * (S-1)        (~input * (S-1)/S)
      all-to-all       size * (S-1)/S
      collective-permute  size
    Async -start/-done pairs are counted once (at the -start).

    XLA cost/text inspection sees while bodies once; collectives inside a
    computation whose name marks it as a loop body are multiplied by
    ``loop_trips`` (= the layer-scan trip count for rolled lowerings;
    pass 1 for unrolled lowerings or loop-free programs).
    """
    totals: dict[str, float] = {}
    wire: dict[str, float] = {}
    counts: dict[str, int] = {}
    in_body = False
    depth = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like: %name (args) -> type {  /  ENTRY ...
        if stripped.endswith("{") and ("(" in stripped):
            name = stripped.split(" ", 1)[0].lower()
            in_body = ("body" in name) or ("while" in name)
            depth = 1
            mult = loop_trips if in_body else 1
        m = _COLL_CALL_RE.search(line)
        if m is not None:
            kind = m.group(1)
            lhs = line[: m.end()]
            res_bytes = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(lhs))
            g = _GROUPS_RE.search(line)
            if g:
                s = int(g.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(line)
                s = gl.group(1).count(",") + 1 if gl else 1
            if s <= 1:
                factor = 0.0
            elif kind == "all-reduce":
                factor = 2.0 * (s - 1) / s
            elif kind == "all-gather":
                factor = (s - 1) / s
            elif kind == "reduce-scatter":
                factor = float(s - 1)
            elif kind == "all-to-all":
                factor = (s - 1) / s
            else:  # collective-permute
                factor = 1.0
            mult = loop_trips if in_body else 1
            totals[kind] = totals.get(kind, 0) + res_bytes * mult
            wire[kind] = wire.get(kind, 0) + res_bytes * factor * mult
            counts[kind] = counts.get(kind, 0) + 1
    totals["total"] = sum(totals.values())
    wire["total"] = sum(wire.values())
    return {"bytes": totals, "wire_bytes": wire, "counts": counts,
            "loop_trips_applied": loop_trips}


def _analytic_bytes_per_device(shardings, abstracts, mesh) -> int:
    total = 0
    for sh, ab in zip(jax.tree.leaves(shardings), jax.tree.leaves(abstracts)):
        size = int(np.prod(ab.shape)) * jnp.dtype(ab.dtype).itemsize
        nshards = 1
        if hasattr(sh, "spec"):
            for axis in jax.tree.leaves(tuple(sh.spec)):
                if axis is not None:
                    nshards *= mesh.shape[axis]
        total += size // max(nshards, 1)
    return total


def _lower_cell(arch: str, shape: str, mesh, unroll: bool = True):
    """Returns (lowered, meta) for one cell.

    unroll=True unrolls the layer-group scan so XLA cost analysis sees every
    layer's FLOPs and collectives (while bodies are otherwise counted once).
    """
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=cfg.n_groups)
    sp = SHAPES[shape]
    batch_abs = input_specs(cfg, shape)
    meta: dict = {"params": cfg.param_count(),
                  "active_params": cfg.active_param_count(),
                  "step": sp.step, "seq_len": sp.seq_len,
                  "global_batch": sp.global_batch}

    if sp.step == "train":
        step, rules, psh, osh = S.make_train_step(cfg, mesh, shape)
        params_abs = S.state_shardings(cfg, mesh, shape)[3]
        opt_abs = S.abstract_opt_state(cfg, params_abs)
        bsh = S.batch_shardings(cfg, mesh, shape, batch_abs)
        params_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, psh)
        opt_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            opt_abs, osh)
        batch_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            batch_abs, bsh)
        lowered = step.lower(params_abs, opt_abs, batch_abs,
                             jax.ShapeDtypeStruct((), jnp.int32))
        state_bytes = (_analytic_bytes_per_device(psh, params_abs, mesh)
                       + _analytic_bytes_per_device(osh, opt_abs, mesh))
        meta["analytic_state_bytes_per_device"] = state_bytes
    elif sp.step == "prefill":
        step, rules, psh, csh = S.make_prefill_step(cfg, mesh, shape)
        params_abs = S.state_shardings(cfg, mesh, shape)[3]
        bsh = S.batch_shardings(cfg, mesh, shape, batch_abs)
        params_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, psh)
        batch_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            batch_abs, bsh)
        lowered = step.lower(params_abs, batch_abs)
        meta["analytic_state_bytes_per_device"] = (
            _analytic_bytes_per_device(psh, params_abs, mesh))
    else:  # decode
        step, rules, psh, csh = S.make_decode_step(cfg, mesh, shape)
        params_abs = S.state_shardings(cfg, mesh, shape)[3]
        caches_abs = T.init_decode_caches(cfg, sp.global_batch, sp.seq_len,
                                          abstract=True)
        params_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, psh)
        caches_abs = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            caches_abs, csh)
        lowered = step.lower(params_abs, caches_abs, batch_abs)
        meta["analytic_state_bytes_per_device"] = (
            _analytic_bytes_per_device(psh, params_abs, mesh)
            + _analytic_bytes_per_device(csh, caches_abs, mesh))
    return lowered, meta


def _lower_graph_cell(mesh, n: int = 1 << 26, d_max: int = 64,
                      exchange_every: int = 1):
    """The paper's own workload at pod scale: distributed LPA iteration."""
    from repro.core.distributed import graph_input_specs, make_lpa_step
    from repro.parallel.rules import data_axes  # noqa: F401

    n_dev = int(np.prod(mesh.devices.shape))
    n_pad = ((n + n_dev * 8 - 1) // (n_dev * 8)) * (n_dev * 8)
    step = make_lpa_step(mesh, n_pad, d_max,
                         exchange_every=exchange_every, mode="ref")
    specs = graph_input_specs(n_pad, d_max)
    lowered = step.lower(specs["nbr"], specs["nw"], specs["nmask"],
                         specs["labels"], specs["active"],
                         specs["iteration"], specs["n_real"])
    meta = {"step": "graph_lpa", "n_vertices": n, "d_max": d_max,
            "n_pad": n_pad, "exchange_every": exchange_every,
            "directed_edges_modeled": n * d_max}
    return lowered, meta


def run_cell(arch: str, shape: str, mesh_kind: str,
             out_dir: Path = OUT_DIR, exchange_every: int = 1,
             unroll: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    if arch == "graph-lpa":
        lowered, meta = _lower_graph_cell(mesh,
                                          exchange_every=exchange_every)
    else:
        lowered, meta = _lower_cell(arch, shape, mesh, unroll=unroll)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from repro.parallel.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:  # noqa: BLE001
        mem = {"error": str(e)}
    # rolled lowerings keep the layer scan as a while loop: collectives in
    # the body execute once per group -> multiply by the scan trip count
    if arch == "graph-lpa" or not unroll:
        trips = 1
        if arch != "graph-lpa":
            trips = get_config(arch).n_groups
        coll = collective_bytes(compiled.as_text(), loop_trips=trips)
    else:
        coll = collective_bytes(compiled.as_text(), loop_trips=1)
    rec_unrolled = bool(unroll and arch != "graph-lpa")

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": n_chips,
        "meta": meta, "cost_analysis": cost, "memory_analysis": mem,
        "collectives": coll, "unrolled": rec_unrolled,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_x{exchange_every}" if arch == "graph-lpa" and \
        exchange_every != 1 else ""
    if rec_unrolled:
        suffix += "_unrolled"
    fname = out_dir / f"{arch}_{shape}_{mesh_kind}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {arch} {shape} {mesh_kind}: "
          f"flops={cost.get('flops', float('nan')):.3e} "
          f"wire={coll['wire_bytes'].get('total', 0):.3e}B "
          f"compile={t_compile:.1f}s -> {fname.name}", flush=True)
    return rec


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch, cfg in ARCHS.items():
        for shape in supported_shapes(cfg):
            cells.append((arch, shape))
    cells.append(("graph-lpa", "graph"))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--exchange-every", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan: full-fidelity cost "
                         "analysis, ~60x slower compile (hillclimb cells)")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s, m) for a, s in all_cells()
                for m in (("pod", "multipod") if args.both_meshes
                          else (args.mesh,))]
    else:
        assert args.arch, "--arch required without --all"
        shapes = ([args.shape] if args.shape else
                  (supported_shapes(get_config(args.arch))
                   if args.arch != "graph-lpa" else ["graph"]))
        todo = [(args.arch, s, m) for s in shapes
                for m in (("pod", "multipod") if args.both_meshes
                          else (args.mesh,))]

    failures = []
    for arch, shape, mesh_kind in todo:
        suffix = ""
        fname = OUT_DIR / f"{arch}_{shape}_{mesh_kind}{suffix}.json"
        if args.skip_existing and fname.exists():
            print(f"[dryrun] skip existing {fname.name}", flush=True)
            continue
        try:
            run_cell(arch, shape, mesh_kind,
                     exchange_every=args.exchange_every,
                     unroll=args.unroll)
        except Exception:  # noqa: BLE001
            print(f"[dryrun] FAILED {arch} {shape} {mesh_kind}", flush=True)
            traceback.print_exc()
            failures.append((arch, shape, mesh_kind))
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}", flush=True)
        raise SystemExit(1)
    print("[dryrun] all cells OK", flush=True)


if __name__ == "__main__":
    main()
