"""Span tracer: contextvar-nested wall-time spans + Chrome-trace export.

The same attribution idea as ``trace_context`` in ``engine/cache.py`` —
a ContextVar carries the current span so nested stages parent correctly
across threads and concurrent engines — but recording *durations*
instead of retrace counts.  Spans wrap host-side stage boundaries only
(engine prepare/dispatch/compact, ooc partition visits / prefetch / halo
exchange, serving admission→dispatch→settle); they never enter jitted or
per-sweep code, which the R006 lint rule enforces.

Export is the Chrome trace-event JSON array (``chrome://tracing`` /
Perfetto): complete events (``"ph": "X"``) with microsecond timestamps
relative to tracer start, ``tid`` = OS thread ident so concurrent
request lanes render as parallel tracks.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Any

_MAX_SPANS = 65536  # bounded history: long servers drop oldest spans

_CURRENT: contextvars.ContextVar["Span | None"] = \
    contextvars.ContextVar("repro_current_span", default=None)

_ids = itertools.count(1)


@dataclasses.dataclass
class Span:
    """One finished (or in-flight) wall-time interval."""
    name: str
    t0: float                      # perf_counter at enter
    dur: float = 0.0               # seconds; 0.0 while in flight
    span_id: int = 0
    parent_id: int = 0             # 0 = root
    tid: int = 0                   # OS thread ident
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    def set(self, **attrs) -> "Span":
        """Attach attributes after enter (counts known only at exit)."""
        self.attrs.update(attrs)
        return self


class _NullSpan:
    """Returned when tracing is disabled — absorbs ``.set()`` for free."""
    __slots__ = ()

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class Tracer:
    """Bounded in-memory span recorder with a Chrome-trace exporter."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=_MAX_SPANS)
        self._epoch = time.perf_counter()

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        if not self.enabled:
            yield _NULL
            return
        parent = _CURRENT.get()
        s = Span(name=name, t0=time.perf_counter(), span_id=next(_ids),
                 parent_id=parent.span_id if parent else 0,
                 tid=threading.get_ident(), attrs=dict(attrs))
        token = _CURRENT.set(s)
        try:
            yield s
        finally:
            _CURRENT.reset(token)
            s.dur = time.perf_counter() - s.t0
            with self._lock:
                self._spans.append(s)

    def current(self) -> Span | None:
        return _CURRENT.get()

    def spans(self, prefix: str = "") -> list[Span]:
        with self._lock:
            return [s for s in self._spans if s.name.startswith(prefix)]

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
        self._epoch = time.perf_counter()

    def chrome_trace(self) -> list[dict]:
        """Trace-event list: complete (``ph:"X"``) events, µs timebase."""
        pid = os.getpid()
        with self._lock:
            spans = list(self._spans)
        events = []
        for s in spans:
            args = {k: v for k, v in s.attrs.items()}
            if s.parent_id:
                args["parent_span"] = s.parent_id
            events.append({
                "name": s.name, "ph": "X", "pid": pid, "tid": s.tid,
                "ts": round((s.t0 - self._epoch) * 1e6, 3),
                "dur": round(s.dur * 1e6, 3),
                "args": args,
            })
        return events

    def export_chrome(self, path) -> int:
        """Write the Chrome-trace JSON array; returns the event count."""
        events = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump(events, fh)
        return len(events)


# Process-global tracer.  ``span("engine.fit")`` is the one-liner every
# stage boundary uses; disable with ``TRACER.enabled = False`` (spans
# then cost one attribute read and an empty yield).
TRACER = Tracer()
span = TRACER.span
