"""Unified runtime observability: metrics registry, span tracer,
per-fit convergence profiles.

Three surfaces, one import point:

* :class:`MetricsRegistry` / :data:`REGISTRY` — process-global named
  counters / gauges / histograms with scoped child views; the single
  ``snapshot()`` behind every component's legacy ``stats()`` dict.
* :class:`Tracer` / :data:`TRACER` / :func:`span` — contextvar-nested
  wall-time spans over host-side stage boundaries, exported as a
  Chrome-trace (``chrome://tracing`` / Perfetto) JSON array.
* :class:`ConvergenceProfile` — per-sub-sweep frontier/changed curves
  captured device-side (in-core) or at existing host sync points (ooc),
  surfaced as ``DetectionResult.profile`` behind
  ``EngineConfig.profile``.
* :class:`QualityReport` / :func:`compute_quality` — per-fit result
  quality (modularity, disconnected fraction, community sizes, label
  churn) behind ``EngineConfig.quality``; host-side, post-convergence,
  bit-parity-preserving.
* :func:`prometheus_text` / :class:`MetricsServer` / :class:`JsonlSink`
  — exporters: Prometheus text format (with span-id exemplars on
  latency histograms), a stdlib HTTP scrape endpoint, and a JSONL file
  sink.

``python -m repro.launch.obs`` dumps the registry and exports traces
for a standard workload.
"""
from repro.obs.convergence import (
    ConvergenceProfile,
    PhaseProfile,
    empty_batch_profile_buffer,
    empty_profile_buffer,
    phase_from_batch_buffer,
    phase_from_buffer,
    phase_from_rows,
)
from repro.obs.export import (
    JsonlSink,
    MetricsServer,
    parse_prometheus_text,
    prometheus_text,
)
from repro.obs.quality import (
    QualityReport,
    canonical_labels,
    compute_quality,
    label_churn,
    record_report,
)
from repro.obs.registry import (
    REGISTRY,
    CappedCounterSet,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Scope,
)
from repro.obs.trace import TRACER, Span, Tracer, span

__all__ = [
    "REGISTRY", "MetricsRegistry", "Scope", "Counter", "Gauge", "Histogram",
    "CappedCounterSet",
    "TRACER", "Tracer", "Span", "span",
    "ConvergenceProfile", "PhaseProfile",
    "empty_profile_buffer", "empty_batch_profile_buffer",
    "phase_from_buffer", "phase_from_batch_buffer", "phase_from_rows",
    "QualityReport", "compute_quality", "label_churn", "canonical_labels",
    "record_report",
    "prometheus_text", "parse_prometheus_text", "MetricsServer", "JsonlSink",
]
