"""Metric exporters: Prometheus/OpenMetrics text, HTTP endpoint, JSONL.

Three ways out of the process for the :mod:`repro.obs.registry` state:

* :func:`prometheus_text` — OpenMetrics-flavoured text exposition
  (cumulative ``le`` buckets, ``_total`` counters, ``# EOF``), with
  per-bucket exemplars carrying the tracer span id that produced the
  latest observation, so a slow latency bucket links straight to its
  Chrome-trace span.
* :class:`MetricsServer` — a stdlib ``ThreadingHTTPServer`` serving
  ``/metrics`` (text format), ``/metrics.json`` (snapshot), and
  ``/healthz``; ``serve --metrics-port`` and the benches scrape it.
* :class:`JsonlSink` — append-a-snapshot-per-line file sink for offline
  trend analysis (``serve --metrics-jsonl``).

:func:`parse_prometheus_text` is the strict line-grammar counterpart the
tests and the CI scrape check run over the endpoint's output — the
exposition never drifts from something a real scraper would accept.
"""
from __future__ import annotations

import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                REGISTRY)

# Prometheus metric-name alphabet; everything else becomes "_".
_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")
_PREFIX = "repro_"


def _metric_name(name: str) -> str:
    return _PREFIX + _NAME_SANITIZE.sub("_", name)


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return format(float(v), ".10g")


def prometheus_text(registry: MetricsRegistry | None = None) -> str:
    """Render a registry in Prometheus text exposition format.

    Counters get the ``_total`` suffix, histograms cumulative ``le``
    buckets (``+Inf`` last) plus ``_sum``/``_count``, and buckets whose
    latest observation ran inside a tracer span carry an OpenMetrics
    exemplar: ``... # {span_id="17"} 42.5``.
    """
    reg = registry if registry is not None else REGISTRY
    lines: list[str] = []
    for name, metric in sorted(reg.metrics().items()):
        pname = _metric_name(name)
        if isinstance(metric, Counter):
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname}_total {_fmt(metric.value)}")
        elif isinstance(metric, Gauge):
            v = metric.value
            if not isinstance(v, (int, float)):
                continue  # non-numeric gauge (never set); unexportable
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_fmt(v)}")
        elif isinstance(metric, Histogram):
            lines.append(f"# TYPE {pname} histogram")
            counts = metric.summary()["buckets"]
            exemplars = metric.exemplars()
            cum = 0
            for i, b in enumerate(metric.buckets):
                cum += counts[f"le_{b:g}"]
                line = f'{pname}_bucket{{le="{_fmt(b)}"}} {cum}'
                ex = exemplars[i]
                if ex is not None:
                    line += f' # {{span_id="{ex[1]}"}} {_fmt(ex[0])}'
                lines.append(line)
            cum += counts["overflow"]
            line = f'{pname}_bucket{{le="+Inf"}} {cum}'
            ex = exemplars[-1]
            if ex is not None:
                line += f' # {{span_id="{ex[1]}"}} {_fmt(ex[0])}'
            lines.append(line)
            lines.append(f"{pname}_sum {_fmt(metric.sum)}")
            lines.append(f"{pname}_count {metric.count}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# --- strict parser (tests + CI scrape check) -------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[+-]?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|Inf)|NaN)"
    r"(?: # \{(?P<exlabels>[^}]*)\} "
    r"(?P<exvalue>[+-]?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|Inf)|NaN))?$")
_LABEL_RE = re.compile(r'^([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"$')
_COMMENT_RE = re.compile(r"^# (?:TYPE|HELP|UNIT) [a-zA-Z_:][a-zA-Z0-9_:]* .+$")


def _parse_labels(raw: str | None) -> dict[str, str]:
    labels: dict[str, str] = {}
    if not raw:
        return labels
    for pair in raw.split(","):
        m = _LABEL_RE.match(pair.strip())
        if m is None:
            raise ValueError(f"malformed label pair: {pair!r}")
        labels[m.group(1)] = m.group(2)
    return labels


def parse_prometheus_text(text: str) -> dict[str, list[dict[str, Any]]]:
    """Parse text exposition back into samples; raise on any bad line.

    Returns ``{metric_name: [{"labels": {...}, "value": float,
    "exemplar": {"labels": {...}, "value": float} | None}, ...]}``.
    Deliberately strict — this is the grammar gate the CI scrape check
    leans on, not a lenient convenience parser.
    """
    out: dict[str, list[dict[str, Any]]] = {}
    saw_eof = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if saw_eof:
            raise ValueError(f"line {lineno}: content after # EOF")
        if line == "# EOF":
            saw_eof = True
            continue
        if line.startswith("#"):
            if _COMMENT_RE.match(line) is None:
                raise ValueError(f"line {lineno}: malformed comment: {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        exemplar = None
        if m.group("exvalue") is not None:
            exemplar = {"labels": _parse_labels(m.group("exlabels")),
                        "value": float(m.group("exvalue"))}
        out.setdefault(m.group("name"), []).append(
            {"labels": _parse_labels(m.group("labels")),
             "value": float(m.group("value")),
             "exemplar": exemplar})
    if not saw_eof:
        raise ValueError("missing # EOF terminator")
    return out


# --- HTTP endpoint ---------------------------------------------------------

class MetricsServer:
    """Stdlib HTTP exporter for a metrics registry.

    ``port=0`` binds an ephemeral port (read it back from ``.port``);
    the server runs on one daemon thread and every route renders at
    request time, so scrapes always see live values:

    * ``GET /metrics`` — Prometheus text format (:func:`prometheus_text`)
    * ``GET /metrics.json`` — ``registry.snapshot()`` as JSON
    * ``GET /healthz`` — ``{"ok": true, ...}``, merged with the optional
      ``health_fn()`` dict (the serving tier plugs its HealthMonitor in)
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 port: int = 0, host: str = "127.0.0.1",
                 health_fn: Callable[[], dict] | None = None):
        reg = registry if registry is not None else REGISTRY
        server = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path == "/metrics":
                    body = prometheus_text(reg).encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/metrics.json":
                    body = json.dumps(reg.snapshot(), default=str).encode()
                    ctype = "application/json"
                elif self.path == "/healthz":
                    payload = {"ok": True}
                    if health_fn is not None:
                        payload.update(health_fn())
                    body = json.dumps(payload, default=str).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # scrapes are not log events
                del args

        del server
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-exporter", daemon=True)
        self._started = False

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MetricsServer":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def close(self) -> None:
        if self._started:
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
        self._httpd.server_close()

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


# --- JSONL sink ------------------------------------------------------------

class JsonlSink:
    """Append one timestamped registry snapshot per line to a file."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fh = open(path, "a", encoding="utf-8")

    def emit(self, registry: MetricsRegistry | None = None,
             **extra: Any) -> dict[str, Any]:
        reg = registry if registry is not None else REGISTRY
        record = {"ts": time.time(), **extra, "metrics": reg.snapshot()}
        line = json.dumps(record, default=str)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()
        return record

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
