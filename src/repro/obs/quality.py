"""Per-fit result-quality telemetry: is the answer still good?

The PR-9 observability layer (registry, spans, convergence profiles)
instruments *how fast* detection runs; this module instruments *whether
the results stay good* as tenants stream deltas — modularity (paper
Eq. 1), the disconnected-community fraction (the paper's headline
invariant, live instead of test-only), community count and size
distribution, and label churn against the previous fit of the same
fingerprint/tenant.

Everything here runs on the host at a stage boundary, *after* the sweep
loop has converged and the final labels are already on the host — the
only device work is the pre-existing jitted reductions
(:func:`repro.core.modularity.modularity`,
``DetectionResult.check_connected``) invoked once per fit on the final
assignment, and the engine pays those only in "full" mode ("basic"
stays host-only: sizes, count, churn).  Nothing touches the compiled plans: ``EngineConfig.quality``
is deliberately NOT part of ``algo_key()``, so labels and iteration
counts are bit-identical across quality modes by construction.  The R006
lint rule keeps these hooks out of jitted bodies and sweep-dispatch
loops.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

QUALITY_MODES = ("off", "basic", "full")

# Churn is a fraction in [0, 1]; fine buckets at the low end where the
# steady-state streaming signal lives.
CHURN_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0)


@dataclasses.dataclass
class QualityReport:
    """Quality of one detection result.  ``DetectionResult.quality``."""

    mode: str                  # "basic" | "full"
    n: int                     # vertices covered by the assignment
    num_communities: int
    # Paper Eq. 1.  The engine pays this device pass only in "full" mode
    # (it costs about one extra sweep); None on "basic" engine reports
    # and host-only (ooc) reports.  Direct compute_quality callers get it
    # whenever they pass a graph.
    modularity: float | None
    # Fraction of communities that are internally disconnected — the
    # paper's headline guarantee says 0.0 after any split mode.  Only
    # computed in "full" mode (it is the expensive split_lp-rooted pass);
    # None in "basic" and on host-only reports.
    disconnected_fraction: float | None
    size_min: int
    size_max: int
    size_mean: float
    size_p50: float
    size_p99: float
    # Fraction of vertices whose community changed vs the previous
    # assignment (see :func:`label_churn`).  None when there was no
    # previous assignment to compare against (cold fit).
    churn: float | None
    churn_compared: int        # vertices the churn fraction was taken over

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def canonical_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel communities by order of first occurrence.

    Two assignments that induce the same partition canonicalize to the
    same array no matter how either names its communities, so element-wise
    comparison measures membership drift rather than label renaming.
    """
    labels = np.asarray(labels)
    _, first, inverse = np.unique(labels, return_index=True,
                                  return_inverse=True)
    # np.unique ranks communities by label value; re-rank by first
    # occurrence so community naming cannot manufacture churn.
    order = np.argsort(np.argsort(first))
    return order[inverse.reshape(labels.shape)].astype(np.int64)


def label_churn(prev: Any, new: Any) -> tuple[float | None, int]:
    """``(churned_fraction, compared)`` between two assignments.

    Both sides are canonicalized (:func:`canonical_labels`) and compared
    element-wise over the common vertex prefix, so identical partitions
    report exactly 0.0 regardless of labeling.  For differing partitions
    this is an upper bound on membership change: a moved vertex always
    counts, and a move that re-ranks community first-occurrence order can
    drag bystanders with it.  Returns ``(None, 0)`` with no previous
    assignment.
    """
    if prev is None:
        return None, 0
    prev = np.asarray(prev)
    new = np.asarray(new)
    k = min(prev.shape[0], new.shape[0])
    if k == 0:
        return None, 0
    a = canonical_labels(prev[:k])
    b = canonical_labels(new[:k])
    return float(np.mean(a != b)), int(k)


def compute_quality(labels: Any, *, mode: str, graph: Any = None,
                    prev_labels: Any = None,
                    num_communities: int | None = None,
                    modularity: float | None = None,
                    disconnected_fraction: float | None = None,
                    ) -> QualityReport:
    """Build a :class:`QualityReport` for a final label assignment.

    ``graph=None`` produces a host-only report (sizes, count, churn) —
    the out-of-core path uses this, since the full graph never sits on
    the device there.  ``modularity`` / ``disconnected_fraction`` accept
    already-computed values (``compute_metrics``, ``check_connected``'s
    cache) so quality never repeats a device pass another layer paid for.
    """
    if mode not in QUALITY_MODES or mode == "off":
        raise ValueError(f"quality mode must be 'basic' or 'full', "
                         f"got {mode!r}")
    labels = np.asarray(labels)
    n = int(labels.shape[0])
    sizes = np.bincount(labels.astype(np.int64, copy=False)) if n else \
        np.zeros(0, dtype=np.int64)
    sizes = sizes[sizes > 0]
    k = int(num_communities if num_communities is not None else sizes.shape[0])
    if modularity is None and graph is not None:
        import jax.numpy as jnp

        from repro.core.modularity import modularity as _modularity
        modularity = float(_modularity(graph, jnp.asarray(labels)))
    churn, compared = label_churn(prev_labels, labels)
    return QualityReport(
        mode=mode, n=n, num_communities=k,
        modularity=modularity,
        disconnected_fraction=(disconnected_fraction
                               if mode == "full" else None),
        size_min=int(sizes.min()) if sizes.size else 0,
        size_max=int(sizes.max()) if sizes.size else 0,
        size_mean=float(sizes.mean()) if sizes.size else 0.0,
        size_p50=float(np.percentile(sizes, 50)) if sizes.size else 0.0,
        size_p99=float(np.percentile(sizes, 99)) if sizes.size else 0.0,
        churn=churn, churn_compared=compared)


def record_report(scope: Any, report: QualityReport) -> None:
    """Write a report through a registry scope (``<scope>.quality.*``-style
    names; callers pass an already-namespaced scope).

    Gauges carry the latest fit's level (modularity, community count,
    disconnected fraction); the churn histogram accumulates the drift
    distribution across fits.  Host-side only — R006 territory if this
    ever moved into a sweep loop.
    """
    if scope is None or report is None:
        return
    scope.counter("reports").inc()
    scope.gauge("communities").set(report.num_communities)
    scope.gauge("size_max").set(report.size_max)
    if report.modularity is not None:
        scope.gauge("modularity").set(report.modularity)
    if report.disconnected_fraction is not None:
        scope.gauge("disconnected_fraction").set(report.disconnected_fraction)
    if report.churn is not None:
        scope.histogram("churn", CHURN_BUCKETS).observe(report.churn)
