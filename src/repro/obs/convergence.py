"""Per-fit convergence profiles: the frontier-decay curve of one run.

FLPA (Traag & Šubelj, arXiv 2209.13338) wins or loses on exactly one
curve: how fast the active frontier decays per sweep.  A
``ConvergenceProfile`` captures that curve for every fit — per sub-sweep
candidate (active-frontier) size, labels-changed count, and the
sub-sweep index — without touching the hot loop's host-sync discipline:

* **In-core paths** record **device-side** into a preallocated
  ``(2 * max_iterations, 3)`` int32 buffer carried through the
  ``lax.while_loop`` state (row ``2*it + sweep`` per parity sub-sweep)
  and fetched **once** after the existing post-convergence
  ``block_until_ready`` — zero new host syncs, so the R001 lint gate
  stays clean.  The buffer write never feeds back into labels or the
  convergence test, so profiled runs are bit-identical to unprofiled
  ones by construction (and the parity suite asserts it).
* **The out-of-core driver** already reduces per-sub-sweep changed
  counts on the host (they drive its convergence loop), so it records
  rows host-side at those existing sync points — again zero new syncs.

``EngineConfig.profile`` selects depth: ``"off"`` (no buffer in the
executable at all — the flag joins ``algo_key()``), ``"convergence"``
(propagation phase), ``"full"`` (propagation + Split-Last phase).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PhaseProfile:
    """Per-sub-sweep counters for one phase of one fit."""
    phase: str            # "propagation" | "split"
    sweep: np.ndarray     # (S,) int32 sub-sweep index (2*it + parity)
    active: np.ndarray    # (S,) candidate-vertex count entering the sweep
    changed: np.ndarray   # (S,) vertices that changed label in the sweep
    truncated: bool = False  # phase outran the preallocated buffer

    @property
    def num_sub_sweeps(self) -> int:
        return int(len(self.sweep))

    def to_dict(self) -> dict:
        return {"phase": self.phase, "sweep": self.sweep.tolist(),
                "active": self.active.tolist(),
                "changed": self.changed.tolist(),
                "truncated": self.truncated}


@dataclasses.dataclass
class ConvergenceProfile:
    """Full profile of one fit: propagation always, split under "full"."""
    propagation: PhaseProfile
    split: PhaseProfile | None = None
    n: int = 0            # real vertex count (frontier fractions)

    def frontier_decay(self) -> np.ndarray:
        """Active-frontier fraction per propagation sub-sweep — the FLPA
        comparison curve (active[t] / n)."""
        if not self.n:
            return np.zeros(0, np.float64)
        return self.propagation.active.astype(np.float64) / float(self.n)

    def to_dict(self) -> dict:
        return {"n": self.n, "propagation": self.propagation.to_dict(),
                "split": self.split.to_dict() if self.split else None}


def empty_profile_buffer(rows: int):
    """Device-side preallocation: (rows, 3) int32, -1 marks unwritten."""
    import jax.numpy as jnp
    return jnp.full((rows, 3), -1, jnp.int32)


def empty_batch_profile_buffer(rows: int, k1: int):
    """Batched preallocation: (rows, 2, k1) int32 [active, changed]."""
    import jax.numpy as jnp
    return jnp.full((rows, 2, k1), -1, jnp.int32)


def phase_from_buffer(phase: str, buf, rows: int,
                      truncated: bool = False) -> PhaseProfile:
    """Trim a fetched (cap, 3) [active, changed, sweep] buffer to the
    ``rows`` sub-sweeps that actually ran."""
    arr = np.asarray(buf)
    rows = max(0, min(int(rows), arr.shape[0]))
    return PhaseProfile(phase=phase,
                        sweep=arr[:rows, 2].astype(np.int32),
                        active=arr[:rows, 0].astype(np.int64),
                        changed=arr[:rows, 1].astype(np.int64),
                        truncated=truncated)


def phase_from_batch_buffer(phase: str, buf, slot: int,
                            rows: int, truncated: bool = False,
                            ) -> PhaseProfile:
    """Slice one member's curve out of a fetched (cap, 2, k1) buffer."""
    arr = np.asarray(buf)
    rows = max(0, min(int(rows), arr.shape[0]))
    return PhaseProfile(phase=phase,
                        sweep=np.arange(rows, dtype=np.int32),
                        active=arr[:rows, 0, slot].astype(np.int64),
                        changed=arr[:rows, 1, slot].astype(np.int64),
                        truncated=truncated)


def solo_profile(pbuf, lpa_iters: int, sbuf, split_iters: int,
                 split_cap: int, n: int) -> ConvergenceProfile:
    """Assemble a solo fit's profile from fetched device buffers.

    ``pbuf``: propagation (cap, 3) buffer, valid rows = ``2 * lpa_iters``.
    ``sbuf``: optional split buffer capped at ``split_cap`` sweeps — a
    split that outran the cap overwrote the last row (flagged truncated).
    """
    prop = phase_from_buffer("propagation", pbuf, 2 * lpa_iters)
    split = None
    if sbuf is not None:
        split = phase_from_buffer("split", sbuf,
                                  min(split_iters, split_cap),
                                  truncated=split_iters > split_cap)
    return ConvergenceProfile(propagation=prop, split=split, n=n)


def batch_profiles(pbuf, lpa_iters, sbuf, split_iters, split_cap: int,
                   sizes) -> list[ConvergenceProfile]:
    """Per-slot profiles from a batched run's fetched (cap, 2, k1)
    buffers.  Each slot's curve is trimmed to the sub-sweeps *its*
    standalone run would have executed (frozen slots stop counting)."""
    pb = np.asarray(pbuf)
    sb = None if sbuf is None else np.asarray(sbuf)
    lpa_iters = np.asarray(lpa_iters)
    split_iters = None if split_iters is None else np.asarray(split_iters)
    out = []
    for i, n_i in enumerate(np.asarray(sizes)):
        prop = phase_from_batch_buffer("propagation", pb, i,
                                       2 * int(lpa_iters[i]))
        split = None
        if sb is not None:
            si = int(split_iters[i])
            split = phase_from_batch_buffer("split", sb, i,
                                            min(si, split_cap),
                                            truncated=si > split_cap)
        out.append(ConvergenceProfile(propagation=prop, split=split,
                                      n=int(n_i)))
    return out


def phase_from_rows(phase: str, rows: list[tuple[int, int, int]],
                    ) -> PhaseProfile:
    """Host-side accumulation (the out-of-core driver): a list of
    (sweep_index, active_count, changed_count) rows."""
    arr = np.asarray(rows, dtype=np.int64).reshape(-1, 3)
    return PhaseProfile(phase=phase, sweep=arr[:, 0].astype(np.int32),
                        active=arr[:, 1], changed=arr[:, 2])
