"""Process-wide metrics registry: counters, gauges, histograms.

One namespace for every runtime surface that used to keep its own ad-hoc
``stats()`` dict (engine warm cache, micro-batcher, admission queue,
slice loader, memory ledger).  Components claim a *scope* — a child view
whose metric names are prefixed and stored in the shared root — write
through plain ``Counter``/``Gauge``/``Histogram`` handles, and keep their
old ``stats()`` methods as thin reads over the same handles.

Design constraints, in order:

* **Thread-safe.**  The serving tier mutates metrics from client
  threads, the dispatcher, and the batcher worker at once.  One root
  lock guards the name table; each metric instance carries its own lock
  so hot counters don't serialize against unrelated scopes.
* **Multi-instance.**  Tests build many engines/services per process.
  ``scope()`` hands out the bare prefix to the first claimant and
  ``prefix#N`` to later ones, so per-instance reads never alias another
  instance's numbers; ``Scope.release()`` frees the label and drops the
  metrics (wired into ``close()`` where components have one).
* **No device work.**  Everything here is host-side bookkeeping; the
  R006 lint rule keeps these calls out of jitted / per-sweep code.
"""
from __future__ import annotations

import bisect
import re
import threading
from collections import deque
from typing import Any, Callable, Iterable

_RESERVOIR = 4096  # raw samples kept per histogram for exact small-N quantiles


class Counter:
    """Monotonic event count."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time level (queue depth, resident bytes, cache entries)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, n) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket distribution with a bounded raw-sample reservoir.

    Buckets are cumulative upper bounds (Prometheus-style ``le``); the
    reservoir keeps the most recent ``_RESERVOIR`` observations so small
    runs get *exact* quantiles — the thin-view ``stats()`` methods that
    used to hold their own latency lists read them from here instead.
    """

    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_count", "_samples",
                 "_exemplars")

    def __init__(self, buckets: Iterable[float]):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)  # +1 = overflow
        self._sum = 0.0
        self._count = 0
        self._samples: deque[float] = deque(maxlen=_RESERVOIR)
        # Last (value, span_id) observed per bucket (incl. overflow) —
        # OpenMetrics exemplars linking a latency bucket to the trace
        # span that produced it.  Only kept when observe() ran inside a
        # tracer span.
        self._exemplars: list[tuple[float, int] | None] = \
            [None] * (len(self.buckets) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        idx = bisect.bisect_left(self.buckets, v)
        # Exemplar capture: one contextvar read; the tracer never calls
        # back into the registry, so no lock-order hazard.
        from repro.obs.trace import TRACER
        cur = TRACER.current()
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1
            self._samples.append(v)
            if cur is not None:
                self._exemplars[idx] = (v, cur.span_id)

    def exemplars(self) -> list[tuple[float, int] | None]:
        """Per-bucket ``(value, span_id)`` exemplars (overflow last)."""
        with self._lock:
            return list(self._exemplars)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Exact over the reservoir (the full stream while it fits)."""
        with self._lock:
            if not self._samples:
                return 0.0
            xs = sorted(self._samples)
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def summary(self) -> dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
            xs = sorted(self._samples)

        def _q(q: float) -> float:
            return xs[min(int(q * len(xs)), len(xs) - 1)] if xs else 0.0
        return {"count": total, "sum": s,
                "mean": (s / total if total else 0.0),
                "p50": _q(0.50), "p95": _q(0.95), "p99": _q(0.99),
                "buckets": {f"le_{b:g}": c
                            for b, c in zip(self.buckets, counts)}
                | {"overflow": counts[-1]}}


class Scope:
    """Child view of a registry: names are prefixed into the shared root."""

    def __init__(self, root: "MetricsRegistry", label: str):
        self._root = root
        self.label = label
        self._released = False

    def counter(self, name: str) -> Counter:
        return self._root._get(f"{self.label}.{name}", Counter)

    def gauge(self, name: str) -> Gauge:
        return self._root._get(f"{self.label}.{name}", Gauge)

    def histogram(self, name: str, buckets: Iterable[float]) -> Histogram:
        return self._root._get(f"{self.label}.{name}", Histogram, buckets)

    def scope(self, prefix: str) -> "Scope":
        return self._root.scope(f"{self.label}.{prefix}")

    def release(self) -> None:
        """Free this scope's label and drop its metrics from the root."""
        if not self._released:
            self._released = True
            self._root._release(self.label)


class MetricsRegistry:
    """Thread-safe named-metric store with scoped child views."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Any] = {}
        self._labels: set[str] = set()

    def _get(self, name: str, kind: Callable, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(*args)
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets: Iterable[float]) -> Histogram:
        return self._get(name, Histogram, buckets)

    def scope(self, prefix: str) -> Scope:
        """Claim a child namespace.  The first claimant of ``prefix``
        gets the bare label; later ones get ``prefix#1``, ``prefix#2``…
        so per-instance metrics never alias across instances."""
        with self._lock:
            label, i = prefix, 0
            while label in self._labels:
                i += 1
                label = f"{prefix}#{i}"
            self._labels.add(label)
        return Scope(self, label)

    def _release(self, label: str) -> None:
        # Child labels ("serve.admission" under "serve") go too — else the
        # next instance gets the bare parent label but "#1"-suffixed
        # children, and absolute child-metric names silently alias.
        with self._lock:
            self._labels = {l for l in self._labels
                            if l != label and not l.startswith(label + ".")}
            dead = [k for k in self._metrics
                    if k == label or k.startswith(label + ".")]
            for k in dead:
                del self._metrics[k]

    def metrics(self) -> dict[str, Any]:
        """Shallow copy of ``name -> metric instance`` (exporters read the
        live handles for bucket counts and exemplars the summary drops)."""
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> dict[str, Any]:
        """Flat ``name -> value`` dict; histograms expand to summaries."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: dict[str, Any] = {}
        for name, m in items:
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def render_text(self) -> str:
        """Human-readable one-metric-per-line dump (for CLIs / logs)."""
        lines = []
        for name, v in self.snapshot().items():
            if isinstance(v, dict):  # histogram summary
                lines.append(
                    f"{name}  count={v['count']} mean={v['mean']:.4g} "
                    f"p50={v['p50']:.4g} p95={v['p95']:.4g} "
                    f"p99={v['p99']:.4g}")
            elif isinstance(v, float):
                lines.append(f"{name}  {v:.6g}")
            else:
                lines.append(f"{name}  {v}")
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._labels.clear()


class CappedCounterSet:
    """Bounded per-key counter family over an unbounded id space.

    The first ``max_labels`` distinct keys each get their own counter
    (``<scope>.<name>.<key>``); every later key shares one
    ``<scope>.<name>.other`` overflow counter.  This is how per-tenant
    counts enter the registry without per-tenant cardinality: tenant ids
    are caller-chosen strings, and a registry must never absorb an
    unbounded label space (the Prometheus exporter renders every name).
    Exact per-key numbers stay available from the owning component's
    ``stats()`` dict.
    """

    def __init__(self, scope: "Scope", name: str, max_labels: int = 16):
        if max_labels < 1:
            raise ValueError("max_labels must be >= 1")
        self._scope = scope
        self._name = name
        self._max = max_labels
        self._lock = threading.Lock()
        self._handles: dict[str, Counter] = {}
        self._other: Counter | None = None

    def counter(self, key: Any) -> Counter:
        k = str(key)
        with self._lock:
            h = self._handles.get(k)
            if h is None:
                if len(self._handles) < self._max:
                    # Keys are metric-name segments: no dots (fake
                    # hierarchy) or whitespace.
                    safe = re.sub(r"[^A-Za-z0-9_\-]", "_", k)
                    h = self._scope.counter(f"{self._name}.{safe}")
                    self._handles[k] = h
                else:
                    if self._other is None:
                        self._other = self._scope.counter(
                            f"{self._name}.other")
                    h = self._other
            return h

    def inc(self, key: Any, n: int = 1) -> None:
        self.counter(key).inc(n)

    @property
    def tracked(self) -> tuple[str, ...]:
        """Keys that own a dedicated counter (≤ ``max_labels``)."""
        with self._lock:
            return tuple(self._handles)


# The process-global root every component defaults to.  Tests that need
# isolation construct their own MetricsRegistry and inject it.
REGISTRY = MetricsRegistry()
