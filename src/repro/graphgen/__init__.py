from repro.graphgen.synthetic import (  # noqa: F401
    erdos_renyi,
    evolving_sequence,
    figure1_graph,
    grid2d,
    karate_club,
    planted_partition,
    ring_of_cliques,
    rmat,
    sbm,
)
