"""Synthetic graph generators (host-side numpy).

These back the reduced-scale reproduction of the paper's benchmark suite
(Table 1 graphs are 25M..3.8B edges — out of reach on a 1-core CPU container),
plus the crafted examples from the paper's Figures 1 and 2.
"""
from __future__ import annotations

import numpy as np

from repro.core.delta import GraphDelta, undirected_edges
from repro.core.graph import Graph, build_graph


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> Graph:
    """G(n, p) with p chosen to hit ``avg_degree``."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    e = rng.integers(0, n, size=(int(m * 1.2), 2))
    e = e[e[:, 0] != e[:, 1]][:m]
    return build_graph(e, n=n)


def sbm(sizes: list[int], p_in: float, p_out: float, seed: int = 0,
        ) -> tuple[Graph, np.ndarray]:
    """Stochastic block model; returns (graph, ground-truth membership)."""
    rng = np.random.default_rng(seed)
    n = int(sum(sizes))
    bounds = np.cumsum([0] + list(sizes))
    truth = np.zeros(n, dtype=np.int32)
    edges = []
    for b in range(len(sizes)):
        lo, hi = bounds[b], bounds[b + 1]
        truth[lo:hi] = b
        # intra-block edges
        nb = hi - lo
        m_in = int(p_in * nb * (nb - 1) / 2)
        if m_in:
            e = rng.integers(lo, hi, size=(m_in, 2))
            edges.append(e)
        # inter-block edges to later blocks
        for b2 in range(b + 1, len(sizes)):
            lo2, hi2 = bounds[b2], bounds[b2 + 1]
            m_out = int(p_out * nb * (hi2 - lo2))
            if m_out:
                e = np.stack([rng.integers(lo, hi, size=m_out),
                              rng.integers(lo2, hi2, size=m_out)], axis=1)
                edges.append(e)
    e = np.concatenate(edges, axis=0)
    e = e[e[:, 0] != e[:, 1]]
    return build_graph(e, n=n), truth


def planted_partition(n_comm: int, comm_size: int, p_in: float = 0.3,
                      p_out: float = 0.002, seed: int = 0,
                      ) -> tuple[Graph, np.ndarray]:
    return sbm([comm_size] * n_comm, p_in, p_out, seed)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> Graph:
    """Kronecker/RMAT power-law graph (Graph500-style parameters)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    srcs = np.zeros(m, dtype=np.int64)
    dsts = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random((m, 2))
        go_right_src = r[:, 0] > (a + b)      # pick bottom half for src
        # conditional for dst depends on src half
        p_right_top, p_right_bot = b / (a + b), 1.0 - c / (1.0 - a - b + 1e-12)
        go_right_dst = np.where(go_right_src,
                                r[:, 1] > (1.0 - p_right_bot),
                                r[:, 1] < p_right_top)
        srcs |= go_right_src.astype(np.int64) << bit
        dsts |= go_right_dst.astype(np.int64) << bit
    e = np.stack([srcs, dsts], axis=1)
    e = e[e[:, 0] != e[:, 1]]
    return build_graph(e, n=n)


def grid2d(side: int) -> Graph:
    """2D lattice — degree ~2.1 road-network proxy (asia_osm analogue)."""
    idx = np.arange(side * side).reshape(side, side)
    edges = np.concatenate([
        np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1),
        np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1),
    ])
    return build_graph(edges, n=side * side)


def ring_of_cliques(n_cliques: int, clique_size: int) -> Graph:
    """Classic modularity testbed: cliques joined in a ring by single edges."""
    edges = []
    for q in range(n_cliques):
        base = q * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((base + i, base + j))
        nxt = ((q + 1) % n_cliques) * clique_size
        edges.append((base, nxt))
    return build_graph(np.array(edges), n=n_cliques * clique_size)


def karate_club() -> tuple[Graph, np.ndarray]:
    """Zachary's karate club (34 vertices, 78 edges) + 2-faction ground truth."""
    e = [(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8),
         (0, 10), (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21),
         (0, 31), (1, 2), (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21),
         (1, 30), (2, 3), (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28),
         (2, 32), (3, 7), (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10),
         (5, 16), (6, 16), (8, 30), (8, 32), (8, 33), (9, 33), (13, 33),
         (14, 32), (14, 33), (15, 32), (15, 33), (18, 32), (18, 33), (19, 33),
         (20, 32), (20, 33), (22, 32), (22, 33), (23, 25), (23, 27), (23, 29),
         (23, 32), (23, 33), (24, 25), (24, 27), (24, 31), (25, 31), (26, 29),
         (26, 33), (27, 33), (28, 31), (28, 33), (29, 32), (29, 33), (30, 32),
         (30, 33), (31, 32), (31, 33), (32, 33)]
    faction = np.array([0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0,
                        1, 0, 1, 0, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1],
                       dtype=np.int32)
    return build_graph(np.array(e), n=34), faction


def evolving_sequence(n: int, avg_degree: float, rounds: int,
                      delta_edges: int, seed: int = 0,
                      base: Graph | None = None,
                      ) -> tuple[Graph, list[GraphDelta]]:
    """Evolving-graph trace: a base graph plus ``rounds`` small deltas.

    Each delta retires ``delta_edges`` existing undirected edges and
    inserts ``delta_edges`` fresh ones (unit weight, no self loops, not
    currently present) — the small-churn regime where warm batched
    re-detection should beat full cold re-detection.  ``base`` defaults
    to an Erdős–Rényi graph G(n, avg_degree); pass any Graph (e.g. a
    planted partition) to churn it instead.  Deterministic in ``seed``.
    """
    rng = np.random.default_rng(seed)
    if base is None:
        base = erdos_renyi(n, avg_degree, seed=seed)
    n = base.n
    edges, _ = undirected_edges(base)
    alive = {(int(u), int(v)) for u, v in edges}

    deltas = []
    for _ in range(rounds):
        pool = sorted(alive)
        k_del = min(delta_edges, len(pool))
        idx = rng.choice(len(pool), size=k_del, replace=False) if k_del else []
        dels = [pool[i] for i in idx]
        alive.difference_update(dels)

        # fresh w.r.t. the pre-round graph: never re-insert an edge this
        # same delta deletes (a delete+insert pair would cancel out)
        forbidden = alive | set(dels)
        ins: list[tuple[int, int]] = []
        attempts = 0
        while len(ins) < delta_edges and attempts < 100 * delta_edges:
            attempts += 1
            u, v = int(rng.integers(n)), int(rng.integers(n))
            if u == v:
                continue
            e = (min(u, v), max(u, v))
            if e in forbidden:
                continue
            forbidden.add(e)
            alive.add(e)
            ins.append(e)
        deltas.append(GraphDelta.make(
            insert=np.asarray(ins, np.int64).reshape(-1, 2),
            delete=np.asarray(dels, np.int64).reshape(-1, 2)))
    return base, deltas


def figure1_graph() -> tuple[Graph, np.ndarray, np.ndarray]:
    """The paper's Figure 1 / Figure 2 scenario.

    Vertices 0..6 form community C1 in two lobes {0,1,2} and {4,5,6} bridged
    only through the cut vertex 3; vertices 7..9 form a heavy community C2
    that vertex 3 defects to, internally disconnecting C1.

    Returns (graph, assignment_before, assignment_after_defection); the
    "after" assignment exhibits the internally-disconnected C1 and is the
    canonical test input for detection + splitting.
    """
    edges = [
        # lobe A of C1
        (0, 1), (1, 2), (0, 2),
        # bridge through cut vertex 3
        (2, 3), (3, 4),
        # lobe B of C1
        (4, 5), (5, 6), (4, 6),
        # community C2 (heavy internal weights)
        (7, 8), (8, 9), (7, 9),
        # vertex 3's strong pull toward C2
        (3, 7), (3, 8), (3, 9),
    ]
    w = [1, 1, 1,
         1, 1,
         1, 1, 1,
         4, 4, 4,
         4, 4, 4]
    g = build_graph(np.array(edges), np.array(w, dtype=np.float32), n=10)
    before = np.array([1, 1, 1, 1, 1, 1, 1, 2, 2, 2], dtype=np.int32)
    after = np.array([1, 1, 1, 2, 1, 1, 1, 2, 2, 2], dtype=np.int32)
    return g, before, after
