"""End-to-end community-detection pipeline with checkpoint/restart.

Generates an SBM graph, runs distributed-style GSL-LPA with per-iteration
checkpointing, simulates a mid-run failure, restarts from the checkpoint,
and verifies the result matches an uninterrupted run — the fault-tolerance
story for billion-edge production runs (DESIGN.md §6).  The recovered
label state is then finished through the unified Engine as a warm start
(incremental re-detection), with the legacy ``gsl_lpa`` wrapper checked
against it for back-compat.

    PYTHONPATH=src python examples/community_pipeline.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import gsl_lpa
from repro.core.lpa import LpaState, lpa_move, neighbors_of, _label_hash
from repro.engine import Engine, EngineConfig
from repro.graphgen import planted_partition


def lpa_with_checkpoints(g, mgr: CheckpointManager, max_iters=20, tau=0.05,
                         fail_at: int | None = None, resume: bool = False):
    """Host-driven LPA loop: one jitted iteration per step + checkpoint."""
    n = g.n
    parity = (_label_hash(jnp.arange(n, dtype=jnp.int32), jnp.int32(-1))
              & 1).astype(bool)
    state = {"labels": jnp.arange(n, dtype=jnp.int32),
             "active": jnp.ones(n, bool), "iteration": jnp.int32(0)}
    start = 0
    if resume and mgr.latest_step() is not None:
        state, start, _ = mgr.restore(state)
        print(f"  resumed from iteration {start}")

    for it in range(start, max_iters):
        labels, active = state["labels"], state["active"]
        dn_total = 0
        for sweep, klass in enumerate((~parity, parity)):
            cand = active & klass
            labels, changed, dn = lpa_move(g, labels, cand, 2 * it + sweep)
            active = (active & ~cand) | neighbors_of(g, changed)
            dn_total += int(dn)
        state = {"labels": labels, "active": active,
                 "iteration": jnp.int32(it + 1)}
        mgr.save(it + 1, state)
        if fail_at is not None and it + 1 == fail_at:
            raise RuntimeError(f"simulated node failure at iteration {it+1}")
        if dn_total <= tau * n:
            break
    return state["labels"]


def main() -> None:
    g, truth = planted_partition(10, 80, p_in=0.25, p_out=0.002, seed=11)
    print(f"SBM graph: {g.n} vertices, {g.num_edges} directed edges")

    with tempfile.TemporaryDirectory() as d:
        # uninterrupted reference
        ref = lpa_with_checkpoints(g, CheckpointManager(Path(d) / "ref"))

        # interrupted run: fail at iteration 2, restart, complete
        mgr = CheckpointManager(Path(d) / "ft")
        try:
            lpa_with_checkpoints(g, mgr, fail_at=2)
        except RuntimeError as e:
            print(f"  {e}")
        labels = lpa_with_checkpoints(g, mgr, resume=True)

    assert np.array_equal(np.asarray(ref), np.asarray(labels)), \
        "restart diverged from uninterrupted run"
    print("  restart == uninterrupted: OK (bit-exact)")

    # Finish through the Engine: the checkpointed labels warm-start the
    # detection (the propagation phase converges almost immediately), the
    # split phase separates any internally-disconnected communities.
    eng = Engine(EngineConfig(backend="segment", compute_metrics=True))
    res = eng.fit(g, init_labels=np.asarray(labels))
    q, frac = res.modularity, res.disconnected_fraction
    print(f"final: {res.num_communities} communities, Q={q:.3f}, "
          f"disconnected={frac:.1%} "
          f"(warm-start LPA took {res.lpa_iterations} iteration(s))")
    assert frac == 0.0

    # Legacy wrapper back-compat: same warm-start through gsl_lpa matches.
    legacy = gsl_lpa(g, init_labels=jnp.asarray(labels))
    assert np.array_equal(legacy.labels, res.labels), \
        "legacy gsl_lpa diverged from Engine result"
    print("  legacy gsl_lpa == Engine: OK")


if __name__ == "__main__":
    main()
