"""Quickstart: GSL-LPA community detection through the unified Engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import gsl_lpa, gve_lpa
from repro.engine import Engine, EngineConfig
from repro.graphgen import karate_club, planted_partition


def main() -> None:
    eng = Engine(EngineConfig(backend="auto", compute_metrics=True))

    # --- Zachary's karate club ---
    g, truth = karate_club()
    res = eng.fit(g)                       # propagation + Split-Last
    print(f"karate club: {res.num_communities} communities, "
          f"Q={res.modularity:.3f}, {res.lpa_iterations} LPA iters, "
          f"{res.split_iterations} split sweeps "
          f"[{res.backend} backend, bucket {res.bucket}]")

    # --- planted partition: GSL-LPA vs plain parallel LPA (GVE-LPA) ---
    g2, truth2 = planted_partition(12, 50, p_in=0.3, p_out=0.003, seed=7)
    no_split = Engine(EngineConfig(split="none", compute_metrics=True))
    for name, engine in (("GVE-LPA (no split)", no_split),
                         ("GSL-LPA (split-last)", eng)):
        r = engine.fit(g2)
        print(f"{name:22s} Q={r.modularity:.3f} "
              f"communities={r.num_communities} "
              f"disconnected_frac={r.disconnected_fraction:.3%}  "
              f"t={r.total_seconds * 1e3:.0f}ms")

    # same-bucket graphs share one compiled executable — second fit is warm
    g3, _ = planted_partition(12, 50, p_in=0.3, p_out=0.003, seed=8)
    r3 = eng.fit(g3)
    print(f"second same-bucket fit: cache_hit={r3.cache_hit}, "
          f"t={r3.total_seconds * 1e3:.0f}ms")

    # legacy wrappers still work (now thin facades over the Engine)
    legacy = gsl_lpa(g, split="lp")
    assert np.array_equal(legacy.labels, res.labels), \
        "legacy gsl_lpa diverged from Engine result"
    assert gve_lpa(g2).labels.shape == (g2.n,)
    print("legacy gsl_lpa agrees: True")

    # ground-truth recovery check
    labels = res.labels
    agree = np.mean([
        (labels[i] == labels[j]) == (truth[i] == truth[j])
        for i in range(0, 34, 3) for j in range(i + 1, 34, 3)])
    print(f"karate pairwise agreement with factions: {agree:.2%}")


if __name__ == "__main__":
    main()
