"""Quickstart: GSL-LPA community detection in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import gsl_lpa, gve_lpa, modularity, disconnected_fraction
from repro.graphgen import karate_club, planted_partition


def main() -> None:
    # --- Zachary's karate club ---
    g, truth = karate_club()
    res = gsl_lpa(g, split="lp")          # propagation + Split-Last
    q = float(modularity(g, jnp.asarray(res.labels)))
    print(f"karate club: {len(set(res.labels.tolist()))} communities, "
          f"Q={q:.3f}, {res.lpa_iterations} LPA iters, "
          f"{res.split_iterations} split sweeps")

    # --- planted partition: GSL-LPA vs plain parallel LPA (GVE-LPA) ---
    g2, truth2 = planted_partition(12, 50, p_in=0.3, p_out=0.003, seed=7)
    for name, fn in (("GVE-LPA (no split)", gve_lpa),
                     ("GSL-LPA (split-last)", lambda g: gsl_lpa(g, split="lp"))):
        r = fn(g2)
        frac = float(disconnected_fraction(g2, jnp.asarray(r.labels)))
        print(f"{name:22s} Q={float(modularity(g2, jnp.asarray(r.labels))):.3f} "
              f"communities={len(set(r.labels.tolist()))} "
              f"disconnected_frac={frac:.3%}  "
              f"t={r.total_seconds * 1e3:.0f}ms")

    # ground-truth recovery check
    labels = res.labels
    agree = np.mean([
        (labels[i] == labels[j]) == (truth[i] == truth[j])
        for i in range(0, 34, 3) for j in range(i + 1, 34, 3)])
    print(f"karate pairwise agreement with factions: {agree:.2%}")


if __name__ == "__main__":
    main()
