"""GSL-LPA as a framework feature: MoE expert placement from co-activation.

Builds the expert co-activation graph from (simulated) router statistics of
a 64-expert MoE, detects communities of frequently co-activated experts
with GSL-LPA, and packs communities onto devices to minimise cross-device
all-to-all traffic.  The paper's no-internally-disconnected-communities
guarantee is what makes the packing sound: a disconnected 'community'
would co-locate experts that never fire together, wasting HBM locality
(DESIGN.md §4).

    PYTHONPATH=src python examples/moe_expert_placement.py
"""
import numpy as np

from repro.core import build_graph, gsl_lpa, gve_lpa, disconnected_fraction
import jax.numpy as jnp


def simulate_router_stats(n_experts=64, n_groups=8, tokens=20000, top_k=2,
                          seed=0):
    """Tokens pick experts with strong intra-group affinity."""
    rng = np.random.default_rng(seed)
    group_of = np.repeat(np.arange(n_groups), n_experts // n_groups)
    co = np.zeros((n_experts, n_experts), dtype=np.int64)
    for _ in range(tokens):
        g = rng.integers(n_groups)
        members = np.where(group_of == g)[0]
        if rng.random() < 0.85:          # affinity pick
            pair = rng.choice(members, size=top_k, replace=False)
        else:                            # random pick
            pair = rng.choice(n_experts, size=top_k, replace=False)
        for a in pair:
            for b in pair:
                if a != b:
                    co[a, b] += 1
    return co, group_of


def placement_cost(co, device_of):
    """Cross-device co-activation volume (all-to-all bytes proxy)."""
    cross = co * (device_of[:, None] != device_of[None, :])
    return int(cross.sum()) // 2


def main() -> None:
    co, truth = simulate_router_stats()
    e = np.argwhere(np.triu(co, 1) > 0)
    w = co[e[:, 0], e[:, 1]].astype(np.float32)
    g = build_graph(e, w, n=co.shape[0])

    res = gsl_lpa(g, split="lp")
    frac = float(disconnected_fraction(g, jnp.asarray(res.labels)))
    print(f"expert co-activation graph: {g.num_edges} edges, "
          f"{len(set(res.labels.tolist()))} communities, "
          f"disconnected={frac:.0%}")

    # pack communities onto 8 devices greedily by size
    n_devices = 8
    labels = res.labels
    comm_ids, counts = np.unique(labels, return_counts=True)
    order = np.argsort(-counts)
    device_of = np.zeros(co.shape[0], dtype=np.int64)
    load = np.zeros(n_devices, dtype=np.int64)
    for c in comm_ids[order]:
        d = int(np.argmin(load))
        device_of[labels == c] = d
        load[d] += int((labels == c).sum())

    rng = np.random.default_rng(1)
    random_placement = rng.permutation(co.shape[0]) % n_devices
    cost_lpa = placement_cost(co, device_of)
    cost_rand = placement_cost(co, random_placement)
    print(f"cross-device co-activation: random={cost_rand}  "
          f"gsl-lpa={cost_lpa}  ({1 - cost_lpa / cost_rand:.0%} less "
          f"all-to-all traffic)")
    assert cost_lpa < cost_rand


if __name__ == "__main__":
    main()
