"""Train a ~100M-parameter LM end to end with the full framework stack:
config -> synthetic data pipeline -> sharded train step -> checkpointing
-> preemption handling.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.registry import reduced_config
from repro.launch.train import run


def hundred_m_config():
    """A ~100M llama-family config derived from yi-9b."""
    base = get_config("yi-9b")
    return dataclasses.replace(
        base, n_layers=8, d_model=768, n_heads=12, n_kv=4, head_dim=64,
        d_ff=2048, vocab=8192, remat="none", attn_chunk=128)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"config: {cfg.n_layers}L d={cfg.d_model} "
          f"params={cfg.param_count() / 1e6:.0f}M")

    # register the custom config under a private name and train
    from repro.configs.registry import ARCHS
    cfg = dataclasses.replace(cfg, name="yi-100m")
    ARCHS["yi-100m"] = cfg

    # lr is tuned for the default 8 x 256 token batch; scale it down for
    # smoke-size batches or the tiny-batch gradient noise diverges
    tokens = args.global_batch * args.seq_len
    peak_lr = 3e-3 * min(1.0, tokens / (8 * 256))

    with tempfile.TemporaryDirectory() as d:
        out = run("yi-100m", reduced=False, steps=args.steps,
                  seq_len=args.seq_len, global_batch=args.global_batch,
                  ckpt_dir=d, save_every=50, log_every=10, peak_lr=peak_lr)
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    # single-batch losses are noisy; judge learning on window means, and
    # only once past warmup + a few real update steps
    if len(losses) >= 24:
        k = max(len(losses) // 4, 4)
        first = sum(losses[:k]) / k
        last = sum(losses[-k:]) / k
        assert last < first, f"model did not learn ({first:.3f} -> {last:.3f})"


if __name__ == "__main__":
    main()
