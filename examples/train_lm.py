"""Train a ~100M-parameter LM end to end with the full framework stack:
config -> synthetic data pipeline -> sharded train step -> checkpointing
-> preemption handling.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.registry import reduced_config
from repro.launch.train import run


def hundred_m_config():
    """A ~100M llama-family config derived from yi-9b."""
    base = get_config("yi-9b")
    return dataclasses.replace(
        base, n_layers=8, d_model=768, n_heads=12, n_kv=4, head_dim=64,
        d_ff=2048, vocab=8192, remat="none", attn_chunk=128)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"config: {cfg.n_layers}L d={cfg.d_model} "
          f"params={cfg.param_count() / 1e6:.0f}M")

    # register the custom config under a private name and train
    from repro.configs.registry import ARCHS
    cfg = dataclasses.replace(cfg, name="yi-100m")
    ARCHS["yi-100m"] = cfg

    with tempfile.TemporaryDirectory() as d:
        out = run("yi-100m", reduced=False, steps=args.steps,
                  seq_len=args.seq_len, global_batch=args.global_batch,
                  ckpt_dir=d, save_every=50, log_every=10, peak_lr=3e-3)
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0], "model did not learn"


if __name__ == "__main__":
    main()
