"""Graph representation invariants."""
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.graph import build_graph, to_numpy_adj, to_padded_neighbors
from conftest import random_graph


def test_symmetrize_and_dedup():
    g = build_graph(np.array([[0, 1], [1, 0], [0, 1], [2, 2]]), n=3)
    # (0,1) x3 merged into weight 3 each direction; self loop dropped
    assert g.num_edges == 2
    adj = to_numpy_adj(g)
    assert adj[0] == [(1, 3.0)]
    assert adj[1] == [(0, 3.0)]
    assert adj[2] == []


def test_csr_consistency():
    g = random_graph(50, 6.0, seed=1)
    row_ptr = np.asarray(g.row_ptr)
    src = np.asarray(g.src)[: g.num_edges]
    # src array must be the CSR expansion of row_ptr
    expect = np.repeat(np.arange(g.n), row_ptr[1:] - row_ptr[:-1])
    assert np.array_equal(src, expect)
    # padding is masked
    assert not np.asarray(g.edge_mask)[g.num_edges:].any()
    assert np.asarray(g.wgt)[g.num_edges:].sum() == 0


def test_weighted_degree():
    e = np.array([[0, 1], [1, 2]])
    w = np.array([2.0, 5.0], np.float32)
    g = build_graph(e, w, n=3)
    np.testing.assert_allclose(np.asarray(g.kdeg), [2.0, 7.0, 5.0])
    assert float(g.total_weight) == pytest.approx(14.0)  # 2m


def test_padded_neighbors_roundtrip():
    g = random_graph(40, 5.0, seed=2, weighted=True)
    nbr, nw, nmask = to_padded_neighbors(g)
    assert nbr.shape[1] % 128 == 0
    adj = to_numpy_adj(g)
    for i in range(g.n):
        got = sorted((int(nbr[i, j]), float(nw[i, j]))
                     for j in range(nbr.shape[1]) if nmask[i, j])
        want = sorted((v, w) for v, w in adj[i])
        assert got == want
    # padding slots are weight-0 self edges
    self_rows = np.arange(nbr.shape[0])[:, None]
    assert ((nbr == self_rows) | nmask).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 60), st.integers(0, 10_000))
def test_build_graph_properties(n, seed):
    g = random_graph(n, 4.0, seed=seed)
    src = np.asarray(g.src)[: g.num_edges]
    dst = np.asarray(g.dst)[: g.num_edges]
    wgt = np.asarray(g.wgt)[: g.num_edges]
    # no self loops
    assert (src != dst).all()
    # symmetry with equal weights
    fwd = {(int(s), int(d)): float(w) for s, d, w in zip(src, dst, wgt)}
    for (s, d), w in fwd.items():
        assert fwd.get((d, s)) == w
