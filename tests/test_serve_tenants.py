"""Multi-tenant serving tier: admission fairness + backpressure, the
shared warm-state budget, snapshot/restore, and the K-tenant acceptance
run — N concurrent tenants over one Engine with per-member results
bit-identical to solo warm fits and zero stranded requests."""
import threading

import numpy as np
import pytest

from repro.core import affected_frontier, apply_delta
from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import evolving_sequence
from repro.serve import AdmissionQueue, Rejected, ServiceConfig, TenantService
from repro.serve.loadgen import (
    LoadConfig,
    build_traces,
    replay_parity,
    run_load,
)


def fresh_engine(**kw):
    return Engine(EngineConfig(backend="segment", **kw),
                  cache=CompileCache())


def make_service(engine=None, **cfg_kw):
    return TenantService(engine if engine is not None else fresh_engine(),
                         ServiceConfig(**cfg_kw))


# --- admission queue ---

def test_admission_round_robin_with_one_in_flight_per_tenant():
    """A tenant flooding its FIFO occupies one slot per rotation; a held
    tenant's next request waits for release."""
    q = AdmissionQueue(capacity=16)
    for i in range(3):
        q.offer("a", f"a{i}")
    q.offer("b", "b0")
    q.offer("c", "c0")

    assert q.take(timeout=1) == ("a", "a0")
    # "a" is now held: its 2 queued requests are skipped in rotation
    assert q.take(timeout=1) == ("b", "b0")
    assert q.take(timeout=1) == ("c", "c0")
    assert q.take(timeout=0.05) is None          # everyone eligible is held
    q.release("b")
    assert q.take(timeout=0.05) is None          # b has nothing queued
    q.release("a")
    assert q.take(timeout=1) == ("a", "a1")
    q.release("a")
    assert q.take(timeout=1) == ("a", "a2")
    stats = q.stats()
    assert stats["served_per_tenant"] == {"a": 3, "b": 1, "c": 1}
    assert stats["depth"] == 0 and stats["accepted"] == 5


def test_admission_backpressure_rejects_and_recovers():
    q = AdmissionQueue(capacity=2, retry_after_s=0.01)
    q.offer("a", 1)
    q.offer("b", 2)
    with pytest.raises(Rejected) as ei:
        q.offer("c", 3)
    rej = ei.value
    assert rej.depth == 2 and rej.capacity == 2
    assert rej.retry_after_s == pytest.approx(0.01)
    # capacity bounds *queued* items: taking one frees a slot even while
    # the taken tenant is still held
    assert q.take(timeout=1) == ("a", 1)
    q.offer("c", 3)
    stats = q.stats()
    assert stats["accepted"] == 3 and stats["rejected"] == 1
    assert stats["peak_depth"] == 2


def test_admission_close_drains_then_stops():
    q = AdmissionQueue(capacity=4)
    q.offer("a", 1)
    q.close()
    with pytest.raises(RuntimeError, match="closed"):
        q.offer("a", 2)
    assert q.take(timeout=1) == ("a", 1)         # drain mode: still takeable
    assert q.take(timeout=1) is None             # closed + drained
    assert q.drained()


def test_admission_take_unblocks_on_concurrent_offer():
    q = AdmissionQueue(capacity=4)
    got = []
    t = threading.Thread(target=lambda: got.append(q.take(timeout=10)))
    t.start()
    q.offer("a", "late")
    t.join(timeout=10)
    assert got == [("a", "late")]


# --- tenant service ---

def _trace(n, rounds, seed):
    return evolving_sequence(n, 4.0, rounds, 3, seed=seed)


def test_service_register_update_refresh_parity():
    """The full request surface against a solo oracle: register is a
    cold fit, update a warm frontier-seeded re-detection, refresh a cold
    re-fit of the current graph — all bit-identical to solo calls."""
    base, deltas = _trace(80, 2, seed=3)
    oracle = fresh_engine()
    with make_service(max_batch=4, queue_capacity=8) as svc:
        res0 = svc.register("t", base).result(timeout=300)
        ref0 = oracle.fit(base)
        assert np.array_equal(res0.labels, ref0.labels)
        assert not res0.warm_started

        graph, labels = base, ref0.labels
        for d in deltas:
            res = svc.update("t", d).result(timeout=300)
            graph = apply_delta(graph, d)
            ref = oracle.fit(graph, init_labels=labels,
                             init_active=affected_frontier(d, graph.n))
            labels = ref.labels
            assert res.warm_started
            assert np.array_equal(res.labels, ref.labels)
            assert res.lpa_iterations == ref.lpa_iterations
        assert np.array_equal(svc.labels("t"), labels)

        resf = svc.refresh("t").result(timeout=300)
        assert not resf.warm_started
        assert np.array_equal(resf.labels, oracle.fit(graph).labels)

        with pytest.raises(ValueError, match="already registered"):
            svc.register("t", base)
        with pytest.raises(KeyError):
            svc.update("nobody", deltas[0])
        stats = svc.stats()
        assert stats["completed"] == 4 and stats["failed"] == 0
        assert stats["outstanding"] == 0


def test_service_rejected_register_can_be_retried():
    """A register that never got admitted must not leave a phantom
    session behind (the retry would hit 'already registered')."""
    base, _ = _trace(50, 1, seed=9)
    svc = make_service(queue_capacity=2)
    svc.admission.close()                 # force the admission failure
    with pytest.raises(RuntimeError):
        svc.register("t", base)
    assert svc.tenants() == []            # rolled back, retry possible
    svc.close()


def test_service_warm_budget_spills_lru_tenants():
    """Commits past the shared budget spill the least-recently-served
    tenants' warm labels; spilled tenants run cold-but-correct next
    update; the ledger never exceeds the budget."""
    traces = {t: _trace(100, 1, seed=i) for i, t in
              enumerate(("t0", "t1", "t2"))}
    oracle = fresh_engine()
    # labels are int32: 400 B/tenant.  1000 B holds exactly 2 tenants.
    with make_service(warm_budget=1000, max_batch=1,
                      queue_capacity=8) as svc:
        for t, (base, _) in traces.items():
            svc.register(t, base).result(timeout=300)
        stats = svc.stats()
        assert stats["spills"] == 1
        assert stats["warm_cached_tenants"] == 2
        assert stats["warm_bytes"]["current"] <= 1000
        assert stats["warm_bytes"]["peak"] <= 1000
        assert svc.labels("t0") is None          # LRU victim spilled
        assert svc.labels("t1") is not None
        assert svc.labels("t2") is not None

        # spilled tenant's next update: cold, still correct
        base0, deltas0 = traces["t0"]
        res = svc.update("t0", deltas0[0]).result(timeout=300)
        post0 = apply_delta(base0, deltas0[0])
        assert not res.warm_started
        assert np.array_equal(res.labels, oracle.fit(post0).labels)
        # ... and its commit spilled the new LRU victim in turn
        assert svc.labels("t1") is None
        assert svc.stats()["warm_bytes"]["peak"] <= 1000

        # warm tenant stays warm
        base2, deltas2 = traces["t2"]
        res2 = svc.update("t2", deltas2[0]).result(timeout=300)
        assert res2.warm_started

    # a budget below a single tenant's labels: nothing cacheable at all
    base, _ = traces["t0"]
    with make_service(warm_budget=100, queue_capacity=4) as tiny:
        tiny.register("t", base).result(timeout=300)
        stats = tiny.stats()
        assert stats["uncached"] == 1 and stats["warm_cached_tenants"] == 0
        assert tiny.labels("t") is None


def test_service_snapshot_restore_resumes_warm(tmp_path):
    """Warm labels survive a restart: a restored service re-seeds
    fingerprint-verified tenants without any fit, and their next update
    is the exact warm re-detection the original service would have run —
    strictly cheaper than the cold re-detection storm it replaces."""
    from repro.checkpoint import CheckpointManager

    tenants = ("alpha", "beta", "gamma")
    traces = {t: _trace(90 + 10 * i, 2, seed=20 + i)
              for i, t in enumerate(tenants)}
    mgr = CheckpointManager(tmp_path / "ckpt")

    with make_service(queue_capacity=8) as svc:
        for t, (base, _) in traces.items():
            svc.register(t, base).result(timeout=300)
        for t, (_, deltas) in traces.items():
            svc.update(t, deltas[0]).result(timeout=300)
        saved = svc.snapshot(mgr)
        pre = {t: (svc.graph(t), np.array(svc.labels(t))) for t in tenants}
    assert set(saved["tenants"]) == set(tenants)
    assert all(e["warm"] and e["version"] == 1
               for e in saved["tenants"].values())

    # "restart": fresh engine, fresh service, graphs re-supplied by
    # clients; one tenant's graph has drifted -> fingerprint mismatch
    drifted = apply_delta(pre["gamma"][0], traces["gamma"][1][1])
    graphs = {"alpha": pre["alpha"][0], "beta": pre["beta"][0],
              "gamma": drifted, "delta": pre["alpha"][0]}
    with make_service(queue_capacity=8) as svc2:
        report = svc2.restore(mgr, graphs)
        assert sorted(report["restored"]) == ["alpha", "beta"]
        assert report["mismatched"] == ["gamma"]
        assert report["unknown"] == ["delta"]
        assert svc2.stats()["restored"] == 2

        warm_iters = cold_iters = 0
        for t in ("alpha", "beta"):
            graph, labels = pre[t]
            assert np.array_equal(svc2.labels(t), labels)   # bit-identical
            d = traces[t][1][1]
            res = svc2.update(t, d).result(timeout=300)
            post = apply_delta(graph, d)
            # == the no-restart continuation, member for member
            ref = fresh_engine().fit(
                post, init_labels=_extend(labels, post.n),
                init_active=affected_frontier(d, post.n))
            assert res.warm_started
            assert np.array_equal(res.labels, ref.labels)
            assert res.lpa_iterations == ref.lpa_iterations
            warm_iters += res.lpa_iterations
            cold_iters += fresh_engine().fit(post).lpa_iterations
        # the point of restoring: warm resumption beats re-detection
        assert warm_iters < cold_iters


def _extend(labels, n):
    if n > len(labels):
        return np.concatenate(
            [labels, np.arange(len(labels), n, dtype=np.int32)])
    return labels


# --- the K-tenant acceptance run ---

def test_k32_tenants_mixed_load_zero_stranded_and_bit_parity():
    """32 concurrent tenants, mixed cold/warm/delta traffic from 8
    client threads through one shared engine: every admitted request
    resolves (zero stranded, zero give-ups), parity tenants' final
    labels are bit-identical to a solo warm replay, and warm bytes never
    exceed the configured budget."""
    cfg = LoadConfig(tenants=32, rounds=3, size=96, delta_edges=3,
                     refresh_every=3, parity_tenants=4, client_threads=8,
                     seed=7)
    traces = build_traces(cfg)
    engine_config = EngineConfig(backend="segment")
    svc = TenantService(Engine(engine_config, cache=CompileCache()),
                        ServiceConfig(queue_capacity=16, warm_budget="64KB",
                                      max_batch=8, retry_after_s=0.002))
    try:
        records, summary = run_load(svc, traces, cfg)
        final = {t: (None if svc.labels(t) is None
                     else np.array(svc.labels(t)))
                 for t in svc.tenants()}
        stats = svc.stats()
    finally:
        svc.close()

    assert summary["requests"] == 32 * (1 + 3)
    assert summary["stranded"] == 0          # every admitted request resolved
    assert summary["outstanding"] == 0
    assert summary["give_ups"] == 0 and summary["errors"] == 0
    assert summary["failed"] == 0
    assert summary["completed"] == summary["requests"]
    assert summary["queue_depth_peak"] <= 16
    # 32 tenants x <=400 B of int32 labels fit 64KB: never spill, and the
    # ledger's peak proves the budget held at every instant
    assert summary["spills"] == 0
    assert summary["warm_bytes_peak"] <= 64_000
    assert stats["admission"]["held"] == 0
    # rotation actually served everyone
    assert len(stats["admission"]["served_per_tenant"]) == 32
    assert summary["p99_ms"] >= summary["p50_ms"] > 0
    assert summary["edges_per_s"] > 0

    # bit-parity: multiplexing 32 tenants over one engine changed
    # latency, not results
    parity = {t: r for t, r in final.items()
              if t in list(traces)[: cfg.parity_tenants]}
    solo = replay_parity(traces, parity, engine_config)
    for t, labels in solo.items():
        assert np.array_equal(parity[t], labels), t
