"""Property tests: file-format round trips are bit-exact.

For random edge lists across every (format x symmetry x weighting)
variant: write the file, parse it back, run the §4.1 pipeline, and
``build_graph`` — the CSR must be bit-identical to ``build_graph`` on
the original in-memory edges.  Text serialisation (%.17g) must not
perturb a single weight bit.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow  # hypothesis suites ride the slow CI job

from repro.core.graph import build_graph, graph_fingerprint  # noqa: E402
from repro.io import (  # noqa: E402
    PreprocessOptions,
    load_graph,
    parse_mtx,
    parse_snap,
    preprocess,
    write_mtx,
    write_snap,
)

CSR_FIELDS = ("row_ptr", "src", "dst", "wgt", "edge_mask", "kdeg")


def assert_graph_identical(a, b):
    assert (a.n, a.m_pad, a.num_edges) == (b.n, b.m_pad, b.num_edges)
    for f in CSR_FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype and np.array_equal(x, y), f
    assert graph_fingerprint(a) == graph_fingerprint(b)


# Unique canonical undirected edges (no self loops): the write side
# stores each edge once, so duplicate-merge ambiguity is out of scope —
# preprocessing dedup has its own unit tests.
@st.composite
def edge_sets(draw):
    n = draw(st.integers(2, 40))
    pairs = draw(st.sets(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=1, max_size=80))
    edges = np.array([(min(u, v), max(u, v)) for u, v in pairs
                      if u != v], dtype=np.int64)
    if not len(edges):
        edges = np.array([[0, 1]], dtype=np.int64)
    edges = np.unique(edges, axis=0)
    return n, edges


weight_floats = st.floats(min_value=1e-3, max_value=1e3,
                          allow_nan=False, allow_infinity=False)


@settings(max_examples=25, deadline=None)
@given(edge_sets(), st.booleans(), st.booleans(), st.data())
def test_mtx_roundtrip_bit_identical(tmp_path_factory, ne, symmetric,
                                     weighted, data):
    n, edges = ne
    weights = np.array(data.draw(st.lists(
        weight_floats, min_size=len(edges), max_size=len(edges)))) \
        if weighted else None
    path = tmp_path_factory.mktemp("mtx") / "g.mtx"
    write_mtx(path, edges, weights, n=n, symmetric=symmetric)

    parsed = parse_mtx(path)
    cleaned, stats = preprocess(
        parsed, PreprocessOptions(unit_weights=not weighted))
    assert stats.edges == len(edges)
    got = build_graph(cleaned.edges, cleaned.weights, n=cleaned.n)
    want = build_graph(edges, weights, n=n)
    assert_graph_identical(got, want)


@settings(max_examples=25, deadline=None)
@given(edge_sets(), st.booleans(), st.data())
def test_snap_roundtrip_bit_identical(tmp_path_factory, ne, weighted, data):
    n, edges = ne
    weights = np.array(data.draw(st.lists(
        weight_floats, min_size=len(edges), max_size=len(edges)))) \
        if weighted else None
    path = tmp_path_factory.mktemp("snap") / "g.snap.txt"
    write_snap(path, edges, weights)

    parsed = parse_snap(path, n=n)
    cleaned, _ = preprocess(
        parsed, PreprocessOptions(unit_weights=not weighted))
    got = build_graph(cleaned.edges, cleaned.weights, n=cleaned.n)
    want = build_graph(edges, weights, n=n)
    assert_graph_identical(got, want)


@settings(max_examples=10, deadline=None)
@given(edge_sets())
def test_load_graph_roundtrip_through_store(tmp_path_factory, ne):
    """End to end: write -> load_graph (cold ingest) -> load_graph
    (cache hit) both bit-identical to build_graph on the edges."""
    n, edges = ne
    d = tmp_path_factory.mktemp("store")
    path = d / "g.mtx"
    write_mtx(path, edges, n=n, symmetric=True)
    want = build_graph(edges, n=n)
    cold, rep_cold = load_graph(path, cache_dir=d / "cache",
                                return_report=True)
    warm, rep_warm = load_graph(path, cache_dir=d / "cache",
                                return_report=True)
    assert not rep_cold.cache_hit and rep_warm.cache_hit
    assert_graph_identical(cold, want)
    assert_graph_identical(warm, want)
