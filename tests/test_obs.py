"""Unified telemetry layer: registry, span tracer, convergence profiles.

The load-bearing contract is **bit parity**: ``EngineConfig.profile``
("off" | "convergence" | "full") must never change a single label or
iteration count — solo, batched, or out-of-core, on every backend and
split mode.  The profile buffer rides the while_loop state and never
feeds back, so parity holds by construction; these tests pin it.

Also pinned: the figure-1 profile values themselves (the frontier-decay
curve the FLPA comparison reads), Chrome-trace export well-formedness,
registry thread-safety, and key-parity of the legacy ``stats()`` dicts
that now read through the registry.
"""
import json
import threading

import numpy as np
import pytest

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi, karate_club
from repro.graphgen.synthetic import figure1_graph
from repro.obs import (
    REGISTRY,
    TRACER,
    ConvergenceProfile,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Tracer,
    span,
)
from repro.obs.convergence import phase_from_rows

BACKENDS = ("segment", "tile")
SPLITS = ("none", "lp", "lpp")


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


# --- metrics registry ---

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("g")
    g.set(7)
    g.add(-2)
    assert g.value == 5
    h = reg.histogram("h", (1, 10, 100))
    for v in (0.5, 5, 50, 500):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["buckets"]["overflow"] == 1
    assert s["mean"] == pytest.approx((0.5 + 5 + 50 + 500) / 4)
    assert h.quantile(0.5) in (5.0, 50.0)


def test_registry_snapshot_and_render():
    reg = MetricsRegistry()
    reg.counter("a.n").inc(3)
    reg.histogram("a.h", (1,)).observe(2)
    snap = reg.snapshot()
    assert snap["a.n"] == 3
    assert snap["a.h"]["count"] == 1
    text = reg.render_text()
    assert "a.n  3" in text and "a.h" in text


def test_scope_dedupe_and_release():
    reg = MetricsRegistry()
    s1, s2 = reg.scope("svc"), reg.scope("svc")
    assert s1.label == "svc" and s2.label == "svc#1"
    s1.counter("x").inc()
    s2.counter("x").inc(2)
    child = s1.scope("inner")
    child.counter("y").inc()
    snap = reg.snapshot()
    assert snap["svc.x"] == 1 and snap["svc#1.x"] == 2
    assert snap["svc.inner.y"] == 1
    s1.release()               # drops svc.* including children, frees label
    snap = reg.snapshot()
    assert "svc.x" not in snap and "svc.inner.y" not in snap
    assert snap["svc#1.x"] == 2
    s3 = reg.scope("svc")      # label is reusable after release
    assert s3.label == "svc"
    # double release is harmless
    s1.release()


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_threaded_stress():
    reg = MetricsRegistry()
    c = reg.counter("hot")
    h = reg.histogram("lat", (1, 10))
    scopes = []

    def work(i):
        for _ in range(500):
            c.inc()
            h.observe(i)
        s = reg.scope("worker")
        s.counter("n").inc()
        scopes.append(s)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8 * 500
    assert h.count == 8 * 500
    # every thread got a distinct scope label
    assert len({s.label for s in scopes}) == 8
    for s in scopes:
        s.release()


# --- span tracer / chrome export ---

def test_spans_nest_and_export_chrome(tmp_path):
    tr = Tracer()
    with tr.span("outer", k=1) as outer:
        with tr.span("inner") as inner:
            assert tr.current() is inner
        assert tr.current() is outer
        outer.set(result="done")
    assert tr.current() is None
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]
    by_name = {s.name: s for s in spans}
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].attrs == {"k": 1, "result": "done"}
    assert by_name["outer"].dur >= by_name["inner"].dur >= 0

    out = tmp_path / "trace.json"
    n = tr.export_chrome(out)
    events = json.loads(out.read_text())
    assert n == len(events) == 2
    for ev in events:
        assert set(ev) == {"name", "ph", "pid", "tid", "ts", "dur", "args"}
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0
    inner_ev = next(e for e in events if e["name"] == "inner")
    assert inner_ev["args"]["parent_span"] == by_name["outer"].span_id


def test_tracer_disabled_is_free():
    tr = Tracer(enabled=False)
    with tr.span("x") as s:
        s.set(ignored=True)
    assert tr.spans() == []


def test_engine_fit_emits_spans():
    g = karate_club()[0]
    TRACER.reset()
    fresh_engine().fit(g)
    names = {s.name for s in TRACER.spans("engine.")}
    assert {"engine.fit", "engine.prepare", "engine.dispatch"} <= names


# --- convergence profiles: bit parity ---

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("split", SPLITS)
def test_profile_solo_bit_parity(backend, split):
    g = erdos_renyi(120, 5.0, seed=7)
    base = fresh_engine(backend=backend, split=split).fit(g)
    assert base.profile is None
    for mode in ("convergence", "full"):
        r = fresh_engine(backend=backend, split=split, profile=mode).fit(g)
        assert np.array_equal(r.labels, base.labels)
        assert r.lpa_iterations == base.lpa_iterations
        assert r.split_iterations == base.split_iterations
        assert isinstance(r.profile, ConvergenceProfile)
        prop = r.profile.propagation
        assert prop.num_sub_sweeps == 2 * r.lpa_iterations
        assert (prop.active >= 0).all() and (prop.changed >= 0).all()
        assert (prop.active <= g.n).all()
        # a vertex only changes label as a candidate
        assert (prop.changed <= prop.active).all()
        if mode == "full" and split in ("lp", "lpp"):
            assert r.profile.split is not None
        else:
            assert r.profile.split is None


@pytest.mark.parametrize("backend", BACKENDS)
def test_profile_batched_bit_parity(backend):
    graphs = [erdos_renyi(100, 4.0, seed=1), karate_club()[0],
              erdos_renyi(100, 4.0, seed=2)]
    base = fresh_engine(backend=backend, split="lp").fit_many(graphs)
    eng = fresh_engine(backend=backend, split="lp", profile="full")
    prof = eng.fit_many(graphs)
    solo = [fresh_engine(backend=backend, split="lp", profile="full").fit(g)
            for g in graphs]
    for b, p, s, g in zip(base, prof, solo, graphs):
        assert np.array_equal(p.labels, b.labels)
        assert p.lpa_iterations == b.lpa_iterations
        assert isinstance(p.profile, ConvergenceProfile)
        assert p.profile.n == g.n
        # the batched member's curve is the solo curve (per-slot
        # segment-sums see only that member's vertices)
        assert np.array_equal(p.profile.propagation.active[:2 * p.lpa_iterations],
                              s.profile.propagation.active[:2 * p.lpa_iterations])
        assert np.array_equal(p.profile.propagation.changed[:2 * p.lpa_iterations],
                              s.profile.propagation.changed[:2 * p.lpa_iterations])


@pytest.mark.parametrize("fuse", ("auto", "off"))
def test_profile_ooc_bit_parity(fuse):
    from repro.partition.ooc import fit_out_of_core, open_source
    g = erdos_renyi(200, 6.0, seed=11)
    src = open_source(g)
    runs = {}
    for mode in ("off", "convergence", "full"):
        cfg = EngineConfig(split="lp", profile=mode, fuse_sweeps=fuse)
        runs[mode] = fit_out_of_core(src, cfg, memory_budget="1MB",
                                     num_partitions=3)
    base = runs["off"]
    assert base.profile is None
    for mode in ("convergence", "full"):
        r = runs[mode]
        assert np.array_equal(r.labels, base.labels)
        assert r.lpa_iterations == base.lpa_iterations
        assert r.split_iterations == base.split_iterations
        assert r.profile.propagation.num_sub_sweeps == 2 * r.lpa_iterations
    assert runs["convergence"].profile.split is None
    assert runs["full"].profile.split is not None
    # ooc propagation curve == in-core curve (exact, not a proxy)
    incore = fresh_engine(split="lp", profile="full").fit(g)
    assert np.array_equal(runs["full"].profile.propagation.active,
                          incore.profile.propagation.active)
    assert np.array_equal(runs["full"].profile.propagation.changed,
                          incore.profile.propagation.changed)


# --- convergence profiles: figure-1 correctness ---

@pytest.mark.parametrize("backend", BACKENDS)
def test_profile_figure1_values(backend):
    g, _, _ = figure1_graph()
    r = fresh_engine(backend=backend, split="lp", profile="full").fit(g)
    p = r.profile
    assert p.n == g.n == 10
    assert p.propagation.sweep.tolist() == [0, 1, 2, 3, 4, 5]
    assert p.propagation.active.tolist() == [6, 4, 6, 3, 3, 0]
    assert p.propagation.changed.tolist() == [6, 3, 2, 0, 0, 0]
    assert not p.propagation.truncated
    decay = p.frontier_decay()
    assert decay.tolist() == pytest.approx([0.6, 0.4, 0.6, 0.3, 0.3, 0.0])
    # split phase: 2 min-label sweeps separate the bridged lobes
    assert p.split is not None
    assert p.split.num_sub_sweeps == r.split_iterations == 2
    assert p.split.changed.tolist()[-1] == 0     # fixed point reached
    assert not p.split.truncated
    d = p.to_dict()
    assert d["propagation"]["active"] == [6, 4, 6, 3, 3, 0]
    json.dumps(d)                                 # JSON-serializable


def test_phase_from_rows_roundtrip():
    rows = [(0, 10, 4), (1, 6, 1), (2, 2, 0)]
    ph = phase_from_rows("propagation", rows)
    assert ph.sweep.tolist() == [0, 1, 2]
    assert ph.active.tolist() == [10, 6, 2]
    assert ph.changed.tolist() == [4, 1, 0]
    assert phase_from_rows("split", []).num_sub_sweeps == 0


def test_profile_off_attaches_nothing():
    g = karate_club()[0]
    r = fresh_engine().fit(g)
    assert r.profile is None
    (rb,) = fresh_engine().fit_many([g])
    assert rb.profile is None


def test_profile_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(profile="everything")
    # profile joins the compile key: off/on builds are distinct
    assert EngineConfig(profile="off").algo_key() \
        != EngineConfig(profile="convergence").algo_key()


# --- stats() key parity: the legacy dicts survive the migration ---

def test_engine_stats_keys_and_registry_mirror():
    g = karate_club()[0]
    eng = fresh_engine()
    before = set(eng.stats())
    eng.fit(g)
    eng.fit(g)
    st = eng.stats()
    assert set(st) == before
    snap = REGISTRY.snapshot()
    fits = [v for k, v in snap.items()
            if k.startswith("engine") and k.endswith(".fits")]
    assert any(v >= 2 for v in fits)


def test_microbatcher_stats_keys_and_mirror():
    from repro.launch.microbatch import MicroBatcher
    g = karate_club()[0]
    eng = fresh_engine()
    with MicroBatcher(eng, max_batch=4) as mb:
        label = mb._obs.label
        [s.result() for s in [mb.submit(g) for _ in range(3)]]
        st = mb.stats()
        assert set(st) == {"requests", "batches", "batch_size_hist",
                           "mean_batch", "p50_ms", "p95_ms", "mean_ms"}
        assert st["requests"] == 3
        snap = REGISTRY.snapshot()
        assert snap[f"{label}.requests"] == 3
        assert snap[f"{label}.batches"] == st["batches"]
        assert snap[f"{label}.latency_ms"]["count"] == 3
    # close() released the standalone batcher's scope
    assert f"{label}.requests" not in REGISTRY.snapshot()


def test_admission_stats_keys_and_mirror():
    from repro.serve.admission import AdmissionQueue
    reg = MetricsRegistry()
    q = AdmissionQueue(4, scope=reg.scope("adm"))
    q.offer("a", 1)
    q.offer("b", 2)
    assert q.take() is not None
    st = q.stats()
    assert set(st) == {"capacity", "depth", "peak_depth", "accepted",
                       "rejected", "held", "tenants_queued",
                       "served_per_tenant"}
    snap = reg.snapshot()
    assert snap["adm.accepted"] == st["accepted"] == 2
    assert snap["adm.taken"] == 1
    assert snap["adm.depth"] == st["depth"] == 1
    assert snap["adm.held"] == st["held"] == 1


def test_slice_loader_and_ledger_stats_keys_and_mirror():
    from repro.partition.ooc import _OOC, fit_out_of_core, open_source
    g = erdos_renyi(150, 5.0, seed=3)
    run = fit_out_of_core(open_source(g), EngineConfig(split="lp"),
                          memory_budget="1MB", num_partitions=2)
    assert {"partitions", "partition_loads", "prefetches",
            "peak_resident_bytes"} <= set(run.stats())
    snap = REGISTRY.snapshot()
    label = _OOC.label
    assert snap[f"{label}.fits"] >= 1
    assert snap[f"{label}.loads"] >= run.partition_loads > 0
    assert snap[f"{label}.requests"] >= snap[f"{label}.loads"]
    assert snap[f"{label}.bytes_peak"] > 0
    assert snap[f"{label}.exchange_bytes"] >= run.exchange_bytes > 0


def test_ledger_standalone_unscoped():
    from repro.partition.slices import MemoryLedger
    led = MemoryLedger(1000)            # no scope: raw construction works
    led.acquire(600, "a")
    assert led.stats() == {"budget": 1000, "current": 600, "peak": 600}
    led.release(600)


def test_service_stats_keys_and_scope_release():
    from repro.serve.service import ServiceConfig, TenantService
    g = karate_club()[0]
    eng = fresh_engine()
    svc = TenantService(eng, ServiceConfig(queue_capacity=8))
    label = svc._obs.label
    svc.register("t0", g).result()
    st = svc.stats()
    assert {"tenants", "outstanding", "completed", "failed", "spills",
            "uncached", "restored", "warm_cached_tenants", "warm_bytes",
            "p50_ms", "p99_ms", "mean_ms", "admission",
            "batcher"} <= set(st)
    snap = REGISTRY.snapshot()
    assert snap[f"{label}.completed"] == st["completed"] == 1
    assert snap[f"{label}.tenants"] == 1
    assert f"{label}.admission.accepted" in snap
    assert f"{label}.batcher.requests" in snap
    assert f"{label}.warm.bytes_current" in snap
    svc.close()
    assert not [k for k in REGISTRY.snapshot()
                if k.startswith(f"{label}.") or k == label]


def test_serving_emits_spans():
    from repro.serve.service import ServiceConfig, TenantService
    g = karate_club()[0]
    TRACER.reset()
    with TenantService(fresh_engine(),
                       ServiceConfig(queue_capacity=8)) as svc:
        svc.register("t", g).result()
        svc.refresh("t").result()
    names = {s.name for s in TRACER.spans()}
    assert {"serve.admit", "serve.launch", "serve.settle",
            "batch.dispatch", "batch.settle"} <= names


def test_scope_release_frees_child_labels():
    """Releasing a scope must free its children's labels too — a
    restarted service's sub-scopes get bare names, not #1 suffixes."""
    reg = MetricsRegistry()
    s1 = reg.scope("svc")
    assert s1.scope("inner").label == "svc.inner"
    s1.release()
    s2 = reg.scope("svc")
    assert s2.label == "svc"
    assert s2.scope("inner").label == "svc.inner"


# --- exporters: exemplars, prometheus text, endpoint, jsonl ---

def test_histogram_exemplars_capture_span_ids():
    reg = MetricsRegistry()
    h = reg.histogram("lat", (10, 100))
    h.observe(5)                       # outside any span: no exemplar
    assert h.exemplars() == [None, None, None]
    TRACER.reset()
    with TRACER.span("req") as s:
        h.observe(50)
        h.observe(500)                 # overflow bucket
    ex = h.exemplars()
    assert ex[0] is None
    assert ex[1] == (50.0, s.span_id)
    assert ex[2] == (500.0, s.span_id)
    # the latest observation in a bucket wins
    with TRACER.span("req2") as s2:
        h.observe(60)
    assert h.exemplars()[1] == (60.0, s2.span_id)


def test_prometheus_text_round_trip():
    from repro.obs import parse_prometheus_text, prometheus_text
    reg = MetricsRegistry()
    reg.counter("svc.requests").inc(3)
    reg.gauge("svc.quality.disconnected_fraction").set(0.0)
    h = reg.histogram("svc.lat_ms", (1, 10))
    TRACER.reset()
    with TRACER.span("s") as sp:
        h.observe(0.5)
        h.observe(7.0)
        h.observe(7.0)
    text = prometheus_text(reg)
    assert text.endswith("# EOF\n")
    parsed = parse_prometheus_text(text)
    assert parsed["repro_svc_requests_total"][0]["value"] == 3.0
    assert parsed["repro_svc_quality_disconnected_fraction"][0]["value"] \
        == 0.0
    buckets = parsed["repro_svc_lat_ms_bucket"]
    # cumulative counts, +Inf last
    assert [b["labels"]["le"] for b in buckets] == ["1", "10", "+Inf"]
    assert [b["value"] for b in buckets] == [1.0, 3.0, 3.0]
    # every observation ran inside a span: exemplars carry its id
    ex = buckets[1]["exemplar"]
    assert ex["labels"]["span_id"] == str(sp.span_id)
    assert ex["value"] == 7.0
    assert parsed["repro_svc_lat_ms_count"][0]["value"] == 3.0
    assert parsed["repro_svc_lat_ms_sum"][0]["value"] == \
        pytest.approx(14.5)


def test_prometheus_parser_is_strict():
    from repro.obs import parse_prometheus_text
    with pytest.raises(ValueError, match="EOF"):
        parse_prometheus_text("repro_x_total 1\n")
    with pytest.raises(ValueError, match="malformed sample"):
        parse_prometheus_text("not a metric line!\n# EOF\n")
    with pytest.raises(ValueError, match="after # EOF"):
        parse_prometheus_text("# EOF\nrepro_x_total 1\n")
    with pytest.raises(ValueError, match="malformed comment"):
        parse_prometheus_text("# FREeform chatter\n# EOF\n")


def test_metrics_server_routes():
    import urllib.request

    from repro.obs import MetricsServer, parse_prometheus_text
    reg = MetricsRegistry()
    reg.counter("hits").inc(2)
    with MetricsServer(reg, port=0,
                       health_fn=lambda: {"tenants": 3}) as srv:
        def get(path):
            with urllib.request.urlopen(srv.url + path, timeout=10) as r:
                return r.headers.get("Content-Type"), r.read().decode()

        ctype, text = get("/metrics")
        assert ctype.startswith("text/plain")
        assert parse_prometheus_text(text)["repro_hits_total"][0][
            "value"] == 2.0
        _, js = get("/metrics.json")
        assert json.loads(js)["hits"] == 2
        _, hz = get("/healthz")
        assert json.loads(hz) == {"ok": True, "tenants": 3}
        reg.counter("hits").inc()      # scrapes render live values
        _, text2 = get("/metrics")
        assert parse_prometheus_text(text2)["repro_hits_total"][0][
            "value"] == 3.0
        with pytest.raises(urllib.error.HTTPError):
            get("/nope")


def test_jsonl_sink_appends_snapshots(tmp_path):
    from repro.obs import JsonlSink
    reg = MetricsRegistry()
    reg.counter("n").inc()
    path = tmp_path / "metrics.jsonl"
    with JsonlSink(str(path)) as sink:
        sink.emit(reg, tag="t+1s")
        reg.counter("n").inc()
        sink.emit(reg, tag="shutdown")
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l["tag"] for l in lines] == ["t+1s", "shutdown"]
    assert lines[0]["metrics"]["n"] == 1
    assert lines[1]["metrics"]["n"] == 2
    assert lines[1]["ts"] >= lines[0]["ts"]


# --- ooc chrome trace / stats reporter / obs top ---

def test_ooc_chrome_trace_export(tmp_path):
    g = erdos_renyi(150, 5.0, seed=9)
    TRACER.reset()
    r = fresh_engine(split="lp").fit(g, memory_budget="4KB")
    assert r.partitions > 1
    names = {s.name for s in TRACER.spans()}
    assert {"ooc.plan", "ooc.propagation", "ooc.split"} <= names
    out = tmp_path / "ooc_trace.json"
    n = TRACER.export_chrome(out)
    events = json.loads(out.read_text())
    assert n == len(events) >= 3
    ooc_events = [e for e in events if e["name"].startswith("ooc.")]
    assert {e["name"] for e in ooc_events} \
        >= {"ooc.plan", "ooc.propagation", "ooc.split"}
    for ev in ooc_events:
        assert ev["ph"] == "X" and ev["dur"] >= 0


def test_periodic_stats_reporter_flushes_quality(tmp_path, capsys):
    """The serve driver's --stats-every-s reporter: periodic ticks while
    the workload runs, and a final flush on shutdown that carries the
    quality gauges the run populated (plus the JSONL mirror)."""
    import time as _time

    from repro.launch.serve import _PeriodicStats
    from repro.obs import JsonlSink
    g = karate_club()[0]
    path = tmp_path / "stats.jsonl"
    sink = JsonlSink(str(path))
    with _PeriodicStats(0.05, sink=sink):
        eng = fresh_engine(quality="full")
        label = eng._q_obs.label
        eng.fit(g)
        _time.sleep(0.15)              # let at least one tick fire
    sink.emit(tag="shutdown")
    sink.close()
    out = capsys.readouterr().out
    assert "[stats t+" in out          # periodic snapshot emitted
    assert "[stats final]" in out
    final = out.split("[stats final]")[1]
    assert f"{label}.disconnected_fraction" in final
    assert f"{label}.modularity" in final
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines[-1]["tag"] == "shutdown"
    assert lines[-1]["metrics"][f"{label}.disconnected_fraction"] == 0.0
    assert any(l["tag"] == "final" for l in lines)


def test_obs_top_renders_frames():
    from repro.launch.obs import render_top, run_top
    reg = MetricsRegistry()
    reg.counter("svc.requests").inc(7)
    reg.histogram("svc.lat_ms", (1, 10)).observe(3.0)
    frame = render_top(reg.snapshot(), limit=1)
    assert "metric" in frame and "... 1 more metrics" in frame
    outputs = []
    frames = run_top(every_s=0.0, iterations=2, registry=reg,
                     out=outputs.append)
    assert frames == 2
    joined = "\n".join(outputs)
    assert "svc.requests" in joined and "svc.lat_ms" in joined
    assert "[obs top] frame 2" in joined


def test_obs_top_polls_endpoint():
    from repro.launch.obs import run_top
    from repro.obs import MetricsServer
    reg = MetricsRegistry()
    reg.counter("polls").inc(5)
    outputs = []
    with MetricsServer(reg, port=0) as srv:
        frames = run_top(endpoint=srv.url, every_s=0.0, iterations=1,
                         out=outputs.append)
    assert frames == 1
    assert any("polls" in line for line in outputs)
