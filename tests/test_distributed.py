"""Distributed engine tests (8 virtual devices via subprocess — the parent
process has already locked jax to 1 CPU device)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import lpa_run, split_lp, compact_labels, modularity, \
    disconnected_fraction
from repro.core.distributed import distributed_gsl_lpa
from repro.graphgen import karate_club, planted_partition

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
out = {}
for name, g in [("karate", karate_club()[0]),
                ("planted", planted_partition(6, 40, 0.3, 0.01, seed=2)[0])]:
    labels, it, sit = distributed_gsl_lpa(g, mesh)
    st = lpa_run(g)
    sp = split_lp(g, st.labels)
    ref = np.asarray(compact_labels(sp.labels))
    got = np.asarray(compact_labels(jnp.asarray(labels)))
    ckpt_calls = []
    labels2, it2, sit2 = distributed_gsl_lpa(
        g, mesh, exchange_every=2,
        checkpoint_cb=lambda ph, i, l: ckpt_calls.append(ph))
    out[name] = {
        "exact_match": bool(np.array_equal(ref, got)),
        "iters_match": it == int(st.iteration),
        "stale_q": float(modularity(g, jnp.asarray(labels2))),
        "stale_disc": float(disconnected_fraction(g, jnp.asarray(labels2))),
        "ckpt_cb_phases": sorted(set(ckpt_calls)),
    }
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_distributed_equals_single_device(dist_results):
    """Faithful mode (exchange_every=1) is bit-identical to single device."""
    for name, r in dist_results.items():
        assert r["exact_match"], name
        assert r["iters_match"], name


def test_stale_exchange_valid_communities(dist_results):
    """Beyond-paper stale-label mode: still zero disconnected communities."""
    for name, r in dist_results.items():
        assert r["stale_disc"] == 0.0, name
        assert r["stale_q"] > 0.2, name


def test_checkpoint_callback_covers_both_phases(dist_results):
    for name, r in dist_results.items():
        assert r["ckpt_cb_phases"] == ["lpa", "split"], name
