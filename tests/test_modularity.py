"""Modularity (paper Eq. 1)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import modularity
from repro.graphgen import karate_club, ring_of_cliques
from conftest import random_graph


def test_ring_of_cliques_known_value():
    """k cliques in a ring, one-community-per-clique: Q = 1 - in_frac - ...
    Computed directly from Eq. 1 terms."""
    k, s = 8, 6
    g = ring_of_cliques(k, s)
    comm = jnp.asarray(np.repeat(np.arange(k), s).astype(np.int32))
    q = float(modularity(g, comm))
    m = s * (s - 1) / 2 * k + k          # undirected edge count
    in_c = s * (s - 1) / 2               # within one clique
    k_c = 2 * in_c + 2                   # degrees in one community
    expect = k * (in_c / m - (k_c / (2 * m)) ** 2)
    assert q == pytest.approx(expect, abs=1e-6)


def test_karate_known_split():
    g, faction = karate_club()
    q = float(modularity(g, jnp.asarray(faction)))
    # the 2-faction split scores ~0.358-0.372 depending on the exact
    # assignment of the boundary vertices (literature range)
    assert 0.35 <= q <= 0.38, q


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000), st.integers(1, 5))
def test_bounds_and_invariance(n, seed, n_comm):
    g = random_graph(n, 4.0, seed=seed, weighted=True)
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_comm, size=n).astype(np.int32)
    q = float(modularity(g, jnp.asarray(comm)))
    assert -0.5 - 1e-6 <= q <= 1.0 + 1e-6
    # invariant under community relabeling
    perm = rng.permutation(n_comm).astype(np.int32)
    q2 = float(modularity(g, jnp.asarray(perm[comm])))
    assert q == pytest.approx(q2, abs=1e-5)


def test_single_community_zero():
    g = random_graph(30, 4.0, seed=3)
    q = float(modularity(g, jnp.zeros(30, jnp.int32)))
    assert q == pytest.approx(0.0, abs=1e-6)
