"""Per-tenant health plane: quality timelines, drift/SLO alerts, and the
bounded per-tenant registry counters.

The alerts under test: ``modularity_drop`` (quality regressed faster
than streaming drift explains), ``disconnected`` (the paper's headline
invariant broke — must never fire on real fits, pinned at 0.0 through
the live service below), and ``slo_burn`` (edge-triggered p99 latency
excursions).  Tenant ids are an unbounded label space, so everything
per-tenant enters the metrics registry only through
:class:`repro.obs.CappedCounterSet` — the cap is tested here too.
"""
import numpy as np
import pytest

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi
from repro.obs import REGISTRY, CappedCounterSet, MetricsRegistry
from repro.serve import (
    HealthConfig,
    HealthMonitor,
    QualitySample,
    ServiceConfig,
    TenantService,
    TenantTimeline,
)
from repro.serve.health import sample_from_result


def sample(ts=0.0, kind="update", latency_ms=1.0, **kw):
    return QualitySample(ts=ts, kind=kind, latency_ms=latency_ms, **kw)


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


# --- config & timeline ---

def test_health_config_validation():
    HealthConfig()  # defaults are legal
    with pytest.raises(ValueError):
        HealthConfig(timeline_len=0)
    with pytest.raises(ValueError):
        HealthConfig(modularity_drop=0.0)
    with pytest.raises(ValueError):
        HealthConfig(slo_p99_ms=-1.0)
    with pytest.raises(ValueError):
        HealthConfig(latency_window=0)


def test_timeline_ring_is_bounded():
    tl = TenantTimeline(maxlen=4)
    for i in range(10):
        tl.append(sample(ts=float(i), latency_ms=float(i)))
    assert tl.total == 10 and len(tl.samples) == 4
    assert tl.last.ts == 9.0
    d = tl.to_dict()
    assert d["samples"] == 10 and d["window"] == 4
    assert d["last"]["latency_ms"] == 9.0


def test_timeline_p99_latency_window():
    tl = TenantTimeline(maxlen=64)
    for ms in (1.0,) * 20 + (100.0,):
        tl.append(sample(latency_ms=ms))
    assert tl.p99_latency(window=32) == 100.0
    # a window that excludes the spike never sees it
    for _ in range(40):
        tl.append(sample(latency_ms=2.0))
    assert tl.p99_latency(window=8) == 2.0


# --- alerts ---

def test_modularity_drop_alert_fires_on_threshold():
    mon = HealthMonitor(HealthConfig(modularity_drop=0.05))
    assert mon.record("t", sample(modularity=0.60)) == []
    assert mon.record("t", sample(modularity=0.57)) == []   # within budget
    fired = mon.record("t", sample(modularity=0.40))
    assert [a.kind for a in fired] == ["modularity_drop"]
    assert fired[0].value == pytest.approx(0.17)
    # drop is measured against the *previous* sample, not the peak
    assert mon.record("t", sample(modularity=0.39)) == []


def test_disconnected_alert_fires_on_nonzero():
    mon = HealthMonitor()
    assert mon.record("t", sample(disconnected_fraction=0.0)) == []
    fired = mon.record("t", sample(disconnected_fraction=0.25))
    assert [a.kind for a in fired] == ["disconnected"]
    assert fired[0].threshold == 0.0
    assert "invariant" in fired[0].message


def test_slo_burn_is_edge_triggered():
    mon = HealthMonitor(HealthConfig(slo_p99_ms=10.0, latency_window=4))
    assert mon.record("t", sample(latency_ms=5.0)) == []
    burn = mon.record("t", sample(latency_ms=50.0))
    assert [a.kind for a in burn] == ["slo_burn"]
    # still burning: no duplicate alert while the excursion lasts
    assert mon.record("t", sample(latency_ms=60.0)) == []
    assert "t" in mon.stats()["burning"]
    # recover (window rolls past the spikes), then burn again: re-armed
    for _ in range(4):
        assert mon.record("t", sample(latency_ms=1.0)) == []
    assert mon.stats()["burning"] == []
    again = mon.record("t", sample(latency_ms=99.0))
    assert [a.kind for a in again] == ["slo_burn"]


def test_monitor_stats_shape_and_registry_writes():
    reg = MetricsRegistry()
    mon = HealthMonitor(HealthConfig(slo_p99_ms=10.0, latency_window=2),
                        scope=reg.scope("serve.health"))
    mon.record("a", sample(modularity=0.5, disconnected_fraction=0.0))
    mon.record("a", sample(modularity=0.2, latency_ms=99.0))
    mon.record("b", sample(modularity=0.4))
    st = mon.stats()
    assert set(st) == {"tenants", "alert_counts", "alerts", "burning"}
    assert set(st["tenants"]) == {"a", "b"}
    assert st["tenants"]["a"]["samples"] == 2
    assert st["alert_counts"] == {"modularity_drop": 1, "slo_burn": 1}
    assert [a["kind"] for a in st["alerts"]] == ["modularity_drop",
                                                 "slo_burn"]
    snap = reg.snapshot()
    assert snap["serve.health.samples"] == 3
    assert snap["serve.health.tenants"] == 2
    assert snap["serve.health.alerts_modularity_drop"] == 1
    assert snap["serve.health.alerts_slo_burn"] == 1
    assert snap["serve.health.modularity"] == pytest.approx(0.4)
    assert snap["serve.health.disconnected_fraction"] == 0.0


def test_alert_ring_is_bounded():
    mon = HealthMonitor(HealthConfig(max_alerts=8))
    for i in range(20):
        mon.record(f"t{i}", sample(disconnected_fraction=0.5))
    assert len(mon.alerts) == 8
    assert mon.stats()["alert_counts"]["disconnected"] == 20


def test_sample_from_result_reads_quality():
    g = erdos_renyi(120, 5.0, seed=0)
    res = fresh_engine(quality="full").fit(g)
    s = sample_from_result(res, kind="register", latency_ms=3.5)
    assert s.kind == "register" and s.latency_ms == 3.5
    assert s.communities == res.num_communities
    assert s.disconnected_fraction == 0.0
    assert s.modularity == pytest.approx(res.quality.modularity)
    # quality="off" results degrade to latency-only samples
    res_off = fresh_engine().fit(g)
    s_off = sample_from_result(res_off, kind="update", latency_ms=1.0)
    assert s_off.modularity is None and s_off.communities is None


# --- capped per-tenant counters ---

def test_capped_counter_set_overflow_bucket():
    reg = MetricsRegistry()
    s = reg.scope("svc.admission")
    caps = CappedCounterSet(s, "served", max_labels=3)
    for t in ("a", "b", "c", "d", "e", "a"):
        caps.inc(t)
    assert caps.tracked == ("a", "b", "c")
    snap = reg.snapshot()
    assert snap["svc.admission.served.a"] == 2
    assert snap["svc.admission.served.b"] == 1
    assert snap["svc.admission.served.other"] == 2     # d + e share it
    assert "svc.admission.served.d" not in snap
    # keys sanitize into metric-name segments
    caps2 = CappedCounterSet(s, "kinds", max_labels=2)
    caps2.inc("ten ant.1")
    assert "svc.admission.kinds.ten_ant_1" in reg.snapshot()
    with pytest.raises(ValueError):
        CappedCounterSet(s, "bad", max_labels=0)


def test_service_served_counters_respect_cap():
    graphs = {f"t{i}": erdos_renyi(60 + 10 * i, 5.0, seed=i)
              for i in range(5)}
    with TenantService(fresh_engine(),
                       ServiceConfig(queue_capacity=16,
                                     served_label_cap=2)) as svc:
        label = svc._obs.label
        for t, g in graphs.items():
            svc.register(t, g).result()
        snap = REGISTRY.snapshot()
        # 2 dedicated counters + everything else pooled in .other
        assert snap[f"{label}.admission.served.t0"] == 1
        assert snap[f"{label}.admission.served.t1"] == 1
        assert snap[f"{label}.admission.served.other"] == 3
        assert f"{label}.admission.served.t2" not in snap
        # exact per-tenant truth stays on stats()
        st = svc.stats()
        assert st["admission"]["served_per_tenant"] == {
            t: 1 for t in graphs}
    svc.close()


# --- live service integration ---

def test_service_health_timelines_disconnected_zero():
    from repro.core import GraphDelta
    rng = np.random.default_rng(7)
    graphs = {f"t{i}": erdos_renyi(90 + 15 * i, 5.0, seed=10 + i)
              for i in range(4)}
    with TenantService(fresh_engine(quality="full"),
                       ServiceConfig(queue_capacity=16,
                                     health=HealthConfig())) as svc:
        label = svc._obs.label
        for t, g in graphs.items():
            svc.register(t, g).result()
        for t, g in graphs.items():
            d = GraphDelta.make(insert=rng.integers(
                0, g.n, size=(3, 2)).tolist())
            svc.update(t, d).result()
        health = svc.stats()["health"]
        assert set(health["tenants"]) == set(graphs)
        for t, tl in health["tenants"].items():
            assert tl["samples"] == 2
            last = tl["last"]
            # headline invariant holds on every served fit
            assert last["disconnected_fraction"] == 0.0
            assert last["modularity"] is not None
            assert last["kind"] == "update"
        assert "disconnected" not in health["alert_counts"]
        snap = REGISTRY.snapshot()
        assert snap[f"{label}.health.samples"] == 8
        assert snap[f"{label}.health.disconnected_fraction"] == 0.0
        assert snap[f"{label}.health.tenants"] == 4
    svc.close()


def test_service_health_latency_only_without_quality():
    g = erdos_renyi(80, 5.0, seed=3)
    with TenantService(fresh_engine(),   # quality="off"
                       ServiceConfig(queue_capacity=8)) as svc:
        svc.register("t", g).result()
        svc.refresh("t").result()
        health = svc.stats()["health"]
        tl = health["tenants"]["t"]
        assert tl["samples"] == 2
        assert tl["last"]["latency_ms"] > 0.0
        assert tl["last"]["modularity"] is None
        assert health["alert_counts"] == {}
    svc.close()
