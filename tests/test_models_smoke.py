"""Per-architecture smoke tests (assignment requirement (f)): reduced
same-family config, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import transformer as T
from repro.models.common import init_from_specs


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 16, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    params = init_from_specs(T.model_specs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits = T.forward_train(cfg, params, batch)
    s_total = s + (cfg.frontend_len if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_total, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_no_nans(arch):
    cfg = reduced_config(arch)
    params = init_from_specs(T.model_specs(cfg), jax.random.PRNGKey(1))
    batch = _batch(cfg, 2, 32, seed=1)
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert 1.0 < float(loss) < 20.0        # ~ln(vocab) at init
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in gleaves)
    # at least the embedding gradient must be non-zero
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in gleaves)
    assert gnorm > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_metadata_consistency(arch):
    """Every ParamSpec axes tuple matches its shape rank; full-config param
    counts land in the right ballpark for the advertised model size."""
    from repro.configs import get_config
    from repro.models.common import logical_axes
    cfg = reduced_config(arch)
    specs = T.model_specs(cfg)
    axes = logical_axes(specs)
    jax.tree.map(lambda s: None, specs)  # structure intact
    for ax, sp in zip(jax.tree.leaves(axes,
                                      is_leaf=lambda x: isinstance(x, tuple)),
                      jax.tree.leaves(specs,
                                      is_leaf=lambda x: hasattr(x, "shape"))):
        assert len(ax) == len(sp.shape)


EXPECTED_PARAMS_B = {
    "yi-9b": (7, 11), "mistral-nemo-12b": (10, 14),
    "starcoder2-15b": (13, 18), "qwen1.5-32b": (28, 36),
    "jamba-v0.1-52b": (45, 60), "rwkv6-7b": (6, 9),
    "seamless-m4t-large-v2": (1.2, 2.8), "arctic-480b": (420, 520),
    "qwen2-moe-a2.7b": (12, 17),  # 14.3B total / 2.7B active
    "internvl2-26b": (17, 23),    # LM backbone (vit stub excluded)
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_param_count(arch):
    from repro.configs import get_config
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]"
