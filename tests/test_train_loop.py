"""End-to-end training loop: learning, checkpoint/restart determinism,
preemption, straggler detection."""
import numpy as np

from repro.ft import PreemptionHandler, StragglerMonitor
from repro.launch.train import run


def test_loss_decreases():
    out = run("yi-9b", steps=30, seq_len=64, global_batch=8,
              log_every=100, peak_lr=3e-3)
    losses = out["losses"]
    assert min(losses) < losses[0] - 0.5, (losses[0], min(losses))


def test_checkpoint_restart_bitexact(tmp_path):
    """Interrupted+resumed run == uninterrupted run (same final params)."""
    common = dict(arch="yi-9b", seq_len=32, global_batch=4, log_every=100)
    ref = run(steps=8, **common)

    ck = tmp_path / "ck"
    run(steps=4, ckpt_dir=str(ck), save_every=4, **common)
    resumed = run(steps=8, ckpt_dir=str(ck), save_every=4, resume=True,
                  **common)
    assert resumed["final_step"] == 8
    ra, rb = ref["params"], resumed["params"]
    import jax
    for a, b in zip(jax.tree.leaves(ra), jax.tree.leaves(rb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_checkpoints_and_stops(tmp_path):
    handler = PreemptionHandler()
    handler.request_stop()          # simulate SIGTERM before step loop
    out = run("yi-9b", steps=50, seq_len=32, global_batch=4,
              ckpt_dir=str(tmp_path / "ck"), save_every=100,
              log_every=100, preempt=handler)
    assert out["final_step"] == 1   # stopped at the first boundary
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(tmp_path / "ck").latest_step() == 1


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.0, patience=2)
    for s in range(16):
        mon.step_end(s, duration=0.10)
    assert not mon.tripped
    mon.step_end(16, duration=0.5)
    tripped = mon.step_end(17, duration=0.6)
    assert tripped and mon.flagged_steps == [16, 17]


def test_straggler_tolerates_noise():
    mon = StragglerMonitor(window=16, threshold=2.5, patience=3)
    rng = np.random.default_rng(0)
    for s in range(64):
        mon.step_end(s, duration=0.1 + 0.02 * rng.random())
    assert not mon.tripped and len(mon.flagged_steps) == 0
