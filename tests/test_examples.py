"""The examples are part of the public API surface — keep them green."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _run(script: str, *args, timeout=540):
    import os
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "karate club" in out and "disconnected_frac=0.000%" in out


def test_community_pipeline_fault_tolerance():
    out = _run("community_pipeline.py")
    assert "simulated node failure" in out
    assert "restart == uninterrupted: OK" in out
    assert "disconnected=0.0%" in out


def test_moe_expert_placement():
    out = _run("moe_expert_placement.py")
    assert "less" in out and "all-to-all" in out


def test_train_lm_short():
    out = _run("train_lm.py", "--steps", "8", "--seq-len", "64",
               "--global-batch", "2")
    assert "loss:" in out
