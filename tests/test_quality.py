"""Quality-of-result telemetry: reports, churn, parity, cached checks.

The load-bearing contract mirrors the profile layer's: the ``quality``
config knob ("off" | "basic" | "full") is *post-fit* instrumentation —
it must never change a single label or iteration count, solo, batched,
out-of-core, or streaming.  Quality is deliberately absent from
``algo_key`` so parity holds by construction; these tests pin it anyway.

Also pinned: per-mode report field semantics (basic stays host-only —
sizes, count, churn; only full pays the modularity + connectivity device
passes; ooc reports are always host-only), label churn as a
labeling-invariant membership distance, the fingerprint cache behind
repeated ``check_connected`` calls, and the registry gauge names the
serving health plane reads.
"""
import dataclasses

import numpy as np
import pytest

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi, karate_club
from repro.obs import MetricsRegistry
from repro.obs.quality import (
    QualityReport,
    canonical_labels,
    compute_quality,
    label_churn,
    record_report,
)

QUALITY_MODES = ("off", "basic", "full")


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


# --- canonical labels & churn ---

def test_canonical_labels_first_occurrence():
    labels = np.array([7, 7, 3, 7, 3, 9])
    out = canonical_labels(labels)
    assert np.array_equal(out, [0, 0, 1, 0, 1, 2])
    # already-canonical input is a fixed point
    assert np.array_equal(canonical_labels(out), out)


def test_churn_zero_for_identical_and_renamed_partitions():
    labels = np.array([0, 0, 1, 1, 2, 2])
    assert label_churn(labels, labels) == (0.0, 6)
    # pure relabeling (5,5,9,9,0,0) is the same partition: churn 0
    renamed = np.array([5, 5, 9, 9, 0, 0])
    assert label_churn(labels, renamed) == (0.0, 6)


def test_churn_counts_membership_moves():
    prev = np.array([0, 0, 0, 1, 1, 1])
    new = np.array([0, 0, 1, 1, 1, 1])   # one vertex switched community
    churn, k = label_churn(prev, new)
    assert k == 6 and churn == pytest.approx(1 / 6)


def test_churn_none_without_baseline():
    assert label_churn(None, np.array([0, 1])) == (None, 0)
    assert label_churn(np.array([]), np.array([0, 1])) == (None, 0)


def test_churn_common_prefix_on_grown_graph():
    prev = np.array([0, 0, 1, 1])
    new = np.array([0, 0, 1, 1, 2, 2])   # two vertices appended
    churn, k = label_churn(prev, new)
    assert k == 4 and churn == 0.0


# --- compute_quality report semantics ---

def test_compute_quality_rejects_off_and_unknown():
    labels = np.zeros(4, dtype=np.int32)
    with pytest.raises(ValueError):
        compute_quality(labels, mode="off")
    with pytest.raises(ValueError):
        compute_quality(labels, mode="verbose")


def test_report_size_distribution():
    labels = np.array([0, 0, 0, 1, 1, 2])
    rep = compute_quality(labels, mode="basic")
    assert rep.n == 6 and rep.num_communities == 3
    assert rep.size_min == 1 and rep.size_max == 3
    assert rep.size_mean == pytest.approx(2.0)
    d = rep.to_dict()
    assert d["mode"] == "basic" and d["num_communities"] == 3


def test_basic_vs_full_modularity_and_disconnected():
    import jax.numpy as jnp

    from repro.core import modularity
    g = karate_club()[0]
    eng = fresh_engine()
    res = eng.fit(g)
    basic = compute_quality(res.labels, mode="basic", graph=g)
    full = compute_quality(res.labels, mode="full", graph=g,
                           disconnected_fraction=res.check_connected(g))
    # basic computes modularity (paper Eq. 1) but never echoes connectivity
    ref_q = float(modularity(g, jnp.asarray(res.labels)))
    assert basic.modularity == pytest.approx(ref_q)
    assert basic.disconnected_fraction is None
    assert full.disconnected_fraction == 0.0
    assert full.modularity == pytest.approx(basic.modularity)


def test_quality_report_without_graph_is_host_only():
    labels = np.array([0, 1, 0, 1])
    rep = compute_quality(labels, mode="full",
                          prev_labels=np.array([0, 1, 1, 1]))
    assert rep.modularity is None and rep.disconnected_fraction is None
    assert rep.churn == pytest.approx(0.25) and rep.churn_compared == 4


def test_record_report_registry_names():
    reg = MetricsRegistry()
    scope = reg.scope("quality")
    rep = compute_quality(np.array([0, 0, 1]), mode="basic")
    record_report(scope, rep)
    record_report(scope, None)   # None-safe: skipped fits don't crash
    snap = reg.snapshot()
    assert snap["quality.reports"] == 1
    assert snap["quality.communities"] == 2
    assert snap["quality.size_max"] == 2


# --- engine config plumbing ---

def test_engine_config_validates_quality():
    for mode in QUALITY_MODES:
        assert EngineConfig(quality=mode).quality == mode
    with pytest.raises(ValueError):
        EngineConfig(quality="loud")


def test_quality_not_in_algo_key():
    """quality is post-fit: compiled executables must be shared across
    modes, which algo_key controls."""
    keys = {EngineConfig(quality=m).algo_key() for m in QUALITY_MODES}
    assert len(keys) == 1


# --- bit parity across quality modes ---

@pytest.mark.parametrize("backend", ("segment", "tile"))
def test_parity_solo(backend):
    g = erdos_renyi(240, 6.0, seed=3)
    runs = {m: fresh_engine(backend=backend, quality=m).fit(g)
            for m in QUALITY_MODES}
    ref = runs["off"]
    for m in ("basic", "full"):
        r = runs[m]
        assert np.array_equal(ref.labels, r.labels), m
        assert ref.lpa_iterations == r.lpa_iterations
        assert ref.split_iterations == r.split_iterations
        assert isinstance(r.quality, QualityReport) and r.quality.mode == m
    assert ref.quality is None


def test_parity_batched():
    graphs = [erdos_renyi(n, 5.0, seed=n) for n in (60, 90, 120)]
    runs = {m: fresh_engine(quality=m).fit_many(graphs)
            for m in QUALITY_MODES}
    for i in range(len(graphs)):
        ref = runs["off"][i]
        for m in ("basic", "full"):
            r = runs[m][i]
            assert np.array_equal(ref.labels, r.labels)
            assert ref.lpa_iterations == r.lpa_iterations
            assert r.quality.num_communities == r.num_communities


def test_parity_ooc():
    g = erdos_renyi(300, 6.0, seed=11)
    runs = {m: fresh_engine(quality=m).fit(g, memory_budget="4KB")
            for m in QUALITY_MODES}
    ref = runs["off"]
    assert ref.partitions > 1
    for m in ("basic", "full"):
        r = runs[m]
        assert r.partitions == ref.partitions
        assert np.array_equal(ref.labels, r.labels)
        assert ref.lpa_iterations == r.lpa_iterations
        # ooc quality is host-only: no extra device pass over the spilled
        # graph, so modularity/connectivity stay unset
        assert r.quality.modularity is None
        assert r.quality.disconnected_fraction is None
        assert r.quality.num_communities == r.num_communities


def test_parity_streaming_warm_start():
    from repro.core import GraphDelta, affected_frontier, apply_delta
    g = erdos_renyi(180, 6.0, seed=5)
    base = fresh_engine().fit(g).labels
    d = GraphDelta.make(insert=[[0, 90], [1, 120]])
    g2 = apply_delta(g, d)
    frontier = affected_frontier(d, g2.n)
    runs = {m: fresh_engine(quality=m).fit(g2, init_labels=base,
                                           init_active=frontier)
            for m in QUALITY_MODES}
    ref = runs["off"]
    assert ref.warm_started
    for m in ("basic", "full"):
        r = runs[m]
        assert np.array_equal(ref.labels, r.labels)
        assert ref.lpa_iterations == r.lpa_iterations
        # warm refit has a baseline: churn is a real [0, 1] drift signal
        assert r.quality.churn is not None
        assert 0.0 <= r.quality.churn <= 1.0
        assert r.quality.churn_compared == g2.n


def test_engine_basic_mode_is_host_only():
    """The <=5% overhead gate rests on this: basic never pays a device
    pass, so modularity and connectivity stay None on its reports."""
    g = karate_club()[0]
    r = fresh_engine(quality="basic").fit(g)
    assert r.quality.mode == "basic"
    assert r.quality.modularity is None
    assert r.quality.disconnected_fraction is None
    assert r.quality.num_communities == r.num_communities
    assert r.quality.size_max >= r.quality.size_min > 0


def test_cold_fit_has_no_churn_baseline():
    g = karate_club()[0]
    r = fresh_engine(quality="full").fit(g)
    assert r.quality.churn is None and r.quality.churn_compared == 0
    assert r.quality.disconnected_fraction == 0.0


def test_engine_quality_writes_registry():
    from repro.obs import REGISTRY
    g = karate_club()[0]
    eng = fresh_engine(quality="full")
    label = eng._q_obs.label
    eng.fit(g)
    snap = REGISTRY.snapshot()
    assert snap[f"{label}.reports"] == 1
    assert snap[f"{label}.disconnected_fraction"] == 0.0
    assert f"{label}.modularity" in snap


# --- check_connected fingerprint cache ---

def test_check_connected_caches_on_graph_fingerprint(monkeypatch):
    import repro.core.detect as detect
    g1 = erdos_renyi(80, 5.0, seed=1)
    g2 = erdos_renyi(80, 5.0, seed=2)
    res = fresh_engine().fit(g1)
    real = detect.disconnected_fraction
    calls = []

    def counting(graph, labels):
        calls.append(graph)
        return real(graph, labels)

    monkeypatch.setattr(detect, "disconnected_fraction", counting)
    res.disconnected_fraction = None   # force first compute through cache
    res._connected_fp = None
    assert res.check_connected(g1) == 0.0
    assert res.check_connected(g1) == 0.0   # hit: same fingerprint
    assert len(calls) == 1
    res.check_connected(g2)                 # miss: different graph
    assert len(calls) == 2
    res.check_connected(g2)                 # hit again on the new key
    assert len(calls) == 2


def test_check_connected_cache_survives_field_reads():
    g = karate_club()[0]
    res = fresh_engine(quality="full").fit(g)
    # full mode already ran the pass during fit; a later explicit call
    # must reuse it (same fingerprint) rather than re-reduce
    assert res.disconnected_fraction == 0.0
    fp = res._connected_fp
    assert res.check_connected(g) == 0.0
    assert res._connected_fp == fp


def test_detection_result_quality_excluded_from_comparison():
    fields = {f.name: f for f in dataclasses.fields(
        fresh_engine(quality="basic").fit(karate_club()[0]))}
    assert fields["_connected_fp"].compare is False
