"""Split-Last tests — THE paper invariant: no internally-disconnected
communities after splitting (Algorithms 1 & 2)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    compact_labels,
    disconnected_communities,
    split_bfs_host,
    split_lp,
    split_lpp,
)
from repro.graphgen import figure1_graph
from conftest import (
    host_components_within_communities,
    is_partition_refinement,
    random_graph,
    same_partition,
)


def test_figure1_scenario():
    """The paper's Fig. 1/2: vertex 3 defects, disconnecting C1."""
    g, before, after = figure1_graph()
    # 'before' is connected within each community
    _, bad0, _ = disconnected_communities(g, jnp.asarray(before))
    assert int(bad0) == 0
    # 'after' has exactly one disconnected community (C1)
    flags, bad1, ncomm = disconnected_communities(g, jnp.asarray(after))
    assert int(bad1) == 1 and int(ncomm) == 2
    assert bool(np.asarray(flags)[1])           # community id 1 flagged
    # all three split techniques repair it identically (as partitions)
    lp = np.asarray(split_lp(g, jnp.asarray(after)).labels)
    lpp = np.asarray(split_lpp(g, jnp.asarray(after)).labels)
    bfs = split_bfs_host(g, after)
    assert same_partition(lp, lpp)
    assert same_partition(lp, bfs)
    # C1 split into {0,1,2} and {4,5,6}; C2 = {3,7,8,9}
    assert len(set(lp[[0, 1, 2]])) == 1
    assert len(set(lp[[4, 5, 6]])) == 1
    assert lp[0] != lp[4]
    assert len(set(lp[[3, 7, 8, 9]])) == 1


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 50), st.integers(0, 10_000), st.integers(1, 6))
def test_split_properties(n, seed, n_comm):
    """On random graphs with random community assignments:
    1. post-split communities are internally connected (host BFS oracle);
    2. the split refines the input partition;
    3. LP == LPP == BFS as partitions;
    4. result matches (community x component) from the oracle exactly."""
    g = random_graph(n, 3.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    comm = rng.integers(0, n_comm, size=n).astype(np.int32)

    lp = np.asarray(split_lp(g, jnp.asarray(comm)).labels)
    lpp = np.asarray(split_lpp(g, jnp.asarray(comm)).labels)
    bfs = split_bfs_host(g, comm)
    oracle = host_components_within_communities(g, comm)

    _, bad, _ = disconnected_communities(g, jnp.asarray(lp))
    assert int(bad) == 0                       # invariant 1
    assert is_partition_refinement(lp, comm)   # invariant 2
    assert same_partition(lp, lpp)             # invariant 3
    assert same_partition(lp, bfs)
    assert same_partition(lp, oracle)          # invariant 4


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 40), st.integers(0, 10_000))
def test_shortcut_equivalence(n, seed):
    """Pointer-jumping (beyond-paper optimization) preserves the result."""
    g = random_graph(n, 3.0, seed=seed)
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, 4, size=n).astype(np.int32)
    plain = split_lp(g, jnp.asarray(comm), shortcut=False)
    fast = split_lp(g, jnp.asarray(comm), shortcut=True)
    assert np.array_equal(np.asarray(plain.labels), np.asarray(fast.labels))
    assert int(fast.iterations) <= int(plain.iterations)


def test_shortcut_speeds_up_paths():
    """On a long path, shortcutting must reduce sweeps O(n) -> O(log n)."""
    from repro.core.graph import build_graph
    n = 256
    e = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    g = build_graph(e, n=n)
    comm = jnp.zeros(n, jnp.int32)
    plain = split_lp(g, comm, shortcut=False)
    fast = split_lp(g, comm, shortcut=True)
    assert int(plain.iterations) >= n // 2
    assert int(fast.iterations) <= 12
    assert np.array_equal(np.asarray(plain.labels), np.asarray(fast.labels))


def test_compact_labels():
    lab = jnp.asarray(np.array([7, 7, 3, 9, 3], np.int32))
    c = np.asarray(compact_labels(lab))
    assert c.max() == 2 and same_partition(c, np.asarray(lab))
