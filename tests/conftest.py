import numpy as np
import pytest

from repro.core.graph import build_graph


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_graph(n: int, avg_deg: float, seed: int, weighted: bool = False):
    """Random test graph (possibly disconnected — good for split tests)."""
    g = np.random.default_rng(seed)
    m = max(int(n * avg_deg / 2), 1)
    e = g.integers(0, n, size=(m, 2))
    w = g.uniform(0.5, 4.0, size=m).astype(np.float32) if weighted else None
    return build_graph(e, w, n=n)


def host_components_within_communities(graph, comm):
    """Oracle: (vertex -> (community, component)) labels via host BFS."""
    from repro.core.graph import to_numpy_adj
    from collections import deque
    adj = to_numpy_adj(graph)
    comm = np.asarray(comm)
    out = -np.ones(graph.n, dtype=np.int64)
    for s in range(graph.n):
        if out[s] >= 0:
            continue
        out[s] = s
        q = deque([s])
        while q:
            u = q.popleft()
            for v, _w in adj[u]:
                if out[v] < 0 and comm[v] == comm[s]:
                    out[v] = s
                    q.append(v)
    return out


def is_partition_refinement(new, old):
    """Every new community is contained in exactly one old community."""
    new, old = np.asarray(new), np.asarray(old)
    for c in np.unique(new):
        members = old[new == c]
        if len(np.unique(members)) != 1:
            return False
    return True


def same_partition(a, b):
    """Two labelings induce the same partition (up to relabeling)."""
    a, b = np.asarray(a), np.asarray(b)
    fa = {}
    fb = {}
    for x, y in zip(a.tolist(), b.tolist()):
        if fa.setdefault(x, y) != y or fb.setdefault(y, x) != x:
            return False
    return True
