"""Property tests: partition plans and halo coverage on random graphs.

The load-bearing invariant: for every partition, the halo set is
*exactly* the set of out-of-partition endpoints of its edge window — no
cross-partition edge is ever missed (which would silently freeze label
flow across a cut) and no spurious import is ever staged.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow  # hypothesis suites ride the slow CI job

from conftest import random_graph  # noqa: E402
from repro.partition.plan import attach_halos, plan_partitions  # noqa: E402
from repro.partition.slices import InMemorySource, load_partition  # noqa: E402

graph_spec = st.tuples(st.integers(2, 120), st.integers(5, 60),
                       st.integers(0, 10_000))


def _attach(graph, num_partitions):
    source = InMemorySource(graph)
    plan = plan_partitions(np.asarray(graph.row_ptr),
                           num_partitions=num_partitions)
    return source, attach_halos(
        plan, lambda lo, hi: source.window("dst", lo, hi))


@settings(max_examples=25, deadline=None)
@given(graph_spec, st.integers(1, 12))
def test_halo_sets_exactly_cover_cross_partition_edges(spec, parts):
    n, deg_tenths, seed = spec
    g = random_graph(n, deg_tenths / 10.0, seed=seed)
    src = np.asarray(g.src)[: g.num_edges]
    dst = np.asarray(g.dst)[: g.num_edges]
    _source, plan = _attach(g, parts)

    # the plan tiles [0, n) and [0, num_edges) exactly
    assert plan.parts[0].lo == 0 and plan.parts[-1].hi == g.n
    assert all(a.hi == b.lo and a.e_hi == b.e_lo
               for a, b in zip(plan.parts[:-1], plan.parts[1:]))
    assert plan.parts[-1].e_hi == g.num_edges

    for p in plan.parts:
        window_dst = dst[p.e_lo:p.e_hi]
        crossing = np.unique(
            window_dst[(window_dst < p.lo) | (window_dst >= p.hi)])
        assert np.array_equal(p.halo, crossing.astype(np.int32))
        # windows really belong to the vertex range
        assert np.all((src[p.e_lo:p.e_hi] >= p.lo)
                      & (src[p.e_lo:p.e_hi] < p.hi))


@settings(max_examples=15, deadline=None)
@given(graph_spec, st.integers(2, 8))
def test_local_remap_round_trips(spec, parts):
    n, deg_tenths, seed = spec
    g = random_graph(n, deg_tenths / 10.0, seed=seed)
    src = np.asarray(g.src)[: g.num_edges]
    dst = np.asarray(g.dst)[: g.num_edges]
    source, plan = _attach(g, parts)
    for p in plan.parts:
        res = load_partition(source, p)
        assert np.array_equal(res.local_ids[res.src], src[p.e_lo:p.e_hi])
        assert np.array_equal(res.local_ids[res.dst], dst[p.e_lo:p.e_hi])
        # halo rows sit after the owned rows and never collide with them
        assert res.n_local == p.size + len(p.halo)


@settings(max_examples=8, deadline=None)
@given(st.integers(10, 80), st.integers(10, 50), st.integers(0, 1000),
       st.integers(2, 6))
def test_partitioned_fit_parity_property(n, deg_tenths, seed, parts):
    """End-to-end: a forced partitioned fit is bit-identical to in-core
    on arbitrary random graphs (segment backend, default split)."""
    from repro.engine import CompileCache, Engine, EngineConfig
    from repro.partition.ooc import fit_out_of_core

    g = random_graph(n, deg_tenths / 10.0, seed=seed)
    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    ref = eng.fit(g)
    run = fit_out_of_core(InMemorySource(g), eng.config,
                          memory_budget="1GB", num_partitions=parts,
                          cache=eng.cache)
    ooc_labels = np.unique(run.labels, return_inverse=True)[1]
    assert np.array_equal(ref.labels, ooc_labels.astype(np.int32))
